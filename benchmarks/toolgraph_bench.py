"""Tool-graph compiler benchmark: planner round-trips saved by fusing
independent tool calls, at provably unchanged task quality.

The compiler (core/toolgraph.py + ScriptedPlanner.next_compiled_step)
turns the linear one-call-per-LLM-round-trip loop into DAG round-trips:
each planner request emits a hazard graph of every call it can commit
to, and the runtime executes independent nodes in parallel waves —
across the steps of one session AND across co-resident sessions in the
serving pipeline (execute_graph_batch). The bench measures the two
deltas that fusion is allowed to move — planner round-trips and tokens
— and asserts the three things it must NOT move:

  1. quality parity: gated + ungated quality metrics (correct rate,
     success rate, DetF1, LCC R, Rouge-L) are IDENTICAL linear vs
     compiled — the behaviour model is shared, only round-trip
     structure changes;
  2. fused parity: the cross-session fused pipeline reproduces the
     compiled sequential run bitwise, including tokens and steps;
  3. world isolation: the World fingerprint is unchanged by a fused
     multi-session run (tool execution never mutates shared state).

Headline (asserted, CI-gated via check_regression.py): the gated
compiled cell must cut planner round-trips by >= 1.5x vs gated linear.

Writes results/toolgraph_bench.{json,md}.

  PYTHONPATH=src python benchmarks/toolgraph_bench.py [--tiny] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

COLUMNS = ("gate", "planner", "execution", "correct", "success",
           "det_f1", "lcc_r", "rouge_l", "tokens_per_task",
           "round_trips_per_task", "virtual_steps_per_task",
           "tools_per_round_trip")

QUALITY = ("correct", "success", "det_f1", "lcc_r", "rouge_l")


def _cell(world, tasks, gate, compile_plans, fused, seed, concurrency):
    """Run one (±gate, ±compiler, ±fusion) cell; returns (row, stats)."""
    import numpy as np
    from repro.core.agent import Agent
    from repro.core.gate import IntentGate, ScriptedIntentClassifier
    from repro.core.intents import build_intent_map
    from repro.core.planner import PlannerConfig
    from repro.core.tools import DEFAULT_REGISTRY
    from repro.env.evaluator import evaluate_results
    from repro.serving.pipeline import (GeckOptPipeline, PipelineConfig)

    cfg = PlannerConfig(mode="react", few_shot=False,
                        compile_plans=compile_plans)
    g = None
    if gate:
        imap = build_intent_map(tasks, DEFAULT_REGISTRY)
        g = IntentGate(imap,
                       ScriptedIntentClassifier(
                           0.97, np.random.default_rng(seed)),
                       DEFAULT_REGISTRY.libraries())
    agent = Agent(DEFAULT_REGISTRY, world, cfg, gate=g, seed=seed)
    pipe_stats = {}
    if fused:
        pipe = GeckOptPipeline(agent, PipelineConfig(
            max_concurrent=concurrency, engine_turns=False))
        results = pipe.run(tasks)
        pipe_stats = pipe.stats.summary()
    else:
        results = [agent.run_task(t, task_seed=i)
                   for i, t in enumerate(tasks)]
    rep = evaluate_results(results, "cell")
    n = max(len(results), 1)
    rts = sum(r.ledger.n_round_trips for r in results)
    row = {
        "gate": "on" if gate else "off",
        "planner": "compiled" if compile_plans else "linear",
        "execution": "fused" if fused else "sequential",
        "correct": round(rep.correct_rate, 6),
        "success": round(rep.success_rate, 6),
        "det_f1": round(rep.det_f1, 6),
        "lcc_r": round(rep.lcc_r, 6),
        "rouge_l": round(rep.vqa_rouge_l, 6),
        "tokens_per_task": round(rep.tokens_per_task, 3),
        "round_trips_per_task": round(rts / n, 4),
        "virtual_steps_per_task": round(
            sum(r.ledger.n_virtual_steps for r in results) / n, 4),
        "tools_per_round_trip": round(
            sum(r.ledger.n_tool_calls for r in results) / max(rts, 1),
            4),
    }
    return row, pipe_stats


def bench(tiny: bool = False):
    from repro.env.tasks import make_benchmark
    from repro.env.world import build_world

    seed = 0
    n_tasks, concurrency = (24, 8) if tiny else (200, 16)
    world = build_world(seed)
    tasks = make_benchmark(world, n_tasks, seed=seed)
    fp_before = world.fingerprint()

    rows = []
    cells = {}
    for gate in (False, True):
        for compiled in (False, True):
            row, _ = _cell(world, tasks, gate, compiled, False, seed,
                           concurrency)
            cells[(gate, compiled, False)] = row
            rows.append(row)
    # the serving path: compiled sessions fused across the wave
    fused_row, pipe_stats = _cell(world, tasks, True, True, True, seed,
                                  concurrency)
    cells[(True, True, True)] = fused_row
    rows.append(fused_row)
    fp_after = world.fingerprint()

    def reduction(gate):
        lin = cells[(gate, False, False)]["round_trips_per_task"]
        comp = cells[(gate, True, False)]["round_trips_per_task"]
        return round(lin / max(comp, 1e-9), 4)

    quality_identical = all(
        cells[(gate, False, False)][q] == cells[(gate, True, False)][q]
        for gate in (False, True) for q in QUALITY)
    seq = cells[(True, True, False)]
    metric_cols = [c for c in COLUMNS
                   if c not in ("gate", "planner", "execution")]
    fused_parity = all(seq[c] == fused_row[c] for c in metric_cols)

    gk = cells[(True, True, False)]
    lin_gk = cells[(True, False, False)]
    meta = {
        "tiny": tiny, "n_tasks": n_tasks, "concurrency": concurrency,
        "round_trip_reduction_gated": reduction(True),
        "round_trip_reduction_ungated": reduction(False),
        "token_reduction_gated": round(
            1 - gk["tokens_per_task"] / lin_gk["tokens_per_task"], 4),
        "fused_tokens_per_task": fused_row["tokens_per_task"],
        "tools_per_round_trip_gated": gk["tools_per_round_trip"],
        "quality_identical": quality_identical,
        "fused_parity": fused_parity,
        "world_unchanged": fp_before == fp_after,
        "fused_batches": pipe_stats.get("fused_batches", 0),
        "fused_calls": pipe_stats.get("fused_calls", 0),
        "fused_sessions_peak": pipe_stats.get("fused_sessions_peak", 0),
    }
    if not quality_identical:
        raise AssertionError(
            "tool-graph compilation changed a quality metric — fusion "
            "must only move round-trip structure, never outcomes")
    if not fused_parity:
        raise AssertionError(
            "cross-session fused execution diverged from the compiled "
            "sequential run — reconciliation order or workspace "
            "isolation is broken")
    if not meta["world_unchanged"]:
        raise AssertionError(
            "fused run mutated the shared World — tool implementations "
            "must treat it as read-only")
    if meta["round_trip_reduction_gated"] < 1.5:
        raise AssertionError(
            f"gated round-trip reduction "
            f"{meta['round_trip_reduction_gated']} < 1.5x — the "
            f"compiler is not fusing enough calls to pay for itself")
    return rows, meta


def write_results(rows, meta, path=None):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    md = ["# toolgraph_bench — tool-graph compiler round-trip fusion",
          "",
          f"{meta['n_tasks']} tasks, react zero-shot, gate accuracy "
          f"0.97, pipeline concurrency {meta['concurrency']}; the "
          f"fused row batches every co-resident session's DAG into one "
          f"execution wave per tick.", "",
          "| " + " | ".join(COLUMNS) + " |",
          "|" + "---|" * len(COLUMNS)]
    for r in rows:
        md.append("| " + " | ".join(str(r[c]) for c in COLUMNS) + " |")
    md += ["",
           f"- gated round-trip reduction: "
           f"**{meta['round_trip_reduction_gated']}x** (bar: >= 1.5x); "
           f"ungated {meta['round_trip_reduction_ungated']}x",
           f"- gated token reduction from compilation: "
           f"**{100 * meta['token_reduction_gated']:.1f}%**",
           f"- quality metrics identical linear vs compiled: "
           f"**{meta['quality_identical']}**",
           f"- fused pipeline bitwise equals compiled sequential: "
           f"**{meta['fused_parity']}** "
           f"(world unchanged: {meta['world_unchanged']})",
           f"- fused waves: {meta['fused_batches']} batches / "
           f"{meta['fused_calls']} calls, peak "
           f"{meta['fused_sessions_peak']} sessions per batch",
           "",
           "Interpretation: gating narrows the catalog so the planner "
           "commits to more calls per round-trip; the compiler then "
           "collapses every hazard-independent run of calls into one "
           "DAG request. Round-trips and prompt-token re-sends drop "
           "multiplicatively while the behaviour model — and therefore "
           "every quality metric — is untouched, because the compiled "
           "planner replays the exact linear decision stream and the "
           "hazard deps (rng modelled as a serial write resource) make "
           "any topological execution order bitwise-equal to emission "
           "order."]
    with open(os.path.join(RESULTS_DIR, "toolgraph_bench.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    out_json = path or os.path.join(RESULTS_DIR, "toolgraph_bench.json")
    with open(out_json, "w") as f:
        json.dump({"meta": meta, "rows": rows}, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke config (24 tasks)")
    ap.add_argument("--out", default=None,
                    help="write the JSON here instead of results/ "
                         "(markdown is skipped); used by the CI "
                         "bench-regression gate")
    args = ap.parse_args()
    rows, meta = bench(tiny=args.tiny)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"meta": meta, "rows": rows}, f, indent=1)
    elif not args.tiny:
        write_results(rows, meta)
    for r in rows:
        print(f"gate={r['gate']:3s} {r['planner']:8s} "
              f"{r['execution']:10s} tok/task={r['tokens_per_task']:9.1f} "
              f"rt/task={r['round_trips_per_task']:6.3f} "
              f"tools/rt={r['tools_per_round_trip']:6.3f} "
              f"success={r['success']:.4f}")
    print(f"round_trip_reduction_gated="
          f"{meta['round_trip_reduction_gated']} "
          f"quality_identical={meta['quality_identical']} "
          f"fused_parity={meta['fused_parity']}")
    return rows, meta


if __name__ == "__main__":
    main()
