"""Speculative-decoding benchmark: tokens per target forward and draft
acceptance across intent mixes, KV modes and kernel backends.

Every engine decode step is one target-model forward — the unit the
whole serving stack is billed in. Non-speculative decoding emits at
most one token per busy slot per forward; with ``spec_decode`` the
engine drafts K cheap tokens per slot and verifies them all in ONE
target forward, so ``tokens_per_step`` (tokens / target forwards)
multiplies by the acceptance rate. GeckOpt's intent gating makes
traffic skew onto hot intents with predictable completions — the
regime where a small draft agrees with the target most; the repo ships
no trained weights to distill a draft from, so the bench instantiates
the draft WITH the target's weights (the perfect-agreement stand-in:
greedy acceptance is 1.0 by construction, and the T=0.8 rows show how
sampled verification prices disagreement).

Every (mix, temperature, kv_mode, backend) scenario runs a baseline
engine and a speculative engine over the SAME seeded traffic and
asserts BITWISE-equal outputs and finish reasons — the sample-and-match
acceptance rule (serving/specdec.py) makes speculative decoding a pure
performance lever, never a quality one. The headline row (skewed mix,
greedy, dense, reference) must clear 1.5x baseline tokens/step.

Writes results/specdec_bench.{json,md}.

  PYTHONPATH=src python benchmarks/specdec_bench.py [--tiny] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

COLUMNS = ("mix", "T", "kv", "backend", "mode", "tokens_per_step",
           "accept_rate", "speedup", "tokens_out", "steps",
           "tokens_equal")

N_INTENTS = 4
PREFIX_LEN = 24
SUFFIX_LEN = 6


def _traffic(mix: str, n_sessions: int):
    """Deterministic session list: (prompt ids, prefix key) per session.
    ``skewed`` puts ~75% of sessions on intent 0 (the GeckOpt hot-intent
    regime the cluster router exploits); ``uniform`` spreads evenly."""
    prefixes = {i: list(range(10 + 40 * i, 10 + 40 * i + PREFIX_LEN))
                for i in range(N_INTENTS)}
    sessions = []
    n_hot = (3 * n_sessions) // 4
    for s in range(n_sessions):
        intent = (0 if mix == "skewed" and s < n_hot
                  else s % N_INTENTS)
        suffix = list(range(1000 + SUFFIX_LEN * s,
                            1000 + SUFFIX_LEN * (s + 1)))
        sessions.append((prefixes[intent] + suffix, f"intent:{intent}"))
    return prefixes, sessions


def _drive(eng, sessions, max_new: int, temperature: float):
    from repro.serving.sampling import SamplerConfig
    rid_to_idx = {}
    for i, (ids, key) in enumerate(sessions):
        rid = eng.add_request(
            ids, max_new_tokens=max_new,
            sampler=SamplerConfig(temperature=temperature,
                                  top_k=40 if temperature else 0,
                                  seed=77_000 + i),
            prefix_key=key)
        rid_to_idx[rid] = i
    t0 = time.time()
    done = eng.run_until_done()
    wall = time.time() - t0
    st = eng.throughput_stats()
    outputs = {rid_to_idx[r.request_id]: (tuple(r.output),
                                          r.finish_reason)
               for r in done}
    return outputs, st, wall


def bench(tiny: bool = False):
    import jax
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving.engine import InferenceEngine
    from repro.serving.specdec import SpecConfig

    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    spec_k = 4

    if tiny:
        n_sessions, max_new, max_batch, cache_len = 6, 10, 2, 128
        n_pallas = 3
    else:
        n_sessions, max_new, max_batch, cache_len = 16, 20, 4, 256
        n_pallas = 6
    bs = 16
    kv_blocks = max_batch * cache_len // bs

    spec = SpecConfig(draft_cfg=cfg, draft_params=params, k=spec_k)
    # share jitted step closures across same-shape engines: the bench
    # builds ~20 engines and must compile each step once, not 20x.
    # Two donor pools per backend — the engine steps (shared by all)
    # and the spec-only verify/draft steps (shared by spec engines)
    compiled = {}
    compiled_spec = {}

    def engine(kv, backend, with_spec):
        kw = ({"kv_blocks": kv_blocks, "block_size": bs}
              if kv == "paged" else {})
        eng = InferenceEngine(cfg, params, max_batch=max_batch,
                              cache_len=cache_len, kv_mode=kv,
                              backend=backend,
                              spec_decode=spec if with_spec else None,
                              **kw)
        donor = compiled.get(eng.backend)
        if donor is None:
            compiled[eng.backend] = eng
        else:
            eng._prefill, eng._decode, eng._extend = \
                donor._prefill, donor._decode, donor._extend
        if with_spec:
            sdonor = compiled_spec.get(eng.backend)
            if sdonor is None:
                compiled_spec[eng.backend] = eng
            else:
                eng._verify = sdonor._verify
                eng.spec.share_compiled(sdonor.spec)
        return eng

    rows = []

    def scenario(mix, temperature, kv, backend, n=None):
        prefixes, sessions = _traffic(mix, n or n_sessions)
        results = {}
        for mode in ("baseline", "spec"):
            eng = engine(kv, backend, mode == "spec")
            for i, pref in prefixes.items():
                eng.register_prefix(f"intent:{i}", pref)
            outputs, st, wall = _drive(eng, sessions, max_new,
                                       temperature)
            results[mode] = (outputs, st, wall)
        (b_out, b_st, b_wall), (s_out, s_st, s_wall) = \
            results["baseline"], results["spec"]
        equal = b_out == s_out
        if not equal:
            raise AssertionError(
                f"speculative decoding diverged from the baseline on "
                f"({mix}, T={temperature}, {kv}, {backend}) — the "
                f"sample-and-match acceptance broke bitwise parity")
        speedup = round(s_st["tokens_per_step"]
                        / max(b_st["tokens_per_step"], 1e-9), 4)
        for mode, (out, st, wall) in results.items():
            rows.append({
                "mix": mix, "T": temperature, "kv": kv,
                "backend": backend, "mode": mode,
                "tokens_per_step": st["tokens_per_step"],
                "accept_rate": (st["spec_accept_rate"]
                                if mode == "spec" else ""),
                "speedup": speedup if mode == "spec" else "",
                "tokens_out": sum(len(o) for o, _ in out.values()),
                "steps": st["decode_steps"],
                "rounds": st["spec_rounds"],
                "tokens_equal": equal,
                "wall_s": round(wall, 2),
            })
        return speedup, s_st["spec_accept_rate"]

    headline, headline_accept = scenario("skewed", 0.0, "dense",
                                         "reference")
    scenario("skewed", 0.0, "paged", "reference")
    scenario("uniform", 0.0, "dense", "reference")
    scenario("skewed", 0.8, "dense", "reference")
    scenario("skewed", 0.8, "paged", "reference")
    # pallas smoke pair (interpret mode on CPU — small but real): the
    # flash_verify kernels must stay bitwise-parity too
    scenario("skewed", 0.0, "dense", "pallas", n=n_pallas)
    scenario("skewed", 0.0, "paged", "pallas", n=n_pallas)

    meta = {
        "tiny": tiny, "spec_k": spec_k, "n_sessions": n_sessions,
        "max_new_tokens": max_new, "max_batch": max_batch,
        "cache_len": cache_len, "block_size": bs,
        "kv_blocks": kv_blocks,
        "spec_speedup_skewed_greedy": headline,
        "spec_accept_skewed_greedy": headline_accept,
        "tokens_identical": all(r["tokens_equal"] for r in rows),
    }
    if headline <= 1.5:
        raise AssertionError(
            f"speculative tokens/step speedup {headline} <= 1.5x on "
            f"the skewed greedy mix — the draft-verify loop is not "
            f"paying for itself")
    return rows, meta


def write_results(rows, meta, path=None):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    md = ["# specdec_bench — draft-verify speculative decoding",
          "",
          f"{meta['n_sessions']} sessions over {N_INTENTS} intent "
          f"prefixes, k={meta['spec_k']} draft tokens/round, "
          f"{meta['max_new_tokens']} new tokens each, "
          f"{meta['max_batch']} slots, seeded samplers; draft shares "
          f"the target's weights (perfect-agreement stand-in).", "",
          "| " + " | ".join(COLUMNS) + " |",
          "|" + "---|" * len(COLUMNS)]
    for r in rows:
        md.append("| " + " | ".join(str(r[c]) for c in COLUMNS) + " |")
    md += ["",
           f"- skewed-mix greedy speedup (tokens/target-forward): "
           f"**{meta['spec_speedup_skewed_greedy']}x** "
           f"(bar: > 1.5x)",
           f"- bitwise-identical tokens + finish reasons in every "
           f"scenario: **{meta['tokens_identical']}**",
           "",
           "Interpretation: at T=0 the self-draft always agrees, so "
           "tokens/step approaches k+1 per busy slot — the upper bound "
           "intent-skewed greedy planner traffic approaches with a "
           "well-distilled draft. At T=0.8 the sample-and-match rule "
           "only accepts drafts that equal the target's own seeded "
           "sample, pricing verification exactness in acceptance: "
           "tokens/step degrades toward 1x but NEVER below it, and "
           "outputs stay bitwise identical. Paged and dense agree "
           "throughout (rollback is pos truncation either way); the "
           "pallas rows run the fused flash_verify kernels."]
    with open(os.path.join(RESULTS_DIR, "specdec_bench.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    out_json = path or os.path.join(RESULTS_DIR, "specdec_bench.json")
    with open(out_json, "w") as f:
        json.dump({"meta": meta, "rows": rows}, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke config (small pool, few sessions)")
    ap.add_argument("--out", default=None,
                    help="write the JSON here instead of results/ "
                         "(markdown is skipped); used by the CI "
                         "bench-regression gate")
    args = ap.parse_args()
    rows, meta = bench(tiny=args.tiny)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"meta": meta, "rows": rows}, f, indent=1)
    elif not args.tiny:
        write_results(rows, meta)
    for r in rows:
        print(f"{r['mix']:8s} T={r['T']:.1f} {r['kv']:5s} "
              f"{r['backend']:9s} {r['mode']:8s} "
              f"tok/step={r['tokens_per_step']:7.3f} "
              f"accept={str(r['accept_rate']):6s} "
              f"speedup={str(r['speedup']):6s} equal={r['tokens_equal']}")
    print(f"speedup_skewed_greedy={meta['spec_speedup_skewed_greedy']} "
          f"tokens_identical={meta['tokens_identical']}")
    return rows, meta


if __name__ == "__main__":
    main()
