"""CI benchmark-regression gate: compare a fresh bench JSON against the
committed baseline with per-metric tolerances.

The serving benches are tick-based and fully seeded, so their headline
metrics (tokens/step, prefix-hit ratio, peak KV bytes, accept rate,
memory savings) are DETERMINISTIC — identical on any machine — and can
be gated tightly; wall-clock numbers are never compared. Tolerances
exist so small intentional changes (a scheduler tweak shifting a tick)
don't fail the gate, while real regressions (speculative speedup lost,
prefix cache stops hitting, paged memory win evaporates) do.

Direction semantics per metric:

  higher  regression when current < baseline * (1 - tol)
  lower   regression when current > baseline * (1 + tol)
  equal   regression when current != baseline (invariants: bitwise
          token parity flags, request counts)

Improvements never fail the gate. To RATCHET a baseline after an
intentional improvement, re-run the bench with ``--tiny --out`` and
commit the refreshed ``results/*_tiny.json`` (full-size baselines come
from the plain bench runs).

  PYTHONPATH=src python benchmarks/check_regression.py \
      --bench specdec --current ci-bench/specdec.json
      [--baseline results/specdec_bench_tiny.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def _row(data, **match):
    for r in data["rows"]:
        if all(r.get(k) == v for k, v in match.items()):
            return r
    raise KeyError(f"no row matching {match}")


# ------------------------------------------------------- extractors ----
# one per bench: loaded JSON -> {metric: value}; the SPECS table below
# names the direction + tolerance for each extracted metric

def _engine_metrics(d):
    return {
        "requests": d["requests"],
        "generated_tokens": d["generated_tokens"],
        "tokens_per_step": d["tokens_per_step"],
        "kv_bytes_peak": d["kv_bytes_peak"],
    }


def _cluster_metrics(d):
    aff = _row(d, policy="intent_affinity")
    rr = _row(d, policy="round_robin")
    # stall-free scheduling section (cluster_bench.bench_interleave);
    # the bench already hard-asserts gain >= 1.5x and parity — these
    # gate against the committed baseline on top of that floor
    im = d["interleave"]["meta"]
    return {
        "affinity_prefix_hit": aff["prefix_hit"],
        "affinity_beats_round_robin":
            d["meta"]["affinity_beats_round_robin"],
        "tokens_identical":
            d["meta"]["tokens_identical_across_policies"],
        "tokens_out": rr["tokens_out"],
        "affinity_sla": aff["sla"],
        "interleave_ttft_p99_gain": im["interleave_ttft_p99_gain"],
        "interleave_tokens_identical":
            im["interleave_tokens_identical"],
        "interleave_tps_ratio": im["interleave_tps_ratio"],
    }


def _paging_metrics(d):
    conc = _row(d, scenario="concurrency@budget", mode="paged")
    mem = _row(d, scenario="memory@slots", mode="paged")
    return {
        "paged_memory_savings": d["meta"]["paged_memory_savings"],
        "tokens_identical": d["meta"]["tokens_identical"],
        "paged_peak_concurrent": conc["peak_concurrent"],
        "paged_tokens_per_step": conc["tokens_per_step"],
        "paged_kv_bytes_peak": mem["kv_bytes_peak"],
    }


def _specdec_metrics(d):
    return {
        "spec_speedup_skewed_greedy":
            d["meta"]["spec_speedup_skewed_greedy"],
        "spec_accept_skewed_greedy":
            d["meta"]["spec_accept_skewed_greedy"],
        "tokens_identical": d["meta"]["tokens_identical"],
    }


def _toolgraph_metrics(d):
    return {
        "round_trip_reduction_gated":
            d["meta"]["round_trip_reduction_gated"],
        "token_reduction_gated": d["meta"]["token_reduction_gated"],
        "tools_per_round_trip_gated":
            d["meta"]["tools_per_round_trip_gated"],
        "fused_tokens_per_task": d["meta"]["fused_tokens_per_task"],
        "quality_identical": d["meta"]["quality_identical"],
        "fused_parity": d["meta"]["fused_parity"],
        "world_unchanged": d["meta"]["world_unchanged"],
    }


def _retrieval_metrics(d):
    return {
        "token_savings_512": d["meta"]["token_savings_512"],
        "recall_at_k": d["meta"]["recall_at_k"],
        "outcomes_identical": d["meta"]["outcomes_identical"],
        "quality_identical": d["meta"]["quality_identical"],
    }


# (direction, relative tolerance) per metric; see the module docstring
SPECS = {
    "engine": (_engine_metrics, {
        "requests": ("equal", 0.0),
        "generated_tokens": ("higher", 0.1),
        "tokens_per_step": ("higher", 0.1),
        "kv_bytes_peak": ("lower", 0.1),
    }),
    "cluster": (_cluster_metrics, {
        "affinity_prefix_hit": ("higher", 0.05),
        "affinity_beats_round_robin": ("equal", 0.0),
        "tokens_identical": ("equal", 0.0),
        # volume, not invariant: a jaxlib bump can shift sampled ids
        # (and thus eos timing) — the within-run parity flags above
        # stay exact, the token volume just must not collapse
        "tokens_out": ("higher", 0.1),
        "affinity_sla": ("higher", 0.1),
        # stall-free scheduling: losing the interleaving TTFT win (or
        # its token parity / throughput neutrality) is a regression
        "interleave_ttft_p99_gain": ("higher", 0.1),
        "interleave_tokens_identical": ("equal", 0.0),
        "interleave_tps_ratio": ("higher", 0.05),
    }),
    "paging": (_paging_metrics, {
        "paged_memory_savings": ("higher", 0.1),
        "tokens_identical": ("equal", 0.0),
        "paged_peak_concurrent": ("higher", 0.0),
        "paged_tokens_per_step": ("higher", 0.1),
        "paged_kv_bytes_peak": ("lower", 0.1),
    }),
    "specdec": (_specdec_metrics, {
        "spec_speedup_skewed_greedy": ("higher", 0.1),
        "spec_accept_skewed_greedy": ("higher", 0.05),
        "tokens_identical": ("equal", 0.0),
    }),
    "toolgraph": (_toolgraph_metrics, {
        # round-trips saved is the compiler's headline — direction
        # higher: losing fusion width is the regression being gated
        "round_trip_reduction_gated": ("higher", 0.1),
        "token_reduction_gated": ("higher", 0.1),
        "tools_per_round_trip_gated": ("higher", 0.1),
        "fused_tokens_per_task": ("lower", 0.1),
        # invariants, not volumes: parity flags must hold exactly
        "quality_identical": ("equal", 0.0),
        "fused_parity": ("equal", 0.0),
        "world_unchanged": ("equal", 0.0),
    }),
    "retrieval": (_retrieval_metrics, {
        # the headline: tokens saved by retrieved-toolset exposure at
        # the 512-tool catalog (includes miss-and-widen overhead)
        "token_savings_512": ("higher", 0.05),
        "recall_at_k": ("higher", 0.05),
        # invariant: retrieval must never change a task outcome
        "outcomes_identical": ("equal", 0.0),
        "quality_identical": ("equal", 0.0),
    }),
}


def compare(bench: str, current: dict, baseline: dict):
    """Returns (failures, report_lines) for one bench pair."""
    extract, spec = SPECS[bench]
    cur, base = extract(current), extract(baseline)
    failures, lines = [], []
    for name, (direction, tol) in spec.items():
        c, b = cur[name], base[name]
        if direction == "equal":
            ok = c == b
        elif direction == "higher":
            ok = float(c) >= float(b) * (1.0 - tol)
        else:                                              # lower
            ok = float(c) <= float(b) * (1.0 + tol)
        status = "ok" if ok else "REGRESSION"
        lines.append(f"  {name:28s} {direction:6s} tol={tol:<5} "
                     f"base={b} cur={c}  {status}")
        if not ok:
            failures.append(name)
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", required=True, choices=sorted(SPECS))
    ap.add_argument("--current", required=True,
                    help="fresh bench JSON (e.g. from --tiny --out)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON (default: "
                         "results/<bench>_bench_tiny.json)")
    args = ap.parse_args(argv)
    baseline_path = args.baseline or os.path.join(
        RESULTS_DIR, f"{args.bench}_bench_tiny.json")
    with open(args.current) as f:
        current = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures, lines = compare(args.bench, current, baseline)
    print(f"{args.bench}_bench vs {os.path.relpath(baseline_path)}:")
    print("\n".join(lines))
    if failures:
        print(f"FAIL: {len(failures)} regressed metric(s): "
              f"{', '.join(failures)}")
        return 1
    print("PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
