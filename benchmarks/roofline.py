"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch × shape) on the single-pod mesh:
  compute term    = per-chip HLO FLOPs (trip-count-scaled) / 197 TFLOP/s
  memory term     = per-chip HBM-traffic model bytes / 819 GB/s
  collective term = per-chip collective bytes / 50 GB/s ICI

plus MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference) and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs. Reads results/dryrun/*.json
written by repro.launch.dryrun; writes results/roofline.md.
"""
from __future__ import annotations

import glob
import json
import os

from repro.common.config import INPUT_SHAPES
from repro.configs import ARCH_IDS, get_config
from repro.models.model import count_active_params

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def model_flops_per_chip(arch: str, shape_name: str, n_chips: int) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = count_active_params(cfg)
    if shape.mode == "train":
        tokens = shape.seq_len * shape.global_batch
        total = 6 * n_active * tokens
    elif shape.mode == "prefill":
        tokens = shape.seq_len * shape.global_batch
        total = 2 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2 * n_active * shape.global_batch
    return total / n_chips


def analyze(mesh: str = "single", tag: str = ""):
    suffix = f"__{tag}" if tag else ""
    rows = []
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            path = os.path.join(DRYRUN_DIR,
                                f"{arch}__{shape}__{mesh}{suffix}.json")
            if not os.path.exists(path):
                rows.append({"arch": arch, "shape": shape,
                             "status": "missing"})
                continue
            with open(path) as f:
                rec = json.load(f)
            if rec["status"] != "ok":
                rows.append({"arch": arch, "shape": shape,
                             "status": rec["status"],
                             "reason": rec.get("reason", "")[:60]})
                continue
            n_chips = rec["n_devices"]
            flops = rec["profile"]["flops_scaled"]
            hbm = rec["profile"]["bytes_scaled"]
            coll = rec["collectives"]["collective_bytes"]
            t_c = flops / PEAK_FLOPS
            t_m = hbm / HBM_BW
            t_x = coll / ICI_BW
            dom = max(("compute", t_c), ("memory", t_m),
                      ("collective", t_x), key=lambda kv: kv[1])
            mflops = model_flops_per_chip(arch, shape, n_chips)
            rows.append({
                "arch": arch, "shape": shape, "status": "ok",
                "t_compute_s": t_c, "t_memory_s": t_m,
                "t_collective_s": t_x, "dominant": dom[0],
                "model_flops_per_chip": mflops,
                "useful_ratio": mflops / max(flops, 1),
                "args_gib": rec["memory"].get("argument_size_in_bytes",
                                              0) / 2**30,
                "temp_gib": rec["memory"].get("temp_size_in_bytes",
                                              0) / 2**30,
            })
    return rows


def to_markdown(rows):
    md = ["| arch | shape | compute s | memory s | collective s | "
          "dominant | useful ratio | args GiB/chip | temp GiB/chip |",
          "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            md.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                      f"{r['status']} | | | |")
            continue
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.3f} | "
            f"{r['args_gib']:.2f} | {r['temp_gib']:.1f} |")
    return "\n".join(md)


def delta_markdown(base_rows, prod_rows):
    """Baseline vs production-profile comparison table."""
    md = ["| arch | shape | bottleneck (base) | bottleneck (prod) | Δ | "
          "temp GiB base→prod |",
          "|---|---|---|---|---|---|"]
    by_key = {(r["arch"], r["shape"]): r for r in prod_rows}
    for b in base_rows:
        if b["status"] != "ok":
            continue
        p = by_key.get((b["arch"], b["shape"]))
        if not p or p["status"] != "ok":
            md.append(f"| {b['arch']} | {b['shape']} | — | "
                      f"{(p or {}).get('status', 'missing')} | | |")
            continue
        bdom = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
        pdom = max(p["t_compute_s"], p["t_memory_s"], p["t_collective_s"])
        md.append(
            f"| {b['arch']} | {b['shape']} | {bdom:.3g} s ({b['dominant']}) "
            f"| {pdom:.3g} s ({p['dominant']}) | "
            f"{(1 - pdom / bdom) * 100:+.1f}% | "
            f"{b['temp_gib']:.0f}→{p['temp_gib']:.0f} |")
    return "\n".join(md)


def main():
    rows = analyze("single")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    with open(os.path.join(RESULTS_DIR, "roofline.md"), "w") as f:
        f.write(to_markdown(rows) + "\n")
    ok = [r for r in rows if r["status"] == "ok"]
    print(f"roofline: {len(ok)} pairs analyzed")
    for r in ok:
        print(f"  {r['arch']:18s} {r['shape']:12s} dom={r['dominant']:10s} "
              f"c={r['t_compute_s']:.3g}s m={r['t_memory_s']:.3g}s "
              f"x={r['t_collective_s']:.3g}s useful={r['useful_ratio']:.3f}")
    prod = analyze("single", tag="prod")
    if any(r["status"] == "ok" for r in prod):
        with open(os.path.join(RESULTS_DIR, "roofline_prod.json"), "w") as f:
            json.dump(prod, f, indent=1)
        with open(os.path.join(RESULTS_DIR, "roofline_prod.md"), "w") as f:
            f.write(to_markdown(prod) + "\n\n## baseline vs prod\n\n")
            f.write(delta_markdown(rows, prod) + "\n")
        n_ok = sum(r["status"] == "ok" for r in prod)
        print(f"prod profile: {n_ok} pairs analyzed "
              f"-> results/roofline_prod.md")
    return rows


if __name__ == "__main__":
    main()
