"""Compare roofline terms between dry-run records (baseline vs perf tags).

Usage: PYTHONPATH=src python -m benchmarks.perf_compare <arch> <shape> [tags...]
Prints one row per tag (baseline = untagged record) with the three roofline
terms and deltas vs baseline — the measurement step of each §Perf iteration.
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks.roofline import (DRYRUN_DIR, HBM_BW, ICI_BW, PEAK_FLOPS,
                                 model_flops_per_chip)


def terms(rec):
    flops = rec["profile"]["flops_scaled"]
    hbm = rec["profile"]["bytes_scaled"]
    coll = rec["collectives"]["collective_bytes"]
    return {
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": hbm / HBM_BW,
        "t_collective": coll / ICI_BW,
        "temp_gib": rec["memory"].get("temp_size_in_bytes", 0) / 2**30,
        "flops": flops,
        "useful": (model_flops_per_chip(rec["arch"], rec["shape"],
                                        rec["n_devices"]) / max(flops, 1)),
    }


def load(arch, shape, tag=""):
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__single{suffix}.json")
    with open(path) as f:
        rec = json.load(f)
    assert rec["status"] == "ok", (path, rec.get("error", "")[:200])
    return rec


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    tags = sys.argv[3:] or [""]
    base = terms(load(arch, shape))
    print(f"{arch} × {shape}  (single-pod 16x16)")
    hdr = (f"{'tag':16s} {'compute s':>11s} {'memory s':>11s} "
           f"{'collect s':>11s} {'bottleneck s':>13s} {'temp GiB':>9s} "
           f"{'useful':>7s}")
    print(hdr)

    def row(name, t):
        dom = max(t["t_compute"], t["t_memory"], t["t_collective"])
        print(f"{name:16s} {t['t_compute']:11.3g} {t['t_memory']:11.3g} "
              f"{t['t_collective']:11.3g} {dom:13.3g} {t['temp_gib']:9.1f} "
              f"{t['useful']:7.3f}")
        return dom

    dom0 = row("baseline", base)
    for tag in tags:
        if not tag:
            continue
        t = terms(load(arch, shape, tag))
        dom = row(tag, t)
        print(f"{'':16s} bottleneck delta vs baseline: "
              f"{(1 - dom / dom0) * 100:+.1f}% reduction")


if __name__ == "__main__":
    main()
