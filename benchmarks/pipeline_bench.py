"""Serving-pipeline benchmark: batched gate speedup + concurrent
harness throughput + engine prefix-cache reuse.

Three sections, written to results/pipeline_bench.md / .json:

**gate_batch** — the gate hot path. For each query count Q, classify Q
queries with (a) the sequential ``NeuralIntentClassifier`` — Q×8 jitted
B=1 forward passes, one per (query, intent) pair — and (b) the
``BatchedNeuralIntentClassifier`` — ONE jitted (Q*8, L) forward pass.
Columns:

  Q             queries classified (one admission wave);
  seq_s         wall seconds, sequential 8×B=1 baseline (jit-warm);
  batched_s     wall seconds, single batched forward (jit-warm);
  speedup       seq_s / batched_s — the acceptance bar is strictly > 1
                at Q ≥ 16;
  batched_qps   Q / batched_s, the gate's serving throughput.

**harness** — end-to-end Table-2 traffic. The same task set is run by
the sequential evaluator (one task to completion at a time) and by the
concurrent pipeline (N sessions in flight, wave-batched gating).
Columns:

  tasks         benchmark tasks completed;
  seq_s         sequential harness wall seconds;
  pipeline_s    concurrent pipeline wall seconds;
  tasks_per_s   pipeline throughput;
  metrics_equal pipeline results are bit-identical to sequential (the
                pipeline reorders *work*, never *outcomes*) — maps to
                the paper's claim that gating efficiency costs no task
                performance (Table 2's ± columns).

**engine_prefix** — per-intent prompt-prefix caching on the inference
engine. Gate-style requests sharing a system-prompt prefix are served
with and without ``register_prefix``; columns report prefill token work
(prefix_tokens_saved) avoided by reuse and the hit count.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def bench_gate_batch(qs=(4, 16, 32), seq_len: int = 64):
    import jax
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving.neural_planner import (
        BatchedNeuralIntentClassifier, NeuralIntentClassifier)

    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    seq = NeuralIntentClassifier(cfg, params, seq_len=seq_len)
    bat = BatchedNeuralIntentClassifier(cfg, params, seq_len=seq_len)

    pool = [f"benchmark query {i}: plot sentinel2 images near city {i}"
            for i in range(max(qs))]
    rows = []
    for Q in qs:
        queries = pool[:Q]
        # jit warmup for both paths at this shape
        seq.classify(queries[0])
        bat.classify_batch(queries)
        t0 = time.time()
        a = [seq.classify(q)[0] for q in queries]
        t1 = time.time()
        b = [d[0] for d in bat.classify_batch(queries)]
        t2 = time.time()
        rows.append({"Q": Q, "seq_s": round(t1 - t0, 4),
                     "batched_s": round(t2 - t1, 4),
                     "speedup": round((t1 - t0) / max(t2 - t1, 1e-9), 2),
                     "batched_qps": round(Q / max(t2 - t1, 1e-9), 1),
                     "decisions_equal": a == b})
    return rows


def bench_harness(n_tasks: int = 64, seed: int = 0,
                  concurrency: int = 16):
    from repro.core.agent import Agent
    from repro.core.gate import IntentGate, ScriptedIntentClassifier
    from repro.core.intents import build_intent_map
    from repro.core.planner import PlannerConfig
    from repro.core.tools import DEFAULT_REGISTRY
    from repro.env.evaluator import evaluate
    from repro.env.tasks import make_benchmark
    from repro.env.world import build_world
    from repro.serving.pipeline import evaluate_pipeline

    world = build_world(seed)
    tasks = make_benchmark(world, n_tasks, seed=seed)
    imap = build_intent_map(tasks, DEFAULT_REGISTRY)
    cfg = PlannerConfig(mode="react", few_shot=False)

    def gate():
        return IntentGate(imap, ScriptedIntentClassifier(
            0.97, np.random.default_rng(seed)),
            DEFAULT_REGISTRY.libraries())

    t0 = time.time()
    r_seq = evaluate(Agent(DEFAULT_REGISTRY, world, cfg, gate=gate(),
                           seed=seed), tasks, "seq")
    t1 = time.time()
    r_par = evaluate_pipeline(Agent(DEFAULT_REGISTRY, world, cfg,
                                    gate=gate(), seed=seed),
                              tasks, "par", max_concurrent=concurrency)
    t2 = time.time()
    return {"tasks": n_tasks, "concurrency": concurrency,
            "seq_s": round(t1 - t0, 3),
            "pipeline_s": round(t2 - t1, 3),
            "tasks_per_s": round(n_tasks / max(t2 - t1, 1e-9), 2),
            "metrics_equal": r_seq.row() == r_par.row()}


def bench_engine_prefix(n_requests: int = 8):
    import jax
    from repro.configs import get_smoke_config
    from repro.core.gate import GATE_SYSTEM
    from repro.models.model import init_params
    from repro.serving.engine import InferenceEngine
    from repro.serving.sampling import SamplerConfig

    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    queries = [f"plot images of region {i}" for i in range(n_requests)]

    def serve(use_prefix):
        eng = InferenceEngine(cfg, params, max_batch=4, cache_len=512)
        if use_prefix:
            eng.register_prefix("gate", GATE_SYSTEM)
        t0 = time.time()
        for q in queries:
            eng.add_request(f"{GATE_SYSTEM}\nQuery: {q}\nIntent:",
                            max_new_tokens=4,
                            sampler=SamplerConfig(temperature=0.0),
                            prefix_key="gate" if use_prefix else None)
        outs = sorted((r.request_id, tuple(r.output))
                      for r in eng.run_until_done())
        return time.time() - t0, eng.throughput_stats(), outs

    cold_s, cold_stats, cold_out = serve(False)
    warm_s, warm_stats, warm_out = serve(True)
    return {"requests": n_requests,
            "no_prefix_s": round(cold_s, 3),
            "prefix_s": round(warm_s, 3),
            "prefix_hits": warm_stats["prefix_hits"],
            "prefix_tokens_saved": warm_stats["prefix_tokens_saved"],
            "full_prefills_avoided": (cold_stats["prefills"]
                                      - warm_stats["prefills"] + 1),
            "outputs_equal": cold_out == warm_out}


def run(n_tasks: int = 64, qs=(4, 16, 32)):
    gate_rows = bench_gate_batch(qs)
    harness = bench_harness(n_tasks)
    prefix = bench_engine_prefix()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    md = ["## gate_batch — batched vs sequential intent scoring", "",
          "| Q | seq_s | batched_s | speedup | batched_qps | equal |",
          "|---|---|---|---|---|---|"]
    for r in gate_rows:
        md.append(f"| {r['Q']} | {r['seq_s']} | {r['batched_s']} | "
                  f"{r['speedup']}x | {r['batched_qps']} | "
                  f"{r['decisions_equal']} |")
    md += ["", "## harness — concurrent pipeline vs sequential loop", "",
           f"```\n{json.dumps(harness, indent=1)}\n```", "",
           "## engine_prefix — per-intent prompt-prefix caching", "",
           f"```\n{json.dumps(prefix, indent=1)}\n```"]
    out = {"gate_batch": gate_rows, "harness": harness,
           "engine_prefix": prefix}
    with open(os.path.join(RESULTS_DIR, "pipeline_bench.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    with open(os.path.join(RESULTS_DIR, "pipeline_bench.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    out = run()
    for r in out["gate_batch"]:
        print(f"gate Q={r['Q']:3d}: {r['speedup']}x speedup "
              f"({r['batched_qps']} q/s batched), "
              f"decisions_equal={r['decisions_equal']}")
    h = out["harness"]
    print(f"harness: {h['tasks']} tasks seq {h['seq_s']}s vs pipeline "
          f"{h['pipeline_s']}s ({h['tasks_per_s']} tasks/s), "
          f"metrics_equal={h['metrics_equal']}")
    p = out["engine_prefix"]
    print(f"engine prefix cache: {p['prefix_hits']} hits, "
          f"{p['prefix_tokens_saved']} prefill tokens saved, "
          f"outputs_equal={p['outputs_equal']}")
    return out


if __name__ == "__main__":
    main()
