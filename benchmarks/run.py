"""Benchmark entry point: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,metric,value`` CSV lines and writes per-benchmark artifacts
under results/.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller task counts (CI mode)")
    args = ap.parse_args()
    n = 120 if args.fast else 400

    from benchmarks import engine_bench, gating, roofline, steps_tools, \
        table2

    lines = []

    t0 = time.time()
    t2 = table2.main(n_tasks=n)
    for name, rec in t2.items():
        lines.append(f"table2,{name}_token_reduction_pct,"
                     f"{rec['token_reduction_pct']}")
        lines.append(f"table2,{name}_success_delta_pp,"
                     f"{rec['success_delta_pct']}")
    lines.append(f"table2,wall_s,{time.time()-t0:.1f}")

    t0 = time.time()
    st = steps_tools.main()
    lines.append(f"steps_tools,step_reduction_pct,"
                 f"{st['step_reduction_pct']}")
    lines.append(f"steps_tools,tools_per_step_gain_pct,"
                 f"{st['tools_per_step_gain_pct']}")
    lines.append(f"steps_tools,wall_s,{time.time()-t0:.1f}")

    t0 = time.time()
    g = gating.main()
    lines.append(f"gating,keyword_acc_pct,"
                 f"{g['keyword_classifier_accuracy']}")
    lines.append(f"gating,wall_s,{time.time()-t0:.1f}")

    t0 = time.time()
    eb = engine_bench.main(argv=[])
    lines.append(f"engine,decode_tok_per_s,{eb['decode_tok_per_s']}")
    lines.append(f"engine,wall_s,{time.time()-t0:.1f}")

    rl = roofline.main()
    n_ok = sum(1 for r in rl if r["status"] == "ok")
    n_skip = sum(1 for r in rl if r["status"] == "skipped")
    lines.append(f"roofline,pairs_ok,{n_ok}")
    lines.append(f"roofline,pairs_skipped,{n_skip}")

    print("\n=== CSV ===")
    for ln in lines:
        print(ln)


if __name__ == "__main__":
    main()
