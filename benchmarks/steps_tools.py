"""Fig-1 microbenchmark: multi-step×single-tool vs multi-step×multi-tool.

Measures the paper's central mechanism: distribution of tool calls per
LLM step with the full catalog vs the intent-gated catalog.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier
from repro.core.intents import build_intent_map
from repro.core.planner import PlannerConfig
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.tasks import make_benchmark
from repro.env.world import build_world

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def run(n_tasks: int = 200, seed: int = 0):
    world = build_world(seed)
    tasks = make_benchmark(world, n_tasks, seed=seed)
    imap = build_intent_map(tasks, DEFAULT_REGISTRY)
    gate = IntentGate(imap, ScriptedIntentClassifier(
        0.97, np.random.default_rng(seed)), DEFAULT_REGISTRY.libraries())
    cfg = PlannerConfig(mode="react", few_shot=False)

    def profile(agent, label):
        steps, tools, multi = [], [], 0
        total_steps = 0
        for i, t in enumerate(tasks):
            res = agent.run_task(t, task_seed=i)
            n_steps = res.ledger.n_plan_steps
            steps.append(n_steps)
            tools.append(len(res.executed_tools))
            # count multi-tool steps from the per-step records
            total_steps += n_steps
        return {"label": label,
                "steps_per_task": float(np.mean(steps)),
                "tools_per_task": float(np.mean(tools)),
                "tools_per_step": float(np.sum(tools) / max(1,
                                                            np.sum(steps)))}

    base = profile(Agent(DEFAULT_REGISTRY, world, cfg, gate=None,
                         seed=seed), "full-catalog")
    gk = profile(Agent(DEFAULT_REGISTRY, world, cfg, gate=gate, seed=seed),
                 "geckopt-gated")
    # third profile: gated + tool-graph compiler — steps become DAG
    # round-trips, so tools/step is the fusion width per LLM request
    ccfg = PlannerConfig(mode="react", few_shot=False, compile_plans=True)
    cgate = IntentGate(imap, ScriptedIntentClassifier(
        0.97, np.random.default_rng(seed)), DEFAULT_REGISTRY.libraries())
    comp = profile(Agent(DEFAULT_REGISTRY, world, ccfg, gate=cgate,
                         seed=seed), "geckopt-gated+compiled")
    out = {"full": base, "gated": gk, "gated_compiled": comp,
           "step_reduction_pct": round(
               100 * (1 - gk["steps_per_task"] / base["steps_per_task"]),
               2),
           "tools_per_step_gain_pct": round(
               100 * (gk["tools_per_step"] / base["tools_per_step"] - 1),
               2),
           "compiled_round_trip_reduction": round(
               gk["steps_per_task"] / max(comp["steps_per_task"], 1e-9),
               4)}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "steps_tools.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    out = run()
    print(f"steps/task {out['full']['steps_per_task']:.2f} -> "
          f"{out['gated']['steps_per_task']:.2f} "
          f"(-{out['step_reduction_pct']}%), tools/step "
          f"{out['full']['tools_per_step']:.2f} -> "
          f"{out['gated']['tools_per_step']:.2f} "
          f"(+{out['tools_per_step_gain_pct']}%); compiled round-trips "
          f"{out['gated_compiled']['steps_per_task']:.2f} "
          f"({out['compiled_round_trip_reduction']}x fewer), tools/rt "
          f"{out['gated_compiled']['tools_per_step']:.2f}")
    return out


if __name__ == "__main__":
    main()
