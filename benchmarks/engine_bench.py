"""Serving-engine benchmark: throughput/latency of the planner engine and
the token→FLOPs link that turns the paper's token savings into hardware
cost (the "cloud cost savings" extrapolation of §2).

Prefill FLOPs ≈ 2·N·T per request; GeckOpt shrinks T per step and the
number of steps, so FLOPs/task drops proportionally — measured here with
the real engine on the reduced planner config.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs import get_smoke_config
from repro.models.model import count_params_analytic, init_params
from repro.serving.engine import InferenceEngine
from repro.serving.sampling import SamplerConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def run(n_requests: int = 12, max_new: int = 16, cache_len: int = 256):
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = count_params_analytic(cfg)

    engine = InferenceEngine(cfg, params, max_batch=4,
                             cache_len=cache_len)
    # warmup compile
    engine.add_request("warmup request", max_new_tokens=2)
    engine.run_until_done()

    prompts = [f"plot sentinel2 images around region {i} with clouds "
               f"below 20 percent and draw detections" * 3
               for i in range(n_requests)]
    t0 = time.time()
    for p in prompts:
        engine.add_request(p, max_new_tokens=max_new,
                           sampler=SamplerConfig(temperature=0.7, top_k=40))
    done = engine.run_until_done()
    dt = time.time() - t0
    st = engine.throughput_stats()
    prompt_tokens = sum(len(r.prompt) for r in done)
    gen_tokens = sum(len(r.output) for r in done)
    flops_per_task = 2 * n_params * (prompt_tokens + gen_tokens) \
        / max(len(done), 1)
    out = {
        "requests": len(done),
        "wall_s": round(dt, 2),
        "decode_tok_per_s": round(gen_tokens / max(dt, 1e-9), 1),
        "prefill_tokens": prompt_tokens,
        "model_params": n_params,
        "prefill_flops_per_task": flops_per_task,
        # GeckOpt link: ~26% fewer tokens/task (table2) => same fraction
        # of prefill FLOPs saved per task on the serving fleet.
        # deterministic engine counters (seeded rng, tick-based): the
        # CI bench-regression gate compares these, never wall-clock
        "generated_tokens": gen_tokens,
        "decode_steps": st["decode_steps"],
        "tokens_per_step": st["tokens_per_step"],
        "kv_bytes_peak": st["kv_bytes_peak"],
    }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke config (fewer, shorter requests)")
    ap.add_argument("--out", default=None,
                    help="write the JSON here instead of results/")
    args = ap.parse_args(argv)
    out = (run(n_requests=4, max_new=6, cache_len=192) if args.tiny
           else run())
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)
    elif not args.tiny:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(os.path.join(RESULTS_DIR, "engine_bench.json"),
                  "w") as f:
            json.dump(out, f, indent=1)
    print(f"engine: {out['requests']} reqs in {out['wall_s']}s, "
          f"{out['decode_tok_per_s']} decode tok/s, "
          f"{out['tokens_per_step']} tok/step, "
          f"{out['prefill_flops_per_task']:.2e} prefill FLOPs/task")
    return out


if __name__ == "__main__":
    main()
