import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Per-op breakdown of collectives + HBM traffic for one pair.

Usage: PYTHONPATH=src python -m benchmarks.hlo_breakdown <arch> <shape> [perf-spec] [strategy k=v,...]
"""
import re
import sys
from collections import defaultdict

from repro.common.perf import PerfFlags, set_flags
from repro.launch import dryrun as dr
from repro.launch import hlo_stats as hs
from repro.common.config import INPUT_SHAPES
from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh

import jax

arch, shape_name = sys.argv[1], sys.argv[2]
perf = sys.argv[3] if len(sys.argv) > 3 else ""
strat_spec = sys.argv[4] if len(sys.argv) > 4 else ""
set_flags(PerfFlags().apply_overrides(perf))

strategy = shd.ShardingStrategy()
if strat_spec:
    kw = {}
    for kv in strat_spec.split(","):
        k, v = kv.split("=")
        cur = getattr(strategy, k)
        kw[k] = (v == "True") if isinstance(cur, bool) else type(cur)(v)
    strategy = strategy.replace(**kw)

cache = ("/tmp/hlo_" + "_".join([arch, shape_name, perf, strat_spec])
         .replace("/", "-").replace(",", "+") + ".txt")
if os.path.exists(cache):
    text = open(cache).read()
    n_dev = 256
    print(f"(cached HLO: {cache})")
else:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    fn, args, in_sh, out_sh = dr.build_lowerable(cfg, shape, mesh, strategy)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        compiled = jitted.lower(*args).compile()
    mem = compiled.memory_analysis()
    print(f"temp GiB: {mem.temp_size_in_bytes/2**30:.1f}  "
          f"args GiB: {mem.argument_size_in_bytes/2**30:.1f}")
    text = compiled.as_text()
    with open(cache, "w") as f:
        f.write(text)
    n_dev = mesh.devices.size

comps, mult = hs.computation_multipliers(text)

# ---- collectives per op, with multiplier ----
rows = []
for name, lines in comps.items():
    if name != "__entry__" and lines is comps.get("__entry__"):
        continue
    m = mult.get(name, 1.0) or 1.0
    for ln in lines:
        kind = next((c for c in hs._COLLECTIVES
                     if re.search(rf"\b{c}(-start|-done)?\(", ln)), None)
        if kind is None or f"{kind}-done(" in ln:
            continue
        lhs = ln.split(f" {kind}")[0]
        size = hs._shape_bytes(lhs)
        if size == 0:
            continue
        g = hs._group_size(ln, n_dev)
        if g <= 1:
            continue
        if kind == "all-gather":
            moved = size * (g - 1) / g
        elif kind == "reduce-scatter":
            moved = size * (g - 1)
        elif kind == "all-reduce":
            moved = 2 * size * (g - 1) / g
        elif kind == "all-to-all":
            moved = size * (g - 1) / g
        else:
            moved = size
        shp = lhs.split("=")[1].strip() if "=" in lhs else lhs
        rows.append((moved * m, kind, g, m, shp[:90], name[:40]))
rows.sort(reverse=True)
print("\n=== top collectives (moved bytes x trips) ===")
tot = sum(r[0] for r in rows)
print(f"total: {tot/2**30:.1f} GiB over {len(rows)} ops")
for mv, kind, g, m, shp, comp in rows[:25]:
    print(f"{mv/2**30:9.2f} GiB  {kind:20s} g={g:<4d} trips={m:<6.0f} {shp}  [{comp}]")

# ---- HBM traffic per op kind ----
traffic = defaultdict(float)
fusion_called = set()
for lines in comps.values():
    for ln in lines:
        for k, callee in hs._callees(ln):
            if k in ("to_apply", "call"):
                fusion_called.add(callee)
big = []
for name, lines in comps.items():
    m = mult.get(name, 0.0)
    if m == 0.0 or name in fusion_called:
        continue
    if name != "__entry__" and lines is comps.get("__entry__"):
        continue
    table = hs._shape_table(lines)
    for ln in lines:
        op = hs._instr_op(ln)
        if not op or op in hs._SKIP_OPS:
            continue
        out_b = hs._out_shape_bytes(ln)
        in_b = sum(hs._shape_bytes(table.get(o, "")) for o in hs._operands(ln))
        b = (out_b + in_b) * m
        traffic[op] += b
        big.append((b, op, ln[:110], name[:40]))
print("\n=== HBM traffic by op kind ===")
for op, b in sorted(traffic.items(), key=lambda kv: -kv[1])[:12]:
    print(f"{b/2**40:9.2f} TiB  {op}")
big.sort(reverse=True)
print("\n=== top instructions by traffic ===")
for b, op, ln, comp in big[:20]:
    print(f"{b/2**40:8.3f} TiB  {op:12s} {ln}  [{comp}]")

# ---- traffic grouped by output shape (finds spread-out cost) ----
by_shape = defaultdict(float)
cnt = defaultdict(int)
for b, op, ln, comp in big:
    rhs = ln.split("=", 1)[1].strip() if "=" in ln else ""
    m2 = re.match(r"((\([^)]*\))|[\w\[\],\.]+)", rhs)
    shp = m2.group(1)[:70] if m2 else "?"
    by_shape[(op, shp)] += b
    cnt[(op, shp)] += 1
print("\n=== traffic grouped by (op, out-shape) ===")
for (op, shp), b in sorted(by_shape.items(), key=lambda kv: -kv[1])[:25]:
    print(f"{b/2**40:8.3f} TiB  n={cnt[(op,shp)]:<5d} {op:12s} {shp}")
