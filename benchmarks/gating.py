"""Gate quality benchmark: intent accuracy, fallback rate, gate overhead,
and sensitivity of the token savings to classifier accuracy (the paper's
"fully GPT-driven ... revert to the full toolset" robustness claim).
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier, \
    keyword_intent
from repro.core.intents import build_intent_map
from repro.core.planner import PlannerConfig
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.evaluator import evaluate
from repro.env.tasks import make_benchmark
from repro.env.world import build_world

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def run(n_tasks: int = 160, seed: int = 0):
    world = build_world(seed)
    tasks = make_benchmark(world, n_tasks, seed=seed)
    imap = build_intent_map(tasks, DEFAULT_REGISTRY)
    cfg = PlannerConfig(mode="cot", few_shot=False)
    base = evaluate(Agent(DEFAULT_REGISTRY, world, cfg, gate=None,
                          seed=seed), tasks, "base")

    kw_acc = float(np.mean([keyword_intent(t.query) == t.intent
                            for t in tasks]))
    sweep = {}
    for acc in (1.0, 0.97, 0.90, 0.75, 0.50):
        gate = IntentGate(imap, ScriptedIntentClassifier(
            acc, np.random.default_rng(seed)), DEFAULT_REGISTRY.libraries())
        r = evaluate(Agent(DEFAULT_REGISTRY, world, cfg, gate=gate,
                           seed=seed), tasks, f"acc={acc}")
        sweep[acc] = {
            "token_reduction_pct": round(
                100 * (1 - r.tokens_per_task / base.tokens_per_task), 2),
            "success_delta_pp": round(
                100 * (r.success_rate - base.success_rate), 2),
            "fallback_rate_pct": round(100 * r.fallback_rate, 2),
            "gate_tokens": round(r.gate_tokens, 1),
        }
    out = {"keyword_classifier_accuracy": round(100 * kw_acc, 2),
           "sweep": sweep}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "gating.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    out = run()
    print(f"keyword intent accuracy: {out['keyword_classifier_accuracy']}%")
    for acc, rec in out["sweep"].items():
        print(f"  gate acc {acc}: tokens -{rec['token_reduction_pct']}%, "
              f"success {rec['success_delta_pp']:+}pp, "
              f"fallback {rec['fallback_rate_pct']}%")
    return out


if __name__ == "__main__":
    main()
