"""Cluster router-policy benchmark: intent affinity vs oblivious routing.

Serves ONE seeded synthetic workload (serving/workload.py — skewed
intent mix, seeded per-request samplers, multi-turn sessions) through
the same N-replica ``EngineCluster`` under each routing policy, and
tabulates what the router changes and what it must not change.

Because every request carries a sampler seed, its output tokens are a
pure function of the workload — NOT of placement — so ``tokens_out``
must be identical across policies (the table's ``tokens_equal`` column
asserts it against round_robin). What the router *does* move:

  policy            round_robin | least_loaded | intent_affinity;
  prefix_hit        cluster prefix-hit ratio (hits / admissions). The
                    affinity router sends same-intent traffic to the
                    replica holding that intent's cached prefix prefill,
                    so this is the headline column: affinity >=
                    round_robin is the acceptance bar;
  prefill_tok_saved prompt tokens not recomputed thanks to those hits;
  ttft_p50/p95      ticks from arrival to first token (one tick = one
                    cluster-wide continuous-batching step);
  e2e_p95           ticks from arrival to completion;
  qwait_p95         ticks spent queued before a slot freed up;
  sla               fraction of requests finishing within their
                    per-request deadline;
  util_min/max      per-replica slot utilization spread — affinity
                    concentrates the hot intent on its home replica
                    (high max, low min), the load-aware policies
                    flatten it: the cache-locality vs load-balance
                    trade the router picks;
  tokens_out        total generated tokens (identical by construction).

A second section benchmarks **stall-free scheduling** (DESIGN.md
§Stall-free scheduling): the same bursty workload with a long-prompt
tail is served twice under a chunked prefill budget — run-to-completion
(admission prefill blocks decode until it drains) vs interleaved
(budgeted prefill chunks share every tick with decode). Outputs are
bitwise identical (seeded samplers again); what moves is true TTFT:
decode-bound requests stuck behind a long admission see their first
token ``stall_ticks`` later in run-to-completion mode. The acceptance
bar, asserted here and gated in CI: interleaving improves p99 true
TTFT by >= 1.5x at equal-or-better decode throughput (tokens/tick
within 5%).

Writes results/cluster_bench.{json,md}.

  PYTHONPATH=src python benchmarks/cluster_bench.py
"""
from __future__ import annotations

import argparse
import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

COLUMNS = ("policy", "prefix_hit", "prefill_tok_saved", "ttft_p50",
           "ttft_p95", "e2e_p95", "qwait_p95", "sla", "util_min",
           "util_max", "tokens_out", "tokens_equal")

ICOLUMNS = ("schedule", "ttft_p50", "ttft_p95", "ttft_p99",
            "admit_wait_p95", "e2e_p95", "stall_ticks", "ticks",
            "tok_per_tick", "tokens_out", "tokens_equal")


def _fmt(v, spec: str = "") -> str:
    """Latency percentiles are None when no request produced a first
    token (satellite of the TTFT accounting fix) — render n/a, never
    0.0, so an empty series can't masquerade as a great one."""
    if v is None:
        return "n/a"
    return format(v, spec) if spec else str(v)


def bench(n_replicas: int = 4, n_sessions: int = 32, seed: int = 0,
          max_batch: int = 2, cache_len: int = 192):
    import jax
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving.cluster import ROUTER_POLICIES, EngineCluster
    from repro.serving.workload import (WorkloadConfig, make_workload,
                                        register_workload_prefixes,
                                        skewed_mix, workload_intents)

    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    wcfg = WorkloadConfig(n_sessions=n_sessions, seed=seed,
                          intent_mix=skewed_mix(hot_frac=0.7),
                          profile="poisson", inter_arrival=1.0,
                          max_turns=2, max_new_tokens=4,
                          temperature=0.8, sla_ticks=48)
    requests = make_workload(wcfg)

    # one replica pool, reset between policies: jit-compile once,
    # identical engine state for every router
    pool = EngineCluster(cfg, params, n_replicas, max_batch=max_batch,
                         cache_len=cache_len, seed=seed).replicas
    rows, ref_outputs = [], None
    for policy in ROUTER_POLICIES:
        for e in pool:
            e.reset()
        cluster = EngineCluster(engines=pool, router=policy)
        register_workload_prefixes(cluster, requests)
        t0 = time.time()
        stats = cluster.run_workload(requests)
        wall = time.time() - t0
        s = stats.summary()
        outputs = stats.outputs()
        if ref_outputs is None:
            ref_outputs = outputs
        utils = [r["utilization"] for r in s["per_replica"]]
        rows.append({
            "policy": policy,
            "prefix_hit": s["prefix_hit_ratio"],
            "prefill_tok_saved": sum(r["prefix_tokens_saved"]
                                     for r in s["per_replica"]),
            "ttft_p50": s["ttft_p50"], "ttft_p95": s["ttft_p95"],
            "e2e_p95": s["e2e_p95"], "qwait_p95": s["queue_wait_p95"],
            "sla": s["sla_attainment"],
            "util_min": min(utils), "util_max": max(utils),
            "tokens_out": s["tokens_out"],
            "tokens_equal": outputs == ref_outputs,
            "ticks": s["ticks"], "finished": s["finished"],
            "wall_s": round(wall, 2),
            "per_replica": s["per_replica"],
        })
    by = {r["policy"]: r for r in rows}
    meta = {
        "n_replicas": n_replicas, "max_batch": max_batch,
        "n_sessions": n_sessions, "requests": len(requests),
        "intent_sessions": workload_intents(requests),
        "workload": {"profile": wcfg.profile, "hot_frac": 0.7,
                     "max_turns": wcfg.max_turns,
                     "temperature": wcfg.temperature, "seed": seed},
        "affinity_beats_round_robin": (
            by["intent_affinity"]["prefix_hit"]
            >= by["round_robin"]["prefix_hit"]),
        "tokens_identical_across_policies": all(r["tokens_equal"]
                                                for r in rows),
    }
    return rows, meta


def bench_interleave(n_replicas: int = 2, n_sessions: int = 24,
                     seed: int = 0, max_batch: int = 4,
                     cache_len: int = 320, budget: int = 32,
                     attn_chunk: int = 32):
    """Stall-free scheduling on a bursty long-prompt workload: the SAME
    requests served under the same chunked prefill budget, once
    run-to-completion (decode stalls while any admission prefill is
    pending) and once interleaved (pending prefills and decode share
    every tick). Asserts bitwise token parity, the >= 1.5x p99
    true-TTFT gain and throughput within 5%."""
    import dataclasses

    import jax
    from repro.common.perf import get_flags, set_flags
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving.cluster import EngineCluster
    from repro.serving.workload import (WorkloadConfig, make_workload,
                                        register_workload_prefixes,
                                        skewed_mix)

    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    # bursty arrivals + a long-prompt tail: short decode-bound traffic
    # lands together with ~long_words-token prompts, the workload shape
    # where monolithic admission prefill stalls everyone else's first
    # token. SLAs are generous on purpose: nothing expires, so both
    # schedules serve the identical request set (parity stays bitwise).
    wcfg = WorkloadConfig(n_sessions=n_sessions, seed=seed,
                          intent_mix=skewed_mix(hot_frac=0.7),
                          profile="bursty", burst_size=8,
                          inter_arrival=1.0, max_turns=1,
                          max_new_tokens=12, temperature=0.8,
                          sla_ticks=4096, long_frac=0.25,
                          long_words=224)
    requests = make_workload(wcfg)
    saved = get_flags()
    rows, ref_outputs = [], None
    try:
        # attn_chunk is the prefill chunk grain; the budget admits one
        # whole chunk per tick here, so a ~200-token prompt spreads
        # over ~7 ticks instead of landing as one monolithic prefill
        set_flags(dataclasses.replace(saved, attn_chunk=attn_chunk))
        pool = EngineCluster(cfg, params, n_replicas,
                             max_batch=max_batch, cache_len=cache_len,
                             seed=seed, prefill_budget=budget,
                             admission="slack").replicas
        for schedule, interleave in (("run_to_completion", False),
                                     ("interleaved", True)):
            for e in pool:
                e.reset()
                e.interleave = interleave
            cluster = EngineCluster(engines=pool,
                                    router="intent_affinity")
            register_workload_prefixes(cluster, requests)
            t0 = time.time()
            stats = cluster.run_workload(requests)
            wall = time.time() - t0
            s = stats.summary()
            outputs = stats.outputs()
            if ref_outputs is None:
                ref_outputs = outputs
            rows.append({
                "schedule": schedule,
                "ttft_p50": s["ttft_p50"], "ttft_p95": s["ttft_p95"],
                "ttft_p99": s["ttft_p99"],
                "admit_wait_p95": s["admit_wait_p95"],
                "e2e_p95": s["e2e_p95"],
                "stall_ticks": sum(r["stall_ticks"]
                                   for r in s["per_replica"]),
                "ticks": s["ticks"],
                "tok_per_tick": round(s["tokens_out"]
                                      / max(s["ticks"], 1), 4),
                "tokens_out": s["tokens_out"],
                "tokens_equal": outputs == ref_outputs,
                "finished": s["finished"],
                "sla_expired": s["sla_expired"],
                "wall_s": round(wall, 2),
            })
    finally:
        set_flags(saved)
    by = {r["schedule"]: r for r in rows}
    rtc, il = by["run_to_completion"], by["interleaved"]
    meta = {
        "n_replicas": n_replicas, "max_batch": max_batch,
        "n_sessions": n_sessions, "requests": len(requests),
        "prefill_budget": budget, "attn_chunk": attn_chunk,
        "admission": "slack",
        "workload": {"profile": wcfg.profile,
                     "burst_size": wcfg.burst_size,
                     "long_frac": wcfg.long_frac,
                     "long_words": wcfg.long_words,
                     "temperature": wcfg.temperature, "seed": seed},
        "interleave_ttft_p99_gain": round(
            rtc["ttft_p99"] / il["ttft_p99"], 4),
        "interleave_tokens_identical": all(r["tokens_equal"]
                                           for r in rows),
        "interleave_tps_ratio": round(
            il["tok_per_tick"] / rtc["tok_per_tick"], 4),
    }
    # the acceptance bar (ISSUE 8): interleaving must buy >= 1.5x on
    # p99 true TTFT without giving up decode throughput, on bitwise
    # identical outputs. Hard-assert so the bench itself is the gate.
    assert meta["interleave_tokens_identical"], \
        "interleaving changed generated tokens"
    assert meta["interleave_ttft_p99_gain"] >= 1.5, \
        f"p99 TTFT gain {meta['interleave_ttft_p99_gain']} < 1.5"
    assert meta["interleave_tps_ratio"] >= 0.95, \
        f"tokens/tick ratio {meta['interleave_tps_ratio']} < 0.95"
    return rows, meta


def bench_trace(n_replicas: int = 2, n_sessions: int = 8, seed: int = 0,
                max_batch: int = 2, cache_len: int = 192,
                trace_out: str = ""):
    """Traced tiny cluster bench: one fixed-seed workload served (a)
    under the NullTracer default and (b) twice under a recording
    Tracer. Hard-asserts the observability contracts end-to-end:

      * zero perturbation — tracing changes neither the generated
        tokens nor the tick count, so tokens/tick is EXACTLY equal
        tracer-on vs tracer-off (not within a tolerance);
      * determinism — same seed => byte-identical serialized Chrome
        trace across the two traced runs;
      * schema — the export passes ``validate_chrome_trace``.

    ``trace_out`` additionally writes run (b)'s trace for the CI
    artifact + ``benchmarks/check_trace.py``."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.obs import NULL_TRACER, Tracer
    from repro.obs.export import (chrome_trace, validate_chrome_trace,
                                  write_trace)
    from repro.serving.cluster import EngineCluster
    from repro.serving.workload import (WorkloadConfig, make_workload,
                                        register_workload_prefixes,
                                        skewed_mix)

    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    wcfg = WorkloadConfig(n_sessions=n_sessions, seed=seed,
                          intent_mix=skewed_mix(hot_frac=0.7),
                          profile="poisson", inter_arrival=1.0,
                          max_turns=2, max_new_tokens=4,
                          temperature=0.8)
    requests = make_workload(wcfg)
    pool = EngineCluster(cfg, params, n_replicas, max_batch=max_batch,
                         cache_len=cache_len, seed=seed).replicas

    def serve(tracer):
        for e in pool:
            e.reset()
        cluster = EngineCluster(engines=pool, router="intent_affinity",
                                tracer=tracer)
        register_workload_prefixes(cluster, requests)
        stats = cluster.run_workload(requests)
        return stats.outputs(), stats.summary()

    base_out, base_sum = serve(NULL_TRACER)
    t1 = Tracer()
    out1, sum1 = serve(t1)
    t2 = Tracer()
    out2, _ = serve(t2)
    dumps = lambda t: json.dumps(chrome_trace(t), sort_keys=True,
                                 separators=(",", ":"))
    errors = validate_chrome_trace(chrome_trace(t1))
    meta = {
        "n_replicas": n_replicas, "max_batch": max_batch,
        "requests": len(requests), "seed": seed,
        "trace_records": len(t1.records),
        "ticks": sum1["ticks"],
        "tok_per_tick_untraced": round(
            base_sum["tokens_out"] / max(base_sum["ticks"], 1), 4),
        "tok_per_tick_traced": round(
            sum1["tokens_out"] / max(sum1["ticks"], 1), 4),
        "tokens_equal_tracer_on_off": out1 == base_out,
        "ticks_equal_tracer_on_off": sum1["ticks"] == base_sum["ticks"],
        "trace_byte_identical": dumps(t1) == dumps(t2) and out1 == out2,
        "trace_export_valid": errors == [],
    }
    assert meta["tokens_equal_tracer_on_off"], \
        "tracing changed generated tokens"
    assert meta["ticks_equal_tracer_on_off"], \
        (f"tracing changed tokens/tick: {sum1['ticks']} ticks traced "
         f"vs {base_sum['ticks']} untraced")
    assert meta["trace_byte_identical"], \
        "same-seed traces are not byte-identical"
    assert meta["trace_export_valid"], errors
    if trace_out:
        write_trace(t1, trace_out)
        meta["trace_out"] = os.path.basename(trace_out)
    return meta


def write_results(rows, meta, irows, imeta, tmeta):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    md = ["# cluster_bench — router policies on the intent-affinity "
          "serving cluster", "",
          f"{meta['n_replicas']} replicas x {meta['max_batch']} slots, "
          f"{meta['requests']} requests from {meta['n_sessions']} "
          f"sessions (skewed mix, hot_frac="
          f"{meta['workload']['hot_frac']}, "
          f"profile={meta['workload']['profile']}, seeded samplers at "
          f"T={meta['workload']['temperature']}).", "",
          "| " + " | ".join(COLUMNS) + " |",
          "|" + "---|" * len(COLUMNS)]
    for r in rows:
        md.append("| " + " | ".join(_fmt(r[c]) for c in COLUMNS) + " |")
    md += ["",
           f"- affinity >= round_robin on prefix-hit ratio: "
           f"**{meta['affinity_beats_round_robin']}**",
           f"- identical tokens_out under every policy (seeded "
           f"samplers): **{meta['tokens_identical_across_policies']}**",
           "",
           "Interpretation: `intent_affinity` turns the per-intent "
           "prompt-prefix cache into a cluster-level win — same-intent "
           "traffic rides one replica's cached prefill — at the price "
           "of a hotter home replica (`util_max`) and longer queues "
           "there (`qwait_p95`); the load-aware policies make the "
           "opposite trade. Routing never changes WHAT is generated, "
           "only where and how fast (columns doc in the module "
           "docstring).",
           "",
           "## Stall-free scheduling — chunked prefill interleaved "
           "with decode", "",
           f"{imeta['n_replicas']} replicas x {imeta['max_batch']} "
           f"slots, {imeta['requests']} requests "
           f"(profile={imeta['workload']['profile']}, long_frac="
           f"{imeta['workload']['long_frac']}, long_words="
           f"{imeta['workload']['long_words']}), prefill_budget="
           f"{imeta['prefill_budget']} @ attn_chunk="
           f"{imeta['attn_chunk']}, admission={imeta['admission']}.",
           "",
           "| " + " | ".join(ICOLUMNS) + " |",
           "|" + "---|" * len(ICOLUMNS)]
    for r in irows:
        md.append("| " + " | ".join(_fmt(r[c]) for c in ICOLUMNS)
                  + " |")
    md += ["",
           f"- p99 true-TTFT gain from interleaving: "
           f"**{imeta['interleave_ttft_p99_gain']}x** (bar: >= 1.5x)",
           f"- tokens/tick ratio interleaved/run-to-completion: "
           f"**{imeta['interleave_tps_ratio']}** (bar: >= 0.95)",
           f"- identical tokens under both schedules: "
           f"**{imeta['interleave_tokens_identical']}**",
           "",
           "Interpretation: with run-to-completion admission, every "
           "long prompt freezes its replica's decode for the whole "
           "prefill (`stall_ticks`), so unrelated short requests see "
           "their first token late — the p99 TTFT tail. Interleaving "
           "spends the same chunk budget per tick but keeps decode "
           "running beside it: the tail collapses while throughput "
           "and every generated token stay identical (true TTFT is "
           "first_token_tick - arrival_tick + 1; `admit_wait_p95` is "
           "the old queue-exit proxy, kept for comparison).",
           "",
           "## Request-lifecycle tracing — overhead and determinism",
           "",
           f"{tmeta['n_replicas']} replicas, {tmeta['requests']} "
           f"requests, {tmeta['trace_records']} trace records over "
           f"{tmeta['ticks']} ticks.",
           "",
           f"- tokens/tick traced vs untraced: "
           f"**{tmeta['tok_per_tick_traced']}** vs "
           f"**{tmeta['tok_per_tick_untraced']}** (must be exactly "
           f"equal: tracing never branches control flow)",
           f"- same-seed trace byte-identical: "
           f"**{tmeta['trace_byte_identical']}**",
           f"- Chrome/Perfetto export validates: "
           f"**{tmeta['trace_export_valid']}**"]
    with open(os.path.join(RESULTS_DIR, "cluster_bench.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    with open(os.path.join(RESULTS_DIR, "cluster_bench.json"), "w") as f:
        json.dump({"meta": meta, "rows": rows,
                   "interleave": {"meta": imeta, "rows": irows},
                   "trace": tmeta},
                  f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke config (fewer replicas/sessions)")
    ap.add_argument("--out", default=None,
                    help="write the JSON here instead of results/")
    ap.add_argument("--trace-out", default=None,
                    help="write the traced run's Chrome trace JSON "
                         "here (validated by benchmarks/check_trace.py;"
                         " CI uploads it as an artifact)")
    args = ap.parse_args(argv)
    rows, meta = (bench(n_replicas=2, n_sessions=8, max_batch=2,
                        cache_len=128) if args.tiny else bench())
    irows, imeta = (bench_interleave(n_sessions=16)
                    if args.tiny else bench_interleave())
    tmeta = bench_trace(trace_out=args.trace_out or "")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"meta": meta, "rows": rows,
                       "interleave": {"meta": imeta, "rows": irows},
                       "trace": tmeta},
                      f, indent=1)
    elif not args.tiny:
        write_results(rows, meta, irows, imeta, tmeta)
    for r in rows:
        print(f"{r['policy']:16s} hit={r['prefix_hit']:.3f} "
              f"ttft_p95={_fmt(r['ttft_p95'], '.0f')} qwait_p95="
              f"{_fmt(r['qwait_p95'], '.0f')} util={r['util_min']:.2f}.."
              f"{r['util_max']:.2f} tokens={r['tokens_out']} "
              f"equal={r['tokens_equal']}")
    print(f"affinity_beats_round_robin={meta['affinity_beats_round_robin']}"
          f" tokens_identical={meta['tokens_identical_across_policies']}")
    for r in irows:
        print(f"{r['schedule']:18s} "
              f"ttft_p50={_fmt(r['ttft_p50'], '.0f')} "
              f"ttft_p99={_fmt(r['ttft_p99'], '.0f')} "
              f"stalls={r['stall_ticks']} ticks={r['ticks']} "
              f"tok/tick={r['tok_per_tick']:.2f} "
              f"tokens={r['tokens_out']} equal={r['tokens_equal']}")
    print(f"interleave_ttft_p99_gain={imeta['interleave_ttft_p99_gain']}"
          f" tps_ratio={imeta['interleave_tps_ratio']}"
          f" tokens_identical={imeta['interleave_tokens_identical']}")
    print(f"trace: {tmeta['trace_records']} records, tok/tick "
          f"{tmeta['tok_per_tick_traced']} traced vs "
          f"{tmeta['tok_per_tick_untraced']} untraced, "
          f"byte_identical={tmeta['trace_byte_identical']} "
          f"export_valid={tmeta['trace_export_valid']}")
    return rows, meta, irows, imeta, tmeta


if __name__ == "__main__":
    main()
