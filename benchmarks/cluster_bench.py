"""Cluster router-policy benchmark: intent affinity vs oblivious routing.

Serves ONE seeded synthetic workload (serving/workload.py — skewed
intent mix, seeded per-request samplers, multi-turn sessions) through
the same N-replica ``EngineCluster`` under each routing policy, and
tabulates what the router changes and what it must not change.

Because every request carries a sampler seed, its output tokens are a
pure function of the workload — NOT of placement — so ``tokens_out``
must be identical across policies (the table's ``tokens_equal`` column
asserts it against round_robin). What the router *does* move:

  policy            round_robin | least_loaded | intent_affinity;
  prefix_hit        cluster prefix-hit ratio (hits / admissions). The
                    affinity router sends same-intent traffic to the
                    replica holding that intent's cached prefix prefill,
                    so this is the headline column: affinity >=
                    round_robin is the acceptance bar;
  prefill_tok_saved prompt tokens not recomputed thanks to those hits;
  ttft_p50/p95      ticks from arrival to first token (one tick = one
                    cluster-wide continuous-batching step);
  e2e_p95           ticks from arrival to completion;
  qwait_p95         ticks spent queued before a slot freed up;
  sla               fraction of requests finishing within their
                    per-request deadline;
  util_min/max      per-replica slot utilization spread — affinity
                    concentrates the hot intent on its home replica
                    (high max, low min), the load-aware policies
                    flatten it: the cache-locality vs load-balance
                    trade the router picks;
  tokens_out        total generated tokens (identical by construction).

Writes results/cluster_bench.{json,md}.

  PYTHONPATH=src python benchmarks/cluster_bench.py
"""
from __future__ import annotations

import argparse
import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

COLUMNS = ("policy", "prefix_hit", "prefill_tok_saved", "ttft_p50",
           "ttft_p95", "e2e_p95", "qwait_p95", "sla", "util_min",
           "util_max", "tokens_out", "tokens_equal")


def bench(n_replicas: int = 4, n_sessions: int = 32, seed: int = 0,
          max_batch: int = 2, cache_len: int = 192):
    import jax
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving.cluster import ROUTER_POLICIES, EngineCluster
    from repro.serving.workload import (WorkloadConfig, make_workload,
                                        register_workload_prefixes,
                                        skewed_mix, workload_intents)

    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    wcfg = WorkloadConfig(n_sessions=n_sessions, seed=seed,
                          intent_mix=skewed_mix(hot_frac=0.7),
                          profile="poisson", inter_arrival=1.0,
                          max_turns=2, max_new_tokens=4,
                          temperature=0.8, sla_ticks=48)
    requests = make_workload(wcfg)

    # one replica pool, reset between policies: jit-compile once,
    # identical engine state for every router
    pool = EngineCluster(cfg, params, n_replicas, max_batch=max_batch,
                         cache_len=cache_len, seed=seed).replicas
    rows, ref_outputs = [], None
    for policy in ROUTER_POLICIES:
        for e in pool:
            e.reset()
        cluster = EngineCluster(engines=pool, router=policy)
        register_workload_prefixes(cluster, requests)
        t0 = time.time()
        stats = cluster.run_workload(requests)
        wall = time.time() - t0
        s = stats.summary()
        outputs = stats.outputs()
        if ref_outputs is None:
            ref_outputs = outputs
        utils = [r["utilization"] for r in s["per_replica"]]
        rows.append({
            "policy": policy,
            "prefix_hit": s["prefix_hit_ratio"],
            "prefill_tok_saved": sum(r["prefix_tokens_saved"]
                                     for r in s["per_replica"]),
            "ttft_p50": s["ttft_p50"], "ttft_p95": s["ttft_p95"],
            "e2e_p95": s["e2e_p95"], "qwait_p95": s["queue_wait_p95"],
            "sla": s["sla_attainment"],
            "util_min": min(utils), "util_max": max(utils),
            "tokens_out": s["tokens_out"],
            "tokens_equal": outputs == ref_outputs,
            "ticks": s["ticks"], "finished": s["finished"],
            "wall_s": round(wall, 2),
            "per_replica": s["per_replica"],
        })
    by = {r["policy"]: r for r in rows}
    meta = {
        "n_replicas": n_replicas, "max_batch": max_batch,
        "n_sessions": n_sessions, "requests": len(requests),
        "intent_sessions": workload_intents(requests),
        "workload": {"profile": wcfg.profile, "hot_frac": 0.7,
                     "max_turns": wcfg.max_turns,
                     "temperature": wcfg.temperature, "seed": seed},
        "affinity_beats_round_robin": (
            by["intent_affinity"]["prefix_hit"]
            >= by["round_robin"]["prefix_hit"]),
        "tokens_identical_across_policies": all(r["tokens_equal"]
                                                for r in rows),
    }
    return rows, meta


def write_results(rows, meta):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    md = ["# cluster_bench — router policies on the intent-affinity "
          "serving cluster", "",
          f"{meta['n_replicas']} replicas x {meta['max_batch']} slots, "
          f"{meta['requests']} requests from {meta['n_sessions']} "
          f"sessions (skewed mix, hot_frac="
          f"{meta['workload']['hot_frac']}, "
          f"profile={meta['workload']['profile']}, seeded samplers at "
          f"T={meta['workload']['temperature']}).", "",
          "| " + " | ".join(COLUMNS) + " |",
          "|" + "---|" * len(COLUMNS)]
    for r in rows:
        md.append("| " + " | ".join(str(r[c]) for c in COLUMNS) + " |")
    md += ["",
           f"- affinity >= round_robin on prefix-hit ratio: "
           f"**{meta['affinity_beats_round_robin']}**",
           f"- identical tokens_out under every policy (seeded "
           f"samplers): **{meta['tokens_identical_across_policies']}**",
           "",
           "Interpretation: `intent_affinity` turns the per-intent "
           "prompt-prefix cache into a cluster-level win — same-intent "
           "traffic rides one replica's cached prefill — at the price "
           "of a hotter home replica (`util_max`) and longer queues "
           "there (`qwait_p95`); the load-aware policies make the "
           "opposite trade. Routing never changes WHAT is generated, "
           "only where and how fast (columns doc in the module "
           "docstring)."]
    with open(os.path.join(RESULTS_DIR, "cluster_bench.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    with open(os.path.join(RESULTS_DIR, "cluster_bench.json"), "w") as f:
        json.dump({"meta": meta, "rows": rows}, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke config (fewer replicas/sessions)")
    ap.add_argument("--out", default=None,
                    help="write the JSON here instead of results/")
    args = ap.parse_args(argv)
    rows, meta = (bench(n_replicas=2, n_sessions=8, max_batch=2,
                        cache_len=128) if args.tiny else bench())
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"meta": meta, "rows": rows}, f, indent=1)
    elif not args.tiny:
        write_results(rows, meta)
    for r in rows:
        print(f"{r['policy']:16s} hit={r['prefix_hit']:.3f} "
              f"ttft_p95={r['ttft_p95']:.0f} qwait_p95="
              f"{r['qwait_p95']:.0f} util={r['util_min']:.2f}.."
              f"{r['util_max']:.2f} tokens={r['tokens_out']} "
              f"equal={r['tokens_equal']}")
    print(f"affinity_beats_round_robin={meta['affinity_beats_round_robin']}"
          f" tokens_identical={meta['tokens_identical_across_policies']}")
    return rows, meta


if __name__ == "__main__":
    main()
