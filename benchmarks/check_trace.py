"""CI gate for the traced cluster bench: validate the Chrome trace
artifact (schema + lifecycle coverage) and, optionally, the bench's
``trace`` meta block (determinism/overhead assertions re-checked from
the JSON the bench wrote, so a silently-skipped assertion still fails
the job).

  PYTHONPATH=src python benchmarks/check_trace.py TRACE_JSON [BENCH_JSON]

Exit 0 = valid; every problem is printed to stderr.
"""
from __future__ import annotations

import json
import sys

# the bench meta flags that must all be True (cluster_bench.bench_trace
# hard-asserts them; re-checking here catches a stale/foreign JSON)
META_FLAGS = ("tokens_equal_tracer_on_off", "ticks_equal_tracer_on_off",
              "trace_byte_identical", "trace_export_valid")

# lifecycle events any served workload must have emitted
REQUIRED_EVENTS = ("enqueue", "admit", "first_token")


def check_trace(trace_path: str, bench_path: str = "") -> list:
    from repro.obs.export import load_and_validate
    doc, errors = load_and_validate(trace_path)
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else []
    real = [e for e in events if isinstance(e, dict)
            and e.get("ph") != "M"]
    if not real:
        errors.append("trace has no events beyond metadata")
    names = {e.get("name") for e in real}
    for required in REQUIRED_EVENTS:
        if required not in names:
            errors.append(f"lifecycle event {required!r} missing "
                          f"from the trace")
    spans = [e for e in real if e.get("ph") == "B"]
    if not spans:
        errors.append("trace has no request spans (no B records)")
    if bench_path:
        with open(bench_path) as f:
            meta = json.load(f).get("trace")
        if not isinstance(meta, dict):
            errors.append(f"{bench_path} has no 'trace' meta block")
        else:
            for key in META_FLAGS:
                if meta.get(key) is not True:
                    errors.append(f"bench trace meta {key} is "
                                  f"{meta.get(key)!r}, expected true")
            if meta.get("trace_records", 0) != len(real):
                errors.append(
                    f"record count drifted: bench meta says "
                    f"{meta.get('trace_records')}, trace file has "
                    f"{len(real)}")
    return errors


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if not 1 <= len(argv) <= 2:
        print("usage: check_trace.py TRACE_JSON [BENCH_JSON]",
              file=sys.stderr)
        return 2
    errors = check_trace(*argv)
    for e in errors:
        print(f"check_trace: {e}", file=sys.stderr)
    if errors:
        return 1
    print(f"check_trace: OK ({argv[0]})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
