"""Paged-vs-dense KV-cache benchmark: concurrency and memory at a fixed
KV budget.

The dense engine reserves a full ``cache_len`` slab per slot and copies
the shared intent prefix into every admission; the paged engine
(serving/kvpool.py) spends the SAME physical row budget as refcounted
blocks, CoW-shares the pinned prefix across every slot and admits by
free blocks, not by worst-case preallocation. This bench quantifies the
trade on one hot-intent workload (every session = shared prefix + a
private suffix, seeded samplers at T=0.8):

  concurrency@budget  dense and paged at the SAME physical KV rows;
                      paged gets 4x the slots and sustains them because
                      sessions only own their suffix/decode blocks —
                      ``peak_concurrent`` is the headline column;
  memory@slots        dense and paged at the SAME slot count; paged
                      peak KV bytes drop by the shared-prefix factor
                      (``kv_bytes_peak``, ``shared_peak`` blocks);
  tokens/step         decode throughput per engine iteration (one step
                      decodes every busy slot — more concurrent
                      sessions at equal memory = more tokens per step);
  tokens_equal        dense and paged produce bitwise-identical tokens
                      (per-request sampler seeds make outputs placement-
                      independent, so this holds across slot counts —
                      the engine parity contract, DESIGN.md §Paged KV
                      cache).

Writes results/paging_bench.{json,md}.

  PYTHONPATH=src python benchmarks/paging_bench.py [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

COLUMNS = ("scenario", "mode", "slots", "kv_rows", "peak_concurrent",
           "ticks", "tokens_out", "tokens_per_step", "kv_bytes_peak",
           "shared_peak", "preemptions", "tokens_equal")


def _drive(eng, prompts, prefix_key, max_new):
    """Serve the request list to completion; returns (outputs keyed by
    submission index, row fragment)."""
    from repro.serving.sampling import SamplerConfig
    rid_to_idx = {}
    for i, ids in enumerate(prompts):
        rid = eng.add_request(ids, max_new_tokens=max_new,
                              sampler=SamplerConfig(temperature=0.8,
                                                    top_k=40,
                                                    seed=10_000 + i),
                              prefix_key=prefix_key)
        rid_to_idx[rid] = i
    done, peak, ticks = [], 0, 0
    t0 = time.time()
    while not eng.is_idle() and ticks < 100_000:
        done.extend(eng.step())
        peak = max(peak, eng.busy_slots())
        ticks += 1
    wall = time.time() - t0
    st = eng.throughput_stats()
    outputs = {rid_to_idx[r.request_id]: tuple(r.output) for r in done}
    return outputs, {
        "slots": eng.max_batch,
        "peak_concurrent": peak,
        "ticks": ticks,
        "tokens_out": sum(len(o) for o in outputs.values()),
        "tokens_per_step": round(st["tokens_generated"]
                                 / max(st["decode_steps"], 1), 2),
        "kv_bytes_peak": st["kv_bytes_peak"],
        "kv_bytes_allocated": st["kv_bytes_allocated"],
        "shared_peak": st["kv_blocks_shared_peak"],
        "preemptions": st["preemptions"],
        "prefix_hits": st["prefix_hits"],
        "wall_s": round(wall, 2),
    }


def bench(tiny: bool = False):
    import jax
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving.engine import InferenceEngine

    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)

    if tiny:
        cache_len, bs, dense_slots, paged_slots = 128, 16, 2, 6
        n_sessions, prefix_len, suffix_len, max_new = 6, 40, 6, 4
    else:
        cache_len, bs, dense_slots, paged_slots = 256, 16, 4, 16
        n_sessions, prefix_len, suffix_len, max_new = 24, 100, 8, 8
    kv_rows = dense_slots * cache_len          # the shared budget
    kv_blocks = kv_rows // bs

    prefix = list(range(5, 5 + prefix_len))
    prompts = [prefix + list(range(200 + suffix_len * i,
                                   200 + suffix_len * (i + 1)))
               for i in range(n_sessions)]
    key = "intent:hot"

    def engine(mode, slots, blocks=None):
        kw = ({"kv_blocks": blocks, "block_size": bs}
              if mode == "paged" else {})
        eng = InferenceEngine(cfg, params, max_batch=slots,
                              cache_len=cache_len, kv_mode=mode, **kw)
        eng.register_prefix(key, prefix)
        return eng

    rows, ref_outputs = [], None

    def run(scenario, mode, slots, blocks=None):
        nonlocal ref_outputs
        outputs, frag = _drive(engine(mode, slots, blocks), prompts,
                               key, max_new)
        if ref_outputs is None:
            ref_outputs = outputs
        rows.append({"scenario": scenario, "mode": mode,
                     "kv_rows": (blocks * bs if blocks else
                                 slots * cache_len),
                     "tokens_equal": outputs == ref_outputs, **frag})

    # same physical KV rows; paged converts them into 4x the slots
    run("concurrency@budget", "dense", dense_slots)
    run("concurrency@budget", "paged", paged_slots, kv_blocks)
    # same slot count; paged shrinks the peak footprint
    run("memory@slots", "dense", dense_slots)
    run("memory@slots", "paged", dense_slots,
        dense_slots * cache_len // bs)

    by = {(r["scenario"], r["mode"]): r for r in rows}
    ca_d = by[("concurrency@budget", "dense")]
    ca_p = by[("concurrency@budget", "paged")]
    ms_d = by[("memory@slots", "dense")]
    ms_p = by[("memory@slots", "paged")]
    meta = {
        "tiny": tiny, "cache_len": cache_len, "block_size": bs,
        "kv_budget_rows": kv_rows, "n_sessions": n_sessions,
        "prefix_len": prefix_len, "suffix_len": suffix_len,
        "max_new_tokens": max_new, "temperature": 0.8,
        "paged_more_concurrent": (ca_p["peak_concurrent"]
                                  > ca_d["peak_concurrent"]),
        "paged_memory_savings": round(
            1 - ms_p["kv_bytes_peak"] / max(ms_d["kv_bytes_peak"], 1),
            4),
        "tokens_identical": all(r["tokens_equal"] for r in rows),
    }
    if not meta["tokens_identical"]:
        raise AssertionError(
            "dense and paged engines diverged on the same workload — "
            "the paged KV cache broke the bitwise parity contract")
    return rows, meta


def write_results(rows, meta):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    md = ["# paging_bench — paged vs dense KV cache at a fixed budget",
          "",
          f"{meta['n_sessions']} one-intent sessions (prefix "
          f"{meta['prefix_len']} tok + suffix {meta['suffix_len']} tok, "
          f"{meta['max_new_tokens']} new tokens each, seeded samplers "
          f"at T={meta['temperature']}); budget "
          f"{meta['kv_budget_rows']} KV rows, block_size="
          f"{meta['block_size']}.", "",
          "| " + " | ".join(COLUMNS) + " |",
          "|" + "---|" * len(COLUMNS)]
    for r in rows:
        md.append("| " + " | ".join(str(r[c]) for c in COLUMNS) + " |")
    md += ["",
           f"- paged sustains more concurrent sessions at the same KV "
           f"budget: **{meta['paged_more_concurrent']}**",
           f"- paged peak-memory savings at equal slots: "
           f"**{100 * meta['paged_memory_savings']:.1f}%**",
           f"- bitwise-identical tokens in every run: "
           f"**{meta['tokens_identical']}**",
           "",
           "Interpretation: at the same physical budget the dense "
           "engine is slot-bound (every admission reserves a full "
           "`cache_len` slab and copies the prefix into it) while the "
           "paged engine CoW-shares the pinned prefix blocks and only "
           "owns each session's suffix/decode blocks — so the same "
           "rows serve several times the concurrency (`tokens/step` "
           "scales with it), and at equal slots the peak footprint "
           "drops by the shared-prefix factor. Identical tokens "
           "throughout: paging moves memory, never logits."]
    with open(os.path.join(RESULTS_DIR, "paging_bench.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    with open(os.path.join(RESULTS_DIR, "paging_bench.json"), "w") as f:
        json.dump({"meta": meta, "rows": rows}, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke config (small pool, few sessions); "
                         "skips writing results/")
    ap.add_argument("--out", default=None,
                    help="write the JSON here instead of results/ "
                         "(used by the CI bench-regression gate)")
    args = ap.parse_args(argv)
    rows, meta = bench(tiny=args.tiny)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"meta": meta, "rows": rows}, f, indent=1)
    elif not args.tiny:
        write_results(rows, meta)
    for r in rows:
        print(f"{r['scenario']:19s} {r['mode']:5s} slots={r['slots']:2d} "
              f"rows={r['kv_rows']:5d} peak_conc={r['peak_concurrent']:2d} "
              f"tok/step={r['tokens_per_step']:5.2f} "
              f"peakB={r['kv_bytes_peak']:8d} shared={r['shared_peak']:3d} "
              f"preempt={r['preemptions']} equal={r['tokens_equal']}")
    print(f"paged_more_concurrent={meta['paged_more_concurrent']} "
          f"memory_savings={meta['paged_memory_savings']:.2%} "
          f"tokens_identical={meta['tokens_identical']}")
    return rows, meta


if __name__ == "__main__":
    main()
