"""Tool-retrieval benchmark: prompt-token savings from exposing a
retrieved top-k toolset instead of the full catalog, at catalog sizes
8 → 512, with task outcomes asserted bitwise identical.

The retrieval layer (core/catalog.py + core/retriever.py) scales the
registry to hundreds of tools and serializes only the per-query
retrieved toolset into the planner prompt; the gate still decides the
behaviour model's ``visible`` toolset, so the planner's decision stream
— and therefore every task outcome — cannot change (DESIGN.md §Tool
retrieval). The bench measures the two things retrieval is allowed to
move and the one thing it must not:

  1. tokens: total tokens per task, retrieved vs all-tools-exposed, at
     each catalog size (the miss-and-widen escalations are charged to
     the retrieved cell, so the savings number is honest);
  2. recall@k: how much of each task's actually-executed toolset was in
     the initially retrieved top-k (misses are what widening pays for);
  3. outcomes: executed tool sequence, completion, steps, fallbacks and
     the workspace rng state must be BITWISE IDENTICAL per task across
     the two cells — asserted, and CI-gated via check_regression.py
     ``SPECS["retrieval"]``.

Writes results/retrieval_bench.{json,md}.

  PYTHONPATH=src python benchmarks/retrieval_bench.py [--tiny] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

SIZES = (8, 32, 128, 512)

COLUMNS = ("n_tools", "exposure", "correct", "success", "det_f1",
           "lcc_r", "rouge_l", "tokens_per_task", "widens_per_task",
           "recall_at_k")

QUALITY = ("correct", "success", "det_f1", "lcc_r", "rouge_l")


def _outcome_fingerprint(r):
    """Everything a task outcome is: tool stream, completion, step and
    fallback structure, and the workspace's terminal state including
    its rng stream position."""
    ws = r.workspace
    return (tuple(r.executed_tools), r.completed_plan, r.fallback_used,
            r.intent_predicted, r.steps, tuple(ws.handles),
            ws.last_answer, str(ws.rng.bit_generator.state))


def _cell(world, tasks, registry, imap, intent_libs, exposure, seed, k):
    """Run one (catalog size × exposure mode) cell sequentially."""
    import numpy as np
    from repro.core.agent import Agent
    from repro.core.gate import IntentGate, ScriptedIntentClassifier
    from repro.core.planner import PlannerConfig
    from repro.core.retriever import ToolRetriever
    from repro.env.evaluator import evaluate_results

    gate = IntentGate(imap,
                      ScriptedIntentClassifier(
                          0.97, np.random.default_rng(seed)),
                      registry.libraries())
    retriever = (ToolRetriever(registry, intent_libs, k=k)
                 if exposure == "retrieved" else None)
    agent = Agent(registry, world,
                  PlannerConfig(mode="react", few_shot=False),
                  gate=gate, seed=seed, retriever=retriever,
                  exposure=exposure)
    results = [agent.run_task(t, task_seed=i)
               for i, t in enumerate(tasks)]
    rep = evaluate_results(results, f"{exposure}-{len(registry.tools)}")
    n = max(len(results), 1)
    recalls = []
    for r in results:
        used = {t for t in r.executed_tools}
        if r.toolset is None or not used:
            recalls.append(1.0)
        else:
            exposed = set(r.toolset)
            recalls.append(len(used & exposed) / len(used))
    row = {
        "n_tools": len(registry.tools),
        "exposure": exposure,
        "correct": round(rep.correct_rate, 6),
        "success": round(rep.success_rate, 6),
        "det_f1": round(rep.det_f1, 6),
        "lcc_r": round(rep.lcc_r, 6),
        "rouge_l": round(rep.vqa_rouge_l, 6),
        "tokens_per_task": round(rep.tokens_per_task, 3),
        "widens_per_task": round(sum(r.widens for r in results) / n, 4),
        "recall_at_k": round(sum(recalls) / n, 4),
    }
    return row, results


def bench(tiny: bool = False, k: int = 16):
    from repro.core.catalog import (build_catalog,
                                    catalog_intent_libraries,
                                    catalog_intent_map)
    from repro.env.tasks import make_benchmark
    from repro.env.world import build_world

    seed = 0
    n_tasks = 12 if tiny else 64
    world = build_world(seed)
    tasks = make_benchmark(world, n_tasks, seed=seed)

    rows = []
    savings = {}
    recalls = {}
    outcomes_identical = True
    quality_identical = True
    for n in SIZES:
        registry = build_catalog(n, seed=0)
        imap = catalog_intent_map(registry)
        intent_libs = catalog_intent_libraries(registry)
        row_all, res_all = _cell(world, tasks, registry, imap,
                                 intent_libs, "all", seed, k)
        row_ret, res_ret = _cell(world, tasks, registry, imap,
                                 intent_libs, "retrieved", seed, k)
        rows += [row_all, row_ret]
        for a, b in zip(res_all, res_ret):
            if _outcome_fingerprint(a) != _outcome_fingerprint(b):
                outcomes_identical = False
        if any(row_all[q] != row_ret[q] for q in QUALITY):
            quality_identical = False
        savings[n] = round(
            1 - row_ret["tokens_per_task"]
            / max(row_all["tokens_per_task"], 1e-9), 4)
        recalls[n] = row_ret["recall_at_k"]

    meta = {
        "tiny": tiny, "n_tasks": n_tasks, "sizes": list(SIZES),
        "retriever_k": k,
        "token_savings": {str(n): savings[n] for n in SIZES},
        "token_savings_512": savings[512],
        "recall_at_k": round(sum(recalls.values()) / len(recalls), 4),
        "outcomes_identical": outcomes_identical,
        "quality_identical": quality_identical,
    }
    if not outcomes_identical:
        raise AssertionError(
            "retrieved-toolset exposure changed a task outcome — "
            "retrieval may only narrow the serialized catalog, never "
            "the behaviour model's visible toolset")
    if not quality_identical:
        raise AssertionError(
            "quality metrics moved between all-tools and retrieved "
            "exposure — they must be identical by construction")
    if savings[512] <= 0.15:
        raise AssertionError(
            f"token savings at 512 tools is {savings[512]} <= 0.15 — "
            f"retrieval is not paying for its widening overhead at the "
            f"catalog scale it exists for")
    return rows, meta


def write_results(rows, meta, path=None):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    md = ["# retrieval_bench — retrieved-toolset prompt exposure",
          "",
          f"{meta['n_tasks']} tasks, react zero-shot, gate accuracy "
          f"0.97, retriever k={meta['retriever_k']}; each catalog size "
          f"compares all-tools-exposed vs the retrieved top-k toolset, "
          f"with miss-and-widen escalations charged to the retrieved "
          f"cell.", "",
          "| " + " | ".join(COLUMNS) + " |",
          "|" + "---|" * len(COLUMNS)]
    for r in rows:
        md.append("| " + " | ".join(str(r[c]) for c in COLUMNS) + " |")
    md += ["",
           "- token savings by catalog size: "
           + ", ".join(f"{n}: **{100 * meta['token_savings'][str(n)]:.1f}%**"
                       for n in meta["sizes"]),
           f"- mean recall@k of the initially exposed toolset: "
           f"**{meta['recall_at_k']}**",
           f"- task outcomes bitwise identical across exposure modes: "
           f"**{meta['outcomes_identical']}** (quality identical: "
           f"{meta['quality_identical']})",
           "",
           "Interpretation: the serialized catalog dominates prompt "
           "tokens as the registry grows; retrieval caps it at k tool "
           "schemas per request. Savings are ~0 at 8 tools (k covers "
           "the catalog — retrieval is a no-op by design) and grow "
           "with catalog size. The planner's decision stream reads the "
           "gated visible toolset, not the serialized text, so the "
           "retrieved cell replays the all-tools cell bitwise; misses "
           "only cost widen re-serializations, which the savings "
           "numbers already include."]
    with open(os.path.join(RESULTS_DIR, "retrieval_bench.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    out_json = path or os.path.join(RESULTS_DIR, "retrieval_bench.json")
    with open(out_json, "w") as f:
        json.dump({"meta": meta, "rows": rows}, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke config (12 tasks)")
    ap.add_argument("--retriever-k", type=int, default=16,
                    help="retrieved toolset size (top-k)")
    ap.add_argument("--out", default=None,
                    help="write the JSON here instead of results/ "
                         "(markdown is skipped); used by the CI "
                         "bench-regression gate")
    args = ap.parse_args()
    rows, meta = bench(tiny=args.tiny, k=args.retriever_k)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"meta": meta, "rows": rows}, f, indent=1)
    elif not args.tiny:
        write_results(rows, meta)
    for r in rows:
        print(f"n={r['n_tools']:3d} {r['exposure']:9s} "
              f"tok/task={r['tokens_per_task']:9.1f} "
              f"widens/task={r['widens_per_task']:6.3f} "
              f"recall={r['recall_at_k']:.4f} "
              f"success={r['success']:.4f}")
    print(f"token_savings_512={meta['token_savings_512']} "
          f"recall_at_k={meta['recall_at_k']} "
          f"outcomes_identical={meta['outcomes_identical']}")
    return rows, meta


if __name__ == "__main__":
    main()
