"""Paper Table 2 reproduction: {CoT, ReAct} × {zero, few}-shot × ±GeckOpt
on the synthetic GeoLLM-Engine benchmark.

Since the batched-pipeline refactor the harness drives ``concurrency``
sessions through serving.pipeline.GeckOptPipeline (admission waves are
gated in one batched classifier call; planning interleaves round-robin)
instead of looping tasks one at a time — matching the paper's parallel
Copilot-platform setting. Per-session state is isolated, so the numbers
are bit-identical to the old sequential loop at the same seed
(tests/test_pipeline.py holds the pipeline to that).

Reported columns (results/table2.md), one row per baseline ± GeckOpt:

  Correct↑      % of tasks whose primary outcome is right (answer or
                artifact) — paper "Correct. Rate";
  Success↑      % with the full plan completed AND every required side
                effect present — paper "Success Rate";
  DetF1↑        micro-F1 of object detections vs world ground truth,
                pooled over detection tasks — paper "Obj. Det F1";
  LCC R↑        Pearson r of predicted vs true land-cover fractions —
                paper "LCC R";
  RougeL↑       Rouge-L F between agent answer and reference on VQA
                tasks — paper "VQA Rouge-L";
  Tokens/Task↓  mean ledger tokens (prompt+completion, gate included) —
                the paper's cost metric; the *paper:* rows give the
                paper's k-token figures and % reduction next to ours;
  steps         mean planner LLM requests per task;
  tools/step    mean executed tool calls per planner step — rises under
                gating (the paper's aggregation observation).

Writes results/table2.md + results/table2.json.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier
from repro.core.intents import build_intent_map
from repro.core.planner import PlannerConfig
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.evaluator import evaluate
from repro.env.tasks import make_benchmark
from repro.env.world import build_world
from repro.serving.pipeline import evaluate_pipeline

PAPER = {  # GPT-4 Turbo (0125) numbers from the paper's Table 2
    "cot_zero_shot":   dict(C=80.88, S=77.35, F1=87.99, R=96.56, RL=65.29,
                            tok=23.6, gtok=18.48),
    "cot_few_shot":    dict(C=84.01, S=80.00, F1=88.40, R=99.89, RL=67.65,
                            tok=25.8, gtok=19.45),
    "react_zero_shot": dict(C=84.27, S=80.03, F1=89.34, R=98.83, RL=68.11,
                            tok=26.7, gtok=20.38),
    "react_few_shot":  dict(C=84.31, S=81.11, F1=83.85, R=99.63, RL=69.37,
                            tok=32.5, gtok=25.14),
}

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def run(n_tasks: int = 400, seed: int = 0, gate_accuracy: float = 0.97,
        classifier=None, tag: str = "table2", concurrency: int = 16,
        compile_plans: bool = False):
    """Evaluate all 8 (mode × shot × ±gate) cells.

    ``concurrency`` > 1 drives each cell through the concurrent pipeline
    (N sessions in flight, wave-batched gating); 1 falls back to the
    sequential loop. Both produce identical metrics at the same seed.

    ``compile_plans`` turns on the tool-graph compiler: quality columns
    are invariant (tests/test_geckopt.py asserts it), only steps and
    Tokens/Task move — benchmarks/toolgraph_bench.py measures the delta.
    """
    world = build_world(seed)
    tasks = make_benchmark(world, n_tasks, seed=seed)
    imap = build_intent_map(tasks, DEFAULT_REGISTRY)
    cls = classifier or ScriptedIntentClassifier(
        gate_accuracy, np.random.default_rng(seed))
    gate = IntentGate(imap, cls, DEFAULT_REGISTRY.libraries())

    def _eval(agent, label):
        if concurrency > 1:
            return evaluate_pipeline(agent, tasks, label,
                                     max_concurrent=concurrency)
        return evaluate(agent, tasks, label)

    rows = []
    for mode in ("cot", "react"):
        for fs in (False, True):
            cfg = PlannerConfig(mode=mode, few_shot=fs,
                                compile_plans=compile_plans)
            base = _eval(Agent(DEFAULT_REGISTRY, world, cfg, gate=None,
                               seed=seed), cfg.name)
            gk = _eval(Agent(DEFAULT_REGISTRY, world, cfg, gate=gate,
                             seed=seed), cfg.name + "+GeckOpt")
            rows.append((cfg.name, base, gk))

    os.makedirs(RESULTS_DIR, exist_ok=True)
    md = ["| baseline | Correct↑ | Success↑ | DetF1↑ | LCC R↑ | RougeL↑ | "
          "Tokens/Task↓ | steps | tools/step |",
          "|---|---|---|---|---|---|---|---|---|"]
    out = {}
    for name, base, gk in rows:
        p = PAPER[name]
        for label, r in ((name, base), (name + " +GeckOpt", gk)):
            md.append(
                f"| {label} | {100*r.correct_rate:.2f} | "
                f"{100*r.success_rate:.2f} | {100*r.det_f1:.2f} | "
                f"{100*r.lcc_r:.2f} | {100*r.vqa_rouge_l:.2f} | "
                f"{r.tokens_per_task/1000:.2f}k | {r.steps_per_task:.2f} | "
                f"{r.tools_per_step:.2f} |")
        red = 1 - gk.tokens_per_task / base.tokens_per_task
        pred = 1 - p["gtok"] / p["tok"]
        md.append(f"| *paper: {p['tok']}k → {p['gtok']}k "
                  f"({100*pred:.1f}% red.); ours {100*red:.1f}% red.* "
                  f"| | | | | | | | |")
        out[name] = {"base": base.row(), "geckopt": gk.row(),
                     "token_reduction_pct": round(100 * red, 2),
                     "paper_reduction_pct": round(100 * pred, 2),
                     "success_delta_pct": round(
                         100 * (gk.success_rate - base.success_rate), 2),
                     "fallback_rate_pct": round(100 * gk.fallback_rate, 2)}
    with open(os.path.join(RESULTS_DIR, f"{tag}.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    with open(os.path.join(RESULTS_DIR, f"{tag}.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main(n_tasks: int = 400):
    out = run(n_tasks)
    for name, rec in out.items():
        print(f"{name}: tokens -{rec['token_reduction_pct']}% "
              f"(paper -{rec['paper_reduction_pct']}%), "
              f"success delta {rec['success_delta_pct']}pp, "
              f"fallback {rec['fallback_rate_pct']}%")
    return out


if __name__ == "__main__":
    main()
