"""Kernel backend benchmark: reference-vs-pallas parity + throughput at
the serving shapes InferenceEngine actually runs.

Two sections, written to results/kernel_bench.md / .json:

**kernels** — per-op micro-bench. For each kernel (prefill attention,
chunked-prefill extend, continuous-batching decode, MoE router top-k,
selective-SSM scan, mLSTM scan) at engine bucket shapes, run the
jnp reference and the Pallas kernel and report:

  op, shape       operation and its (batch, heads, seq, ...) shape;
  ref_s           wall seconds, jnp reference path (jit-warm);
  pallas_s        wall seconds, Pallas kernel (jit-warm);
  max_abs_err     max |pallas - ref| over the outputs;
  parity          err < 2e-3 (fp32 online-softmax/scan tolerance).

**engine** — end-to-end InferenceEngine throughput with
``backend="reference"`` vs ``backend="pallas"`` on the smoke planner
(prefix cache + continuous batching exercised), plus exact token
equality of the served outputs.

NOTE on CPU: Pallas runs in ``interpret=True`` mode — a Python-level
kernel emulator. Its timings measure *correctness cost*, not speed; the
``interpret`` flag is recorded in every row so TPU runs (where the
Mosaic-compiled kernels are the fast path) are distinguishable in the
checked-in results.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def _time(fn, reps: int = 3) -> float:
    import jax
    jax.block_until_ready(fn())            # warmup (jit / first trace)
    t0 = time.time()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels import backend as KB
    from repro.kernels import ref as R

    interpret = jax.default_backend() != "tpu"
    be = KB.get_backend("pallas")
    rng = np.random.default_rng(0)
    r = lambda s: jnp.asarray(rng.standard_normal(s, dtype=np.float32))
    rows = []

    def row(op, shape, ref_fn, pl_fn, err_of):
        ref_s = _time(ref_fn)
        pl_s = _time(pl_fn)
        err = float(err_of())
        rows.append({"op": op, "shape": shape,
                     "ref_s": round(ref_s, 4), "pallas_s": round(pl_s, 4),
                     "max_abs_err": float(f"{err:.2e}"),
                     "parity": err < 2e-3, "interpret": interpret})

    # prefill attention at engine prompt buckets (B=1 prefill, GQA 4/2)
    Hq, Hkv, hd = 4, 2, 64
    for S in (128, 512):
        q, k, v = r((1, Hq, S, hd)), r((1, Hkv, S, hd)), r((1, Hkv, S, hd))
        ref = jax.jit(lambda q, k, v: R.attention_ref(q, k, v, causal=True))
        row(f"flash_prefill", f"B1 Hq{Hq}/Hkv{Hkv} S{S} hd{hd}",
            lambda: ref(q, k, v),
            lambda: be.attention(q, k, v, causal=True),
            lambda: jnp.max(jnp.abs(be.attention(q, k, v, causal=True)
                                    - ref(q, k, v))))

    # chunked-prefill extend: 64 new tokens at offset 384 of a 512 cache
    Sc, pos, S = 512, 384, 64
    q, k, v = r((1, Hq, S, hd)), r((1, Hkv, Sc, hd)), r((1, Hkv, Sc, hd))
    ref = jax.jit(lambda q, k, v: R.attention_ref(q, k, v, causal=True,
                                                  q_offset=pos))
    row("flash_prefill(extend)", f"B1 S{S}@{pos} cache{Sc}",
        lambda: ref(q, k, v),
        lambda: be.attention(q, k, v, causal=True, q_offset=pos),
        lambda: jnp.max(jnp.abs(
            be.attention(q, k, v, causal=True, q_offset=pos) - ref(q, k, v))))

    # continuous-batching decode: 8 slots at mixed fill levels, 512 cache
    B, Sc = 8, 512
    q1, k, v = r((B, Hq, hd)), r((B, Hkv, Sc, hd)), r((B, Hkv, Sc, hd))
    kvl = jnp.asarray(rng.integers(1, Sc, B), jnp.int32)
    ref = jax.jit(lambda q, k, v, l: R.decode_attention_ref(q, k, v, l))
    row("flash_decode", f"B{B} Hq{Hq}/Hkv{Hkv} cache{Sc} (B,)kv_len",
        lambda: ref(q1, k, v, kvl),
        lambda: be.decode_attention(q1, k, v, kvl),
        lambda: jnp.max(jnp.abs(be.decode_attention(q1, k, v, kvl)
                                - ref(q1, k, v, kvl))))

    # MoE router top-k at prefill token counts
    T, E, K = 1024, 64, 2
    logits = r((T, E)) * 3.0
    ref = jax.jit(lambda x: R.router_topk_ref(x, K)[:2])
    row("moe_router", f"T{T} E{E} k{K}",
        lambda: ref(logits),
        lambda: be.router_topk(logits, K),
        lambda: jnp.max(jnp.abs(be.router_topk(logits, K)[0]
                                - ref(logits)[0])))

    # selective-SSM scan at hymba-ish decode-prefill shapes
    Bs, Ss, di, n = 2, 256, 256, 16
    dt = jnp.abs(r((Bs, Ss, di))) * 0.1
    x, B_, C_ = r((Bs, Ss, di)), r((Bs, Ss, n)), r((Bs, Ss, n))
    A = -jnp.exp(r((di, n)))
    ref = jax.jit(lambda *a: R.selective_scan_ref(*a)[0])
    row("ssm_scan", f"B{Bs} S{Ss} di{di} n{n}",
        lambda: ref(dt, x, B_, C_, A),
        lambda: be.selective_scan(dt, x, B_, C_, A, None)[0],
        lambda: jnp.max(jnp.abs(be.selective_scan(dt, x, B_, C_, A, None)[0]
                                - ref(dt, x, B_, C_, A))))

    # mLSTM scan at xlstm-125m smoke head geometry
    Bm, H, Sm, hdm = 2, 4, 128, 32
    q, k2, v2 = r((Bm, H, Sm, hdm)), r((Bm, H, Sm, hdm)), r((Bm, H, Sm, hdm))
    ip, fp = r((Bm, H, Sm)) * 0.3, r((Bm, H, Sm)) * 0.3 + 3.0
    ref = jax.jit(lambda *a: R.mlstm_scan_ref(*a)[0])
    row("mlstm_scan", f"B{Bm} H{H} S{Sm} hd{hdm}",
        lambda: ref(q, k2, v2, ip, fp),
        lambda: be.mlstm_scan(q, k2, v2, ip, fp, None)[0],
        lambda: jnp.max(jnp.abs(be.mlstm_scan(q, k2, v2, ip, fp, None)[0]
                                - ref(q, k2, v2, ip, fp))))
    return rows


def bench_engine(n_requests: int = 6):
    import jax
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving.engine import InferenceEngine
    from repro.serving.sampling import SamplerConfig

    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    interpret = jax.default_backend() != "tpu"

    def serve(backend):
        eng = InferenceEngine(cfg, params, max_batch=4, cache_len=256,
                              seed=0, backend=backend)
        eng.register_prefix("gate", "classify the intent of the query:")
        t0 = time.time()
        for i in range(n_requests):
            eng.add_request(
                f"classify the intent of the query: region {i}",
                max_new_tokens=8, sampler=SamplerConfig(temperature=0.0),
                prefix_key="gate")
        outs = sorted((r.request_id, tuple(r.output))
                      for r in eng.run_until_done())
        dt = time.time() - t0
        st = eng.throughput_stats()
        return dt, st["tokens_generated"] / max(dt, 1e-9), outs

    ref_s, ref_tps, ref_out = serve("reference")
    pl_s, pl_tps, pl_out = serve("pallas")
    return {"requests": n_requests, "interpret": interpret,
            "reference_s": round(ref_s, 3),
            "pallas_s": round(pl_s, 3),
            "reference_tok_s": round(ref_tps, 1),
            "pallas_tok_s": round(pl_tps, 1),
            "tokens_equal": ref_out == pl_out}


def run():
    kernels = bench_kernels()
    engine = bench_engine()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    md = ["## kernels — reference vs pallas at serving shapes", "",
          "(pallas timings on CPU are interpret-mode — correctness, not "
          "speed; see benchmarks/kernel_bench.py docstring)", "",
          "| op | shape | ref_s | pallas_s | max_abs_err | parity | "
          "interpret |", "|---|---|---|---|---|---|---|"]
    for r in kernels:
        md.append(f"| {r['op']} | {r['shape']} | {r['ref_s']} | "
                  f"{r['pallas_s']} | {r['max_abs_err']} | {r['parity']} | "
                  f"{r['interpret']} |")
    md += ["", "## engine — end-to-end backend comparison", "",
           f"```\n{json.dumps(engine, indent=1)}\n```"]
    out = {"kernels": kernels, "engine": engine}
    with open(os.path.join(RESULTS_DIR, "kernel_bench.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    with open(os.path.join(RESULTS_DIR, "kernel_bench.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    out = run()
    for r in out["kernels"]:
        print(f"{r['op']:22s} {r['shape']:32s} ref {r['ref_s']}s "
              f"pallas {r['pallas_s']}s err {r['max_abs_err']} "
              f"parity={r['parity']}")
    e = out["engine"]
    print(f"engine: reference {e['reference_s']}s "
          f"({e['reference_tok_s']} tok/s) vs pallas {e['pallas_s']}s "
          f"({e['pallas_tok_s']} tok/s), tokens_equal={e['tokens_equal']}")
    return out


if __name__ == "__main__":
    main()
