"""The CI bench-regression gate (benchmarks/check_regression.py): every
committed tiny baseline must pass against itself, directions/tolerances
must catch real regressions and forgive improvements, and the CLI must
exit nonzero on failure.
"""
import copy
import json
import os

import pytest

from benchmarks.check_regression import SPECS, compare, main

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(bench):
    path = os.path.join(RESULTS, f"{bench}_bench_tiny.json")
    if not os.path.exists(path):
        pytest.skip(f"no committed baseline {path}")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("bench", sorted(SPECS))
def test_baseline_passes_against_itself(bench):
    data = load(bench)
    failures, _ = compare(bench, data, data)
    assert failures == []


def test_regression_beyond_tolerance_fails():
    base = load("specdec")
    cur = copy.deepcopy(base)
    cur["meta"]["spec_speedup_skewed_greedy"] = \
        base["meta"]["spec_speedup_skewed_greedy"] * 0.5
    failures, _ = compare("specdec", cur, base)
    assert failures == ["spec_speedup_skewed_greedy"]


def test_drift_within_tolerance_passes():
    base = load("specdec")
    cur = copy.deepcopy(base)
    cur["meta"]["spec_speedup_skewed_greedy"] = \
        base["meta"]["spec_speedup_skewed_greedy"] * 0.95   # tol 0.1
    failures, _ = compare("specdec", cur, base)
    assert failures == []


def test_improvement_never_fails():
    base = load("paging")
    cur = copy.deepcopy(base)
    cur["meta"]["paged_memory_savings"] = 0.99
    failures, _ = compare("paging", cur, base)
    assert failures == []


def test_equal_metric_catches_parity_break():
    base = load("paging")
    cur = copy.deepcopy(base)
    cur["meta"]["tokens_identical"] = False
    failures, _ = compare("paging", cur, base)
    assert "tokens_identical" in failures


def test_cli_exit_codes(tmp_path):
    base_path = os.path.join(RESULTS, "cluster_bench_tiny.json")
    if not os.path.exists(base_path):
        pytest.skip("no committed baseline")
    assert main(["--bench", "cluster", "--current", base_path]) == 0
    bad = json.load(open(base_path))
    for r in bad["rows"]:
        if r["policy"] == "intent_affinity":
            r["prefix_hit"] = 0.0
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(bad))
    assert main(["--bench", "cluster", "--current", str(p)]) == 1