"""Synthetic traffic generator: determinism, intent mix, profiles."""
import pytest

from repro.core.intents import INTENTS
from repro.serving.workload import (PROFILES, WorkloadConfig,
                                    intent_prefix, make_workload,
                                    prefix_key_for, skewed_mix,
                                    uniform_mix, workload_intents)


def test_same_seed_same_workload():
    """Same config => identical request list (schedule, intents, session
    turn order, prompts, sampler seeds) — no wall-clock randomness."""
    cfg = WorkloadConfig(n_sessions=24, seed=7, profile="poisson",
                         max_turns=3, temperature=0.8)
    a = make_workload(cfg)
    b = make_workload(cfg)
    assert a == b                      # frozen dataclasses, field-exact
    c = make_workload(WorkloadConfig(n_sessions=24, seed=8,
                                     profile="poisson", max_turns=3,
                                     temperature=0.8))
    assert a != c


def test_intent_mix_within_tolerance():
    """Drawn intent frequencies track the requested distribution."""
    mix = skewed_mix(hot="detection_analysis", hot_frac=0.6)
    reqs = make_workload(WorkloadConfig(n_sessions=600, seed=0,
                                        intent_mix=mix))
    counts = workload_intents(reqs)
    n = sum(counts.values())
    assert n == 600
    for intent, p in mix.items():
        assert abs(counts.get(intent, 0) / n - p) < 0.06, (intent, counts)


def test_uniform_mix_sums_to_one():
    for mix in (uniform_mix(), skewed_mix(hot_frac=0.7)):
        assert abs(sum(mix.values()) - 1.0) < 1e-9
        assert set(mix) == set(INTENTS)


def test_skewed_mix_bounds():
    # hot_frac=1.0 is the degenerate all-hot workload, not an error
    mix = skewed_mix(hot="visual_qa", hot_frac=1.0)
    assert mix["visual_qa"] == 1.0
    assert all(v == 0.0 for k, v in mix.items() if k != "visual_qa")
    reqs = make_workload(WorkloadConfig(n_sessions=8, intent_mix=mix))
    assert {w.intent for w in reqs} == {"visual_qa"}
    for bad in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError):
            skewed_mix(hot_frac=bad)
    with pytest.raises(ValueError):
        skewed_mix(hot="not_an_intent")
    with pytest.raises(ValueError):       # < 2 intents: no cold share
        skewed_mix(hot="visual_qa", hot_frac=0.5,
                   intents=("visual_qa",))


@pytest.mark.parametrize("profile", PROFILES)
def test_arrival_schedules(profile):
    cfg = WorkloadConfig(n_sessions=32, seed=3, profile=profile,
                         inter_arrival=2.0, burst_size=4)
    openers = [w for w in make_workload(cfg) if w.turn == 0]
    ticks = [w.arrival_tick for w in openers]
    assert ticks == sorted(ticks)
    assert ticks[0] == 0
    if profile == "uniform":
        assert ticks == [2 * i for i in range(32)]
    if profile == "bursty":
        # bursts of burst_size share one tick, spaced to keep the rate
        assert ticks == [(i // 4) * 8 for i in range(32)]
    if profile == "poisson":
        assert len(set(ticks)) > 1


def test_unknown_profile_rejected():
    with pytest.raises(ValueError):
        make_workload(WorkloadConfig(profile="flashmob"))


def test_sessions_share_intent_and_order_turns():
    reqs = make_workload(WorkloadConfig(n_sessions=20, seed=1,
                                        max_turns=4, turn_gap=2))
    by_session = {}
    for w in reqs:
        by_session.setdefault(w.session_id, []).append(w)
    assert any(len(v) > 1 for v in by_session.values())
    for sid, turns in by_session.items():
        assert [w.turn for w in turns] == list(range(len(turns)))
        assert len({w.intent for w in turns}) == 1
        assert all(w.n_turns == len(turns) for w in turns)
        for w in turns[1:]:
            assert w.arrival_tick == 2       # the turn gap, not absolute
    # workload indices are positional
    assert [w.index for w in reqs] == list(range(len(reqs)))


def test_prompts_carry_intent_prefix():
    reqs = make_workload(WorkloadConfig(n_sessions=16, seed=0))
    for w in reqs:
        assert w.prompt.startswith(intent_prefix(w.intent))
        assert len(w.prompt) > len(intent_prefix(w.intent))
        assert w.prefix_key == prefix_key_for(w.intent)
        assert w.sla_ticks >= 64 and w.max_new_tokens == 4
    bare = make_workload(WorkloadConfig(n_sessions=4, use_prefix=False))
    assert all(w.prefix_key is None for w in bare)


def test_sampler_seeds_unique_and_deterministic():
    reqs = make_workload(WorkloadConfig(n_sessions=64, seed=5))
    seeds = [w.sampler_seed for w in reqs]
    assert len(set(seeds)) == len(seeds)
    assert seeds == [w.sampler_seed
                     for w in make_workload(WorkloadConfig(n_sessions=64,
                                                           seed=5))]
