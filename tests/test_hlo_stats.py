"""HLO static analysis: trip-count scaling + collective accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_stats import (collective_stats,
                                    computation_multipliers, hlo_profile)


def test_scan_trip_count_scaling():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), ()
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    prof = hlo_profile(c.as_text(), 1)
    expect = 10 * 2 * 128 * 256 * 256
    assert prof["dot_flops_scaled"] == pytest.approx(expect, rel=0.01)
    # bytes: each iteration reads h + w and writes h at minimum
    per_iter = (128 * 256 + 256 * 256 + 128 * 256) * 4
    assert prof["bytes_scaled"] >= 10 * per_iter * 0.9


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(h, _):
            def inner(g, _):
                return g @ w, ()
            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, ()
        h, _ = jax.lax.scan(outer, x, None, length=4)
        return h

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    prof = hlo_profile(c.as_text(), 1)
    expect = 12 * 2 * 64 * 64 * 64
    assert prof["dot_flops_scaled"] == pytest.approx(expect, rel=0.05)


SYNTH_HLO = """
HloModule synth

%body.1 (p: (s32[], f32[128,64])) -> (s32[], f32[128,64]) {
  %p = (s32[], f32[128,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,64]{1,0} get-tuple-element(%p), index=1
  %ag = f32[128,256]{1,0} all-gather(%x), replica_groups=[32,4]<=[128], dimensions={1}
  %r = f32[128,64]{1,0} slice(%ag), slice={[0:128],[0:64]}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,64]) tuple(%ni, %r)
}

%cond.1 (p: (s32[], f32[128,64])) -> pred[] {
  %p = (s32[], f32[128,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[128,64]) -> f32[128,64] {
  %a = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%a), replica_groups=[1,128]<=[128], to_apply=%add.1
  %z = s32[] constant(0)
  %init = (s32[], f32[128,64]) tuple(%z, %ar)
  %w = (s32[], f32[128,64]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[128,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collective_stats_synthetic():
    st = collective_stats(SYNTH_HLO, 128)
    # all-gather inside while body: out 128*256*4 bytes, g=4, trips=7
    ag = 128 * 256 * 4 * (3 / 4) * 7
    # all-reduce at entry: 2 * size * (g-1)/g
    ar = 2 * 128 * 64 * 4 * (127 / 128)
    assert st["bytes_all-gather"] == pytest.approx(ag, rel=0.01)
    assert st["bytes_all-reduce"] == pytest.approx(ar, rel=0.01)
    assert st["collective_bytes"] == pytest.approx(ag + ar, rel=0.01)


def test_multipliers_entry_is_one():
    comps, mult = computation_multipliers(SYNTH_HLO)
    assert mult["__entry__"] == 1.0
    assert mult["body.1"] == 7.0
