"""Multi-replica serving cluster: router policies, exact token parity
across replica counts, affinity vs round-robin prefix-hit rates, and
pipeline-on-cluster integration."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier
from repro.core.intents import build_intent_map
from repro.core.planner import PlannerConfig
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.tasks import make_benchmark
from repro.env.world import build_world
from repro.models.model import init_params
from repro.serving.cluster import (ROUTER_POLICIES, EngineCluster,
                                   IntentAffinityRouter, ReplicaView,
                                   make_router, rendezvous_hash)
from repro.serving.workload import (WorkloadConfig, make_workload,
                                    register_workload_prefixes,
                                    skewed_mix, uniform_mix)


@pytest.fixture(scope="module")
def planner():
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def pool(planner):
    """Four replicas sharing one jit cache; tests reset() them."""
    cfg, params = planner
    return EngineCluster(cfg, params, 4, max_batch=2,
                         cache_len=192, seed=0).replicas


def mkcluster(pool, policy, n=None, **kw):
    engines = pool[:n] if n else pool
    for e in engines:
        e.reset()
    return EngineCluster(engines=engines, router=policy, **kw)


# ----------------------------------------------------- router unit tests ----

def views(*loads, holder=None):
    return [ReplicaView(i, busy, q, holds_prefix=(i == holder))
            for i, (busy, q) in enumerate(loads)]


def test_round_robin_cycles():
    r = make_router("round_robin")
    v = views((0, 0), (0, 0), (0, 0))
    assert [r.select(v) for _ in range(6)] == [0, 1, 2, 0, 1, 2]


def test_least_loaded_picks_min_load_ties_to_lowest_index():
    r = make_router("least_loaded")
    assert r.select(views((2, 3), (1, 0), (4, 0))) == 1
    assert r.select(views((1, 1), (0, 2), (2, 0))) == 0   # tie 2,2,2 -> 0
    # queue depth counts as load, not just busy slots
    assert r.select(views((0, 9), (1, 0))) == 1


def test_affinity_routes_to_prefix_holder():
    r = make_router("intent_affinity")
    # holder wins even when busier
    assert r.select(views((4, 6), (0, 0), (0, 0), holder=0), "k") == 0
    # no key -> least loaded
    assert r.select(views((4, 6), (1, 0), (0, 0), holder=0)) == 2
    # no holder -> deterministic rendezvous placement over all replicas
    home = rendezvous_hash("k", range(3))
    assert r.select(views((0, 0), (0, 0), (0, 0)), "k") == home
    assert r.select(views((3, 5), (3, 5), (3, 5)), "k") == home


def test_affinity_spills_when_home_overloaded():
    r = IntentAffinityRouter(spill_load=8)
    assert r.select(views((4, 3), (0, 0), holder=0), "k") == 0   # 7 < 8
    assert r.select(views((4, 4), (0, 0), holder=0), "k") == 1   # 8 >= 8


def test_rendezvous_hash_stable_and_spreading():
    keys = [f"intent:{i}" for i in range(16)]
    homes = {k: rendezvous_hash(k, range(4)) for k in keys}
    assert homes == {k: rendezvous_hash(k, range(4)) for k in keys}
    assert len(set(homes.values())) >= 3       # keys spread over replicas
    # adding a replica only remaps keys the new replica wins
    grown = {k: rendezvous_hash(k, range(5)) for k in keys}
    assert all(grown[k] in (homes[k], 4) for k in keys)


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        make_router("random")


def test_prebuilt_engines_reject_sizing_kwargs(pool):
    """engines= keeps the replicas' own configuration; sizing kwargs
    would be silently dropped, so the constructor refuses them."""
    with pytest.raises(ValueError, match="mutually exclusive"):
        EngineCluster(engines=pool, max_batch=16)
    with pytest.raises(ValueError, match="mutually exclusive"):
        EngineCluster(engines=pool, cache_len=1024)


# ------------------------------------------------- exact token parity ------

def test_token_parity_one_replica_vs_four_all_policies(pool):
    """The same seeded workload (stochastic seeded samplers, multi-turn
    sessions, per-intent prefixes) replayed through 1 replica and through
    4 replicas yields identical per-request outputs under EVERY router
    policy: routing moves work, never tokens."""
    reqs = make_workload(WorkloadConfig(
        n_sessions=8, seed=2, intent_mix=uniform_mix(),
        profile="poisson", max_turns=2, max_new_tokens=3,
        temperature=0.8))

    def serve(policy, n):
        cl = mkcluster(pool, policy, n=n)
        register_workload_prefixes(cl, reqs)
        stats = cl.run_workload(reqs)
        return stats.outputs(), stats.summary()

    ref_out, ref_sum = serve("round_robin", 1)
    assert len(ref_out) == len(reqs) == ref_sum["finished"]
    assert all(len(v) > 0 for v in ref_out.values())
    for policy in ROUTER_POLICIES:
        out, summ = serve(policy, 4)
        assert out == ref_out, policy
        assert summ["tokens_out"] == ref_sum["tokens_out"]
        # the cluster spread the same work over more replicas
        assert sum(r["admissions"] for r in summ["per_replica"]) \
            == len(reqs)


def test_affinity_beats_round_robin_on_skewed_mix(pool):
    """On a skewed intent mix at 4 replicas, consistent-hash affinity
    achieves a strictly higher prefix-hit ratio than round-robin (the
    prefix lives on ONE home replica; oblivious routing misses it on
    the other three) — with identical generated tokens."""
    reqs = make_workload(WorkloadConfig(
        n_sessions=16, seed=0, intent_mix=skewed_mix(hot_frac=0.7),
        profile="poisson", max_turns=2, max_new_tokens=3,
        temperature=0.8))

    def serve(policy):
        cl = mkcluster(pool, policy)
        register_workload_prefixes(cl, reqs)
        stats = cl.run_workload(reqs)
        return stats.summary(), stats.outputs()

    rr, rr_out = serve("round_robin")
    aff, aff_out = serve("intent_affinity")
    assert aff["prefix_hit_ratio"] > rr["prefix_hit_ratio"]
    assert aff["prefix_hit_ratio"] == 1.0     # every request rode its home
    assert rr["prefix_hit_ratio"] <= 0.5
    assert aff_out == rr_out
    assert aff["tokens_out"] == rr["tokens_out"]
    # affinity concentrated the hot intent: per-replica hit rates prove
    # the home replica served hits while others served their own intents
    assert all(r["prefix_hits"] == r["admissions"]
               for r in aff["per_replica"] if r["admissions"])


def test_least_loaded_spreads_bursts(pool):
    """A burst of simultaneous arrivals lands across all replicas under
    least_loaded (each submission sees the previous one's queue)."""
    reqs = make_workload(WorkloadConfig(
        n_sessions=12, seed=4, profile="bursty", burst_size=12,
        inter_arrival=1.0, max_new_tokens=2))
    cl = mkcluster(pool, "least_loaded")
    stats = cl.run_workload(reqs)
    s = stats.summary()
    assert s["finished"] == len(reqs)
    assert all(r["admissions"] >= 2 for r in s["per_replica"])
    assert all(r["utilization"] > 0 for r in s["per_replica"])


def test_cluster_stats_schema(pool):
    """Latency/queue metrics are well-formed ticks and SLA accounting
    covers every finished request."""
    reqs = make_workload(WorkloadConfig(
        n_sessions=6, seed=1, max_new_tokens=2, sla_ticks=64))
    cl = mkcluster(pool, "intent_affinity")
    register_workload_prefixes(cl, reqs)
    stats = cl.run_workload(reqs)
    s = stats.summary()
    assert s["finished"] == s["requests"] == len(reqs)
    assert 1 <= s["ttft_p50"] <= s["ttft_p95"] <= s["e2e_p95"]
    assert 0 <= s["queue_wait_p50"] <= s["queue_wait_p95"]
    assert s["sla_attainment"] == 1.0        # tiny load, generous SLA
    assert s["tokens_out"] >= s["tokens_decoded"] > 0
    for t in stats.traces:
        assert t.finish_tick >= t.admit_tick >= t.arrival_tick
        assert t.request.finish_reason is not None


def test_utilization_bounded_by_one(pool):
    """Terminal-at-admission floods (max_new_tokens=1 drains the whole
    queue through one slot per tick) must not overcount busy-slot-ticks:
    utilization stays in [0, 1]."""
    reqs = make_workload(WorkloadConfig(n_sessions=8, seed=6,
                                        inter_arrival=0.0,
                                        max_new_tokens=1))
    cl = mkcluster(pool, "least_loaded", n=1)
    s = cl.run_workload(reqs).summary()
    assert s["finished"] == len(reqs)
    assert all(0.0 <= r["utilization"] <= 1.0 for r in s["per_replica"])


def test_sla_counts_unfinished_as_misses(pool):
    """Cutting a run off at max_ticks leaves deadline-carrying requests
    unfinished; they count as SLA misses, not silently dropped."""
    reqs = make_workload(WorkloadConfig(n_sessions=8, seed=6,
                                        inter_arrival=0.0, max_turns=2,
                                        max_new_tokens=8, sla_ticks=4))
    cl = mkcluster(pool, "least_loaded", n=1)
    s = cl.run_workload(reqs, max_ticks=2).summary()
    assert s["finished"] < s["requests"]
    # the whole workload is accounted for, including follow-up turns
    # never released before the cutoff
    assert s["requests"] == len(reqs)
    assert s["sla_attainment"] < 1.0


def test_run_workload_requires_fresh_cluster_and_reset_recycles(pool):
    """Back-to-back run_workload on one cluster would silently mix runs
    in ClusterStats — it must refuse; cluster.reset() recycles the whole
    fleet and reproduces a fresh cluster's run exactly."""
    reqs = make_workload(WorkloadConfig(n_sessions=5, seed=9,
                                        max_new_tokens=2,
                                        temperature=0.8))
    cl = mkcluster(pool, "intent_affinity")
    register_workload_prefixes(cl, reqs)
    first = cl.run_workload(reqs)
    with pytest.raises(RuntimeError):
        cl.run_workload(reqs)
    cl.reset()
    assert cl.is_idle() and cl.tick == 0 and not cl.prefixes
    register_workload_prefixes(cl, reqs)
    again = cl.run_workload(reqs)
    assert again.outputs() == first.outputs()
    assert again.summary() == first.summary()


def test_run_workload_rejects_orphaned_followups(pool):
    """A follow-up turn whose predecessor never runs can never be
    released — fail fast instead of spinning to max_ticks."""
    reqs = make_workload(WorkloadConfig(n_sessions=4, seed=3,
                                        max_turns=3, max_new_tokens=2))
    orphans = [w for w in reqs if w.turn > 0]
    assert orphans, "need multi-turn sessions for this test"
    cl = mkcluster(pool, "least_loaded")
    with pytest.raises(ValueError, match="predecessor"):
        cl.run_workload(orphans)


# -------------------------------------------------- pipeline integration ----

def test_pipeline_targets_cluster(planner):
    """GeckOptPipeline(engine=EngineCluster) serves every session's
    planner turn with per-intent prefix caching on the session's home
    replica — same surface as the single engine."""
    cfg, params = planner
    world = build_world(0)
    tasks = make_benchmark(world, 4)
    imap = build_intent_map(make_benchmark(world, 32), DEFAULT_REGISTRY)
    gate = IntentGate(imap, ScriptedIntentClassifier(
        1.0, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
    agent = Agent(DEFAULT_REGISTRY, world,
                  PlannerConfig(mode="cot", few_shot=False), gate=gate,
                  seed=0)
    cluster = EngineCluster(cfg, params, 2, router="intent_affinity",
                            max_batch=2, cache_len=4096)

    from repro.serving.pipeline import GeckOptPipeline, PipelineConfig
    pipe = GeckOptPipeline(agent,
                           PipelineConfig(max_concurrent=4,
                                          engine_max_new_tokens=2),
                           engine=cluster)
    results = pipe.run(tasks)
    assert len(results) == 4
    assert pipe.stats.engine_replicas == 2
    assert pipe.stats.engine_turns == 4
    agg = cluster.throughput_stats()
    # every planner turn was admitted somewhere and rode a prefix
    assert agg["admissions"] == 4
    assert agg["prefix_hits"] == 4
    assert len(cluster.prefixes) <= 4
    assert all(es.idle for es in pipe._engine_sessions)
    assert cluster.is_idle()
