"""GeckOpt system behaviour: gating, fallback, token accounting, mined
intent map vs paper Table 1."""
import numpy as np
import pytest

from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier, \
    keyword_intent
from repro.core.intents import TABLE1_MAP, build_intent_map
from repro.core.planner import PlannerConfig, ScriptedPlanner
from repro.core.tools import DEFAULT_REGISTRY, build_default_registry
from repro.env.evaluator import evaluate
from repro.env.tasks import make_benchmark
from repro.env.world import build_world


@pytest.fixture(scope="module")
def world():
    return build_world(0, n_images=200)


@pytest.fixture(scope="module")
def tasks(world):
    return make_benchmark(world, 64)


@pytest.fixture(scope="module")
def intent_map(tasks):
    return build_intent_map(tasks, DEFAULT_REGISTRY)


def test_registry_structure():
    r = build_default_registry()
    assert len(r.tools) >= 40
    libs = r.libraries()
    for lib in ("SQL_apis", "data_apis", "map_apis", "web_apis", "UI_apis",
                "wiki_apis"):
        assert lib in libs
    # catalog text shrinks monotonically with fewer libraries
    assert len(r.catalog_text(["wiki_apis"])) < len(
        r.catalog_text(["wiki_apis", "data_apis"])) < len(r.catalog_text())


def test_mined_intent_map_matches_paper_table1(intent_map):
    """The offline phase recovers the paper's Table 1 mapping."""
    for intent in ("load_filter_plot", "ui_web_navigation",
                   "information_seeking"):
        mined = set(intent_map.intent_to_libs[intent])
        assert mined == set(TABLE1_MAP[intent]), (intent, mined)


def test_gating_reduces_tokens_per_task(world, tasks, intent_map):
    cfg = PlannerConfig(mode="cot", few_shot=False)
    gate = IntentGate(intent_map, ScriptedIntentClassifier(
        1.0, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
    base = evaluate(Agent(DEFAULT_REGISTRY, world, cfg, gate=None, seed=0),
                    tasks, "b")
    gk = evaluate(Agent(DEFAULT_REGISTRY, world, cfg, gate=gate, seed=0),
                  tasks, "g")
    assert gk.tokens_per_task < base.tokens_per_task
    red = 1 - gk.tokens_per_task / base.tokens_per_task
    assert 0.10 < red < 0.45          # paper regime: up to ~25%
    # success within ~2pp of baseline (paper: <1% on 5k tasks)
    assert abs(gk.success_rate - base.success_rate) < 0.06


def test_gating_encourages_multi_tool_steps(world, tasks, intent_map):
    cfg = PlannerConfig(mode="cot", few_shot=False)
    gate = IntentGate(intent_map, ScriptedIntentClassifier(
        1.0, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
    base = evaluate(Agent(DEFAULT_REGISTRY, world, cfg, gate=None, seed=0),
                    tasks, "b")
    gk = evaluate(Agent(DEFAULT_REGISTRY, world, cfg, gate=gate, seed=0),
                  tasks, "g")
    assert gk.tools_per_step > base.tools_per_step
    assert gk.steps_per_task < base.steps_per_task


def test_fallback_on_wrong_intent(world, tasks, intent_map):
    """With a deliberately bad gate, every task must still complete via
    the full-catalog fallback."""
    cfg = PlannerConfig(mode="cot", few_shot=False)
    bad_gate = IntentGate(intent_map, ScriptedIntentClassifier(
        0.0, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
    agent = Agent(DEFAULT_REGISTRY, world, cfg, gate=bad_gate, seed=0)
    res = [agent.run_task(t, task_seed=i) for i, t in enumerate(tasks[:24])]
    # most misrouted tasks trigger the fallback...
    assert sum(r.fallback_used for r in res) >= len(res) * 0.4
    # ...and still execute tools afterwards
    assert all(len(r.executed_tools) > 0 for r in res
               if r.fallback_used)


def test_gate_charges_one_extra_call(world, tasks, intent_map):
    cfg = PlannerConfig(mode="cot", few_shot=False)
    gate = IntentGate(intent_map, ScriptedIntentClassifier(
        1.0, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
    agent = Agent(DEFAULT_REGISTRY, world, cfg, gate=gate, seed=0)
    res = agent.run_task(tasks[0], task_seed=0)
    gates = [e for e in res.ledger.entries if e.kind == "gate"]
    assert len(gates) == 1
    assert gates[0].prompt_tokens > 0


def test_aggregation_monotone_in_toolset_size():
    cfg = PlannerConfig()
    p = ScriptedPlanner(cfg, DEFAULT_REGISTRY, seed=0)
    n = len(DEFAULT_REGISTRY.tools)
    probs = [p.p_aggregate(k) for k in range(1, n + 1)]
    assert all(a >= b - 1e-9 for a, b in zip(probs, probs[1:]))
    assert probs[0] > probs[-1]


def test_keyword_intent_reasonable(tasks):
    acc = np.mean([keyword_intent(t.query) == t.intent for t in tasks])
    assert acc > 0.9


# --------------------------------------- tool-graph compiler regression ----

def test_compiler_moves_only_steps_and_tokens(world, tasks, intent_map):
    """Table-2 regression for the tool-graph compiler: in BOTH the gated
    and ungated cells, turning compile_plans on must leave every quality
    metric (and the fallback rate) exactly unchanged while cutting
    planner round-trips >= 1.5x and total tokens."""
    libs = DEFAULT_REGISTRY.libraries()
    reports = {}
    for gated in (False, True):
        for compiled in (False, True):
            cfg = PlannerConfig(mode="react", few_shot=False,
                                compile_plans=compiled)
            gate = IntentGate(intent_map, ScriptedIntentClassifier(
                0.97, np.random.default_rng(0)), libs) if gated else None
            reports[(gated, compiled)] = evaluate(
                Agent(DEFAULT_REGISTRY, world, cfg, gate=gate, seed=0),
                tasks, "cell")
    for gated in (False, True):
        lin, comp = reports[(gated, False)], reports[(gated, True)]
        quality = lambda r: (r.correct_rate, r.success_rate, r.det_f1,
                             r.lcc_r, r.vqa_rouge_l, r.fallback_rate)
        assert quality(lin) == quality(comp)
        assert lin.steps_per_task / comp.steps_per_task >= 1.5
        assert comp.tokens_per_task < lin.tokens_per_task
    # gating still compounds with compilation (the GeckOpt claim)
    assert reports[(True, True)].tokens_per_task < \
        reports[(False, True)].tokens_per_task
