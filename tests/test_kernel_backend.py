"""Kernel backend subsystem: registry semantics, pipeline-level parity
(pallas backend must serve the exact tokens of the reference backend),
and kernel-vs-ref sweeps at the serving shapes InferenceEngine uses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier
from repro.core.intents import build_intent_map
from repro.core.planner import PlannerConfig
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.tasks import make_benchmark
from repro.env.world import build_world
from repro.kernels import backend as KB
from repro.kernels.ref import attention_ref, decode_attention_ref
from repro.models.model import decode_step, init_params, prefill
from repro.serving.engine import InferenceEngine
from repro.serving.pipeline import GeckOptPipeline, PipelineConfig
from repro.serving.sampling import SamplerConfig

RNG = np.random.default_rng(7)


def _rand(shape):
    return jnp.asarray(RNG.standard_normal(shape, dtype=np.float32))


@pytest.fixture(scope="module")
def planner():
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ------------------------------------------------------------ registry ----

def test_backend_registry_resolution():
    assert set(KB.available_backends()) >= {"reference", "pallas"}
    assert KB.get_backend().name == "reference"          # PerfFlags default
    assert KB.get_backend("pallas").name == "pallas"
    be = KB.get_backend("pallas")
    assert KB.get_backend(be) is be                      # pass-through
    with KB.use_backend("pallas"):
        assert KB.get_backend().name == "pallas"
        assert KB.get_backend("reference").name == "reference"  # arg wins
    assert KB.get_backend().name == "reference"
    with pytest.raises(ValueError):
        KB.get_backend("cuda")


# ------------------------------------- serving-shape kernel-vs-ref sweep ----

def test_kernel_vs_ref_at_serving_shapes():
    """flash kernels vs oracles at the bucket shapes the engine actually
    runs: GQA prefill at prompt lengths, chunked-prefill extend at a
    traced q_offset, continuous-batching decode with per-slot (B,) fill
    levels."""
    be = KB.get_backend("pallas")
    Hq, Hkv, hd = 4, 2, 64                       # planner-proxy smoke geometry

    # prefill buckets (engine prefills B=1 prompts)
    for S in (32, 96):
        q, k, v = _rand((1, Hq, S, hd)), _rand((1, Hkv, S, hd)), \
            _rand((1, Hkv, S, hd))
        out = be.attention(q, k, v, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-3

    # chunked-prefill extend: S new tokens at offset `pos` of a filled cache
    Sc, pos, S = 128, 70, 16
    k, v = _rand((1, Hkv, Sc, hd)), _rand((1, Hkv, Sc, hd))
    q = _rand((1, Hq, S, hd))
    out = be.attention(q, k, v, causal=True, q_offset=jnp.asarray(pos))
    ref = attention_ref(q, k, v, causal=True, q_offset=pos)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-3

    # continuous-batching decode: every slot at its own fill level
    for Sc in (96, 128, 512):
        B = 3
        q1 = _rand((B, Hq, hd))
        k, v = _rand((B, Hkv, Sc, hd)), _rand((B, Hkv, Sc, hd))
        kvl = jnp.asarray([Sc, Sc // 2, 1], jnp.int32)
        out = be.decode_attention(q1, k, v, kvl)
        ref = decode_attention_ref(q1, k, v, kvl)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-3


# --------------------------------------------------- engine-level parity ----

def test_engine_parity_continuous_batching(planner):
    """The pallas backend must emit the exact token ids of the reference
    backend through the full engine loop — prefix cache, chunked-prefill
    extends, staggered continuous-batching decode — at the same seed."""
    cfg, params = planner

    def serve(backend):
        eng = InferenceEngine(cfg, params, max_batch=3, cache_len=128,
                              seed=0, backend=backend)
        eng.register_prefix("gate", "classify the user intent:")
        rids = [eng.add_request(
            f"classify the user intent: query number {i}",
            max_new_tokens=5, sampler=SamplerConfig(temperature=0.0),
            prefix_key="gate") for i in range(5)]   # 5 requests, 3 slots
        done = {r.request_id: r.output for r in eng.run_until_done()}
        return [done[r] for r in rids], eng.throughput_stats()

    ref_out, ref_stats = serve("reference")
    pl_out, pl_stats = serve("pallas")
    assert ref_out == pl_out
    assert ref_stats == pl_stats


def test_engine_parity_across_architectures():
    """Greedy prefill+decode token parity reference vs pallas for every
    kernel consumer: MoE routing, SSM scan, mLSTM scan, sliding-window
    attention."""
    for arch in ("arctic-480b", "hymba-1.5b", "xlstm-125m", "gemma2-2b"):
        cfg = get_smoke_config(arch)
        params = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                  cfg.vocab_size)
        seqs = {}
        for be in ("reference", "pallas"):
            logits, cache = prefill(params, cfg, {"tokens": toks},
                                    cache_len=64, backend=be)
            cache["pos"] = jnp.asarray([24, 24], jnp.int32)
            out = [np.asarray(jnp.argmax(logits, -1))]
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            for _ in range(3):
                logits, cache = decode_step(params, cfg, cache,
                                            {"tokens": tok}, backend=be)
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                out.append(np.asarray(tok[:, 0]))
            seqs[be] = np.stack(out)
        assert (seqs["reference"] == seqs["pallas"]).all(), arch


# ------------------------------------------------- pipeline-level parity ----

def test_pipeline_parity_reference_vs_pallas(planner):
    """End-to-end: the concurrent gate→plan→execute pipeline with engine
    mirroring must produce identical task metrics AND identical engine
    turn tokens under both backends at the same seed."""
    cfg, params = planner
    world = build_world(0)
    tasks = make_benchmark(world, 4)
    intent_map = build_intent_map(tasks, DEFAULT_REGISTRY)

    def run(backend):
        engine = InferenceEngine(cfg, params, max_batch=2, cache_len=4096,
                                 seed=0, backend=backend)
        gate = IntentGate(intent_map, ScriptedIntentClassifier(
            1.0, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
        agent = Agent(DEFAULT_REGISTRY, world,
                      PlannerConfig(mode="cot", few_shot=False), gate=gate,
                      seed=0)
        pipe = GeckOptPipeline(
            agent, PipelineConfig(max_concurrent=4, engine_max_new_tokens=2),
            engine=engine)
        results = pipe.run(tasks)
        turns = [r.output for es in pipe._engine_sessions for r in es.turns]
        metrics = [(r.completed_plan, r.steps, r.ledger.total_tokens)
                   for r in results]
        return metrics, turns, pipe.stats.summary()

    m_ref, t_ref, s_ref = run("reference")
    m_pl, t_pl, s_pl = run("pallas")
    assert m_ref == m_pl
    assert t_ref == t_pl and len(t_ref) == 4
    assert s_ref["engine_backend"] == "reference"
    assert s_pl["engine_backend"] == "pallas"
