"""Per-kernel interpret-mode validation: shape/dtype sweeps vs the pure-jnp
oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.moe_router import moe_router_topk
from repro.kernels.ref import (attention_ref, decode_attention_ref,
                               mlstm_ref, router_topk_ref,
                               selective_scan_ref)
from repro.kernels.ssm_scan import ssm_scan

RNG = np.random.default_rng(0)


def _rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape, dtype=np.float32)
                       .astype(dtype))


@pytest.mark.parametrize("B,Hq,Hkv,S,hd", [
    (2, 4, 2, 256, 64), (1, 8, 8, 512, 128), (2, 2, 1, 128, 32),
    (1, 6, 3, 384, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(B, Hq, Hkv, S, hd, dtype):
    q, k, v = (jnp.asarray(_rand((b, h, S, hd)), dtype)
               for b, h in ((B, Hq), (B, Hkv), (B, Hkv)))
    out = flash_prefill(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("window,cap,causal", [
    (64, 0.0, True), (0, 50.0, True), (128, 30.0, True), (0, 0.0, False),
])
def test_flash_prefill_variants(window, cap, causal):
    q = _rand((2, 4, 256, 64))
    k = _rand((2, 2, 256, 64))
    v = _rand((2, 2, 256, 64))
    out = flash_prefill(q, k, v, causal=causal, window=window, cap=cap,
                        interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-3


@pytest.mark.parametrize("B,Hq,Hkv,S,hd,kvl", [
    (2, 4, 2, 1024, 64, 700), (1, 8, 1, 512, 128, 512),
    (3, 4, 4, 2048, 64, 1), (2, 8, 2, 512, 64, 511),
])
def test_flash_decode_sweep(B, Hq, Hkv, S, hd, kvl):
    q = _rand((B, Hq, hd))
    k = _rand((B, Hkv, S, hd))
    v = _rand((B, Hkv, S, hd))
    out = flash_decode(q, k, v, kvl, interpret=True)
    ref = decode_attention_ref(q, k, v, kvl)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-3


@pytest.mark.parametrize("T,E,k", [(512, 64, 2), (256, 128, 8),
                                   (256, 16, 1), (1024, 384, 8)])
def test_moe_router_sweep(T, E, k):
    logits = _rand((T, E)) * 3.0
    w, i = moe_router_topk(logits, k, interpret=True)
    wr, ir, _ = router_topk_ref(logits, k)
    assert jnp.allclose(w, wr, atol=1e-5)
    assert jnp.array_equal(i, ir)


@pytest.mark.parametrize("B,S,di,n", [(2, 256, 128, 16), (1, 512, 256, 8),
                                      (2, 128, 512, 16)])
def test_ssm_scan_sweep(B, S, di, n):
    dt = jnp.abs(_rand((B, S, di))) * 0.1
    x = _rand((B, S, di))
    B_ = _rand((B, S, n))
    C_ = _rand((B, S, n))
    A = -jnp.exp(_rand((di, n)))
    y, h_last = ssm_scan(dt, x, B_, C_, A, interpret=True)
    yr, hr = selective_scan_ref(dt, x, B_, C_, A)
    assert float(jnp.max(jnp.abs(y - yr))) < 1e-3
    assert float(jnp.max(jnp.abs(h_last - hr))) < 1e-3


def test_chunked_mlstm_matches_sequential_oracle():
    """The chunkwise-parallel mLSTM must equal the stabilized sequential
    recurrence from the paper."""
    from repro.common.config import ModelConfig, XLSTMConfig
    from repro.models.xlstm import mlstm_seq, mlstm_state_init

    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=64,
                      segments=((("mlstm",), 1),),
                      xlstm=XLSTMConfig(chunk_size=16, proj_factor=2.0))
    B, S, dh = 2, 80, 64   # S deliberately not a multiple of chunk
    H, hd = 4, 16
    x = _rand((B, S, dh))
    p = {
        "wq": _rand((dh, dh)) * 0.3, "wk": _rand((dh, dh)) * 0.3,
        "wv": _rand((dh, dh)) * 0.3,
        "w_if": _rand((dh, 2 * H)) * 0.3,
        "b_i": jnp.zeros((H,)), "b_f": jnp.full((H,), 3.0),
    }
    y, _ = mlstm_seq(p, x, cfg, mlstm_state_init(cfg, B))
    # oracle on the same projected q/k/v
    to_heads = lambda t: t.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    q = to_heads(x @ p["wq"]).astype(jnp.float32)
    k = to_heads(x @ p["wk"]).astype(jnp.float32)
    v = to_heads(x @ p["wv"]).astype(jnp.float32)
    gif = (x @ p["w_if"]).astype(jnp.float32).reshape(B, S, 2, H)
    i_pre = gif[:, :, 0].transpose(0, 2, 1) + p["b_i"][None, :, None]
    f_pre = gif[:, :, 1].transpose(0, 2, 1) + p["b_f"][None, :, None]
    href = mlstm_ref(q, k, v, i_pre, f_pre)
    yref = href.transpose(0, 2, 1, 3).reshape(B, S, dh)
    # chunked vs sequential differ only in fp32 accumulation order
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32) - yref))) < 2e-2
