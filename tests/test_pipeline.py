"""Concurrent serving pipeline: batched gate ≡ sequential gate,
pipeline harness ≡ sequential harness, engine prefix-cache correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.accounting import TokenLedger
from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier
from repro.core.intents import INTENTS, build_intent_map
from repro.core.planner import PlannerConfig, ScriptedPlanner
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.evaluator import evaluate
from repro.env.tasks import make_benchmark
from repro.env.world import build_world
from repro.models.model import init_params, prefill, prefill_extend
from repro.serving.engine import InferenceEngine
from repro.serving.neural_planner import (BatchedNeuralIntentClassifier,
                                          NeuralIntentClassifier)
from repro.serving.pipeline import (GeckOptPipeline, PipelineConfig,
                                    evaluate_pipeline)
from repro.serving.sampling import SamplerConfig
from repro.serving.tokenizer import TOKENIZER


@pytest.fixture(scope="module")
def planner():
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def world():
    return build_world(0)


@pytest.fixture(scope="module")
def tasks(world):
    return make_benchmark(world, 32)


@pytest.fixture(scope="module")
def intent_map(tasks):
    return build_intent_map(tasks, DEFAULT_REGISTRY)


# ------------------------------------------------- batched gate scoring ----

def test_batched_classifier_matches_sequential(planner, tasks):
    """One (Q*8, L) forward pass must make the SAME intent decisions as
    the Q*8 sequential B=1 calls on the same params."""
    cfg, params = planner
    queries = [t.query for t in tasks[:10]]
    seq = NeuralIntentClassifier(cfg, params)
    bat = BatchedNeuralIntentClassifier(cfg, params)
    a = [seq.classify(q) for q in queries]
    b = bat.classify_batch(queries)
    assert a == b
    # odd wave sizes go through the pad path; decisions must not change
    assert bat.classify_batch(queries[:3]) == a[:3]
    assert bat.classify(queries[0]) == a[0]


def test_batched_classifier_loss_matrix_shape(planner, tasks):
    cfg, params = planner
    bat = BatchedNeuralIntentClassifier(cfg, params)
    losses = bat.losses([t.query for t in tasks[:3]])
    assert losses.shape == (3, len(INTENTS))
    assert np.isfinite(losses).all()


def test_gate_batch_matches_sequential_calls(intent_map):
    """IntentGate.batch must reproduce the sequential rng stream and the
    sequential per-query ledger charges."""
    queries = [f"plot images of region {i}" for i in range(9)]
    libs = DEFAULT_REGISTRY.libraries()
    g1 = IntentGate(intent_map, ScriptedIntentClassifier(
        0.7, np.random.default_rng(3)), libs)
    g2 = IntentGate(intent_map, ScriptedIntentClassifier(
        0.7, np.random.default_rng(3)), libs)
    led1 = [TokenLedger() for _ in queries]
    led2 = [TokenLedger() for _ in queries]
    seq = [g1(q, l) for q, l in zip(queries, led1)]
    bat = g2.batch(queries, led2)
    assert seq == bat
    for a, b in zip(led1, led2):
        assert [(e.kind, e.prompt_tokens, e.completion_tokens)
                for e in a.entries] == \
               [(e.kind, e.prompt_tokens, e.completion_tokens)
                for e in b.entries]


# --------------------------------------------- pipeline ≡ sequential -------

@pytest.mark.parametrize("gated", [True, False])
def test_pipeline_metrics_identical_to_sequential(world, tasks,
                                                  intent_map, gated):
    """N concurrent sessions must produce the same Table-2 metrics as
    the sequential harness at the same seed: per-session state is
    isolated and admission keeps the classifier's rng stream in task
    order."""
    cfg = PlannerConfig(mode="react", few_shot=False)
    libs = DEFAULT_REGISTRY.libraries()

    def agent():
        gate = IntentGate(intent_map, ScriptedIntentClassifier(
            0.97, np.random.default_rng(0)), libs) if gated else None
        return Agent(DEFAULT_REGISTRY, world, cfg, gate=gate, seed=0)

    seq = evaluate(agent(), tasks, "seq")
    par = evaluate_pipeline(agent(), tasks, "par", max_concurrent=7)
    assert seq.row() == par.row()
    assert seq.tokens_per_task == par.tokens_per_task
    assert seq.gate_tokens == par.gate_tokens


def test_pipeline_respects_concurrency_cap(world, tasks, intent_map):
    cfg = PlannerConfig(mode="cot", few_shot=False)
    gate = IntentGate(intent_map, ScriptedIntentClassifier(
        0.97, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
    pipe = GeckOptPipeline(Agent(DEFAULT_REGISTRY, world, cfg, gate=gate,
                                 seed=0),
                           PipelineConfig(max_concurrent=5))
    results = pipe.run(tasks)
    assert len(results) == len(tasks)
    assert pipe.stats.peak_concurrent <= 5
    assert pipe.stats.admitted == len(tasks)
    # every admission wave was gated in one batched call
    assert sum(pipe.stats.gate_batch_sizes) == len(tasks)


def test_run_task_unchanged_by_session_refactor(world, tasks):
    """run_task (start/step/finish composed) still matches a hand-rolled
    session drive."""
    cfg = PlannerConfig(mode="react", few_shot=True)
    a1 = Agent(DEFAULT_REGISTRY, world, cfg, gate=None, seed=0)
    r1 = a1.run_task(tasks[0], task_seed=0)
    s = a1.start_session(tasks[0], task_seed=0)
    while not a1.step_session(s):
        pass
    r2 = s.result()
    assert r1.ledger.total_tokens == r2.ledger.total_tokens
    assert r1.steps == r2.steps
    assert r1.executed_tools == r2.executed_tools
    assert r1.completed_plan == r2.completed_plan


# ---------------------------------------- cross-session fused execution ----

@pytest.mark.parametrize("accuracy", [0.97, 0.0])
def test_fused_pipeline_identical_to_solo_compiled(world, tasks,
                                                   intent_map, accuracy):
    """With the tool-graph compiler on, the pipeline fuses every
    co-resident session's DAG into one batched execution per tick; each
    session's TaskResult (ledger included) must be bitwise identical to
    running it alone. accuracy=0.0 forces the TOOL_NOT_FOUND fallback
    path through the fused tick."""
    cfg = PlannerConfig(mode="react", few_shot=False, compile_plans=True)
    libs = DEFAULT_REGISTRY.libraries()

    def agent():
        gate = IntentGate(intent_map, ScriptedIntentClassifier(
            accuracy, np.random.default_rng(0)), libs)
        return Agent(DEFAULT_REGISTRY, world, cfg, gate=gate, seed=0)

    a = agent()
    solo = [a.run_task(t, task_seed=i) for i, t in enumerate(tasks)]
    pipe = GeckOptPipeline(agent(), PipelineConfig(max_concurrent=6,
                                                   engine_turns=False))
    fused = pipe.run(tasks)
    assert len(fused) == len(solo)
    for s, f in zip(solo, fused):
        assert s.executed_tools == f.executed_tools
        assert s.completed_plan == f.completed_plan
        assert s.fallback_used == f.fallback_used
        assert s.intent_predicted == f.intent_predicted
        assert [(e.kind, e.prompt_tokens, e.completion_tokens,
                 e.tool_calls, e.virtual_steps)
                for e in s.ledger.entries] == \
               [(e.kind, e.prompt_tokens, e.completion_tokens,
                 e.tool_calls, e.virtual_steps)
                for e in f.ledger.entries]
        assert s.workspace.rng.bit_generator.state == \
            f.workspace.rng.bit_generator.state
    # the fused path actually ran, and round-trips beat virtual steps
    assert pipe.stats.fused_batches > 0
    assert pipe.stats.fused_sessions_peak > 1
    assert pipe.stats.plan_round_trips < pipe.stats.plan_virtual_steps
    if accuracy == 0.0:
        # the misrouted regime exercised the fallback under fusion
        assert sum(r.fallback_used for r in fused) > 0


def test_fused_wave_error_does_not_poison_siblings(world):
    """A ToolError inside one session's graph must leave a co-fused
    sibling session's observations and workspace bitwise identical to
    its solo run."""
    from repro.core.toolgraph import compile_calls
    from repro.env.tasks import ToolCall
    from repro.env.tools_impl import (TOOL_EFFECTS, Workspace,
                                      execute_graph, execute_graph_batch)
    bad = compile_calls([ToolCall("detect_objects", {})],
                        TOOL_EFFECTS)          # no handles -> ToolError
    good = compile_calls([ToolCall("load_images", {"image_ids": []}),
                          ToolCall("wiki_search", {"query": "port"}),
                          ToolCall("plot_map", {})], TOOL_EFFECTS)

    solo_ws = Workspace(world=world, rng=np.random.default_rng(5))
    solo_obs = [(o.node_id, o.text, o.ok)
                for o in execute_graph(solo_ws, good)]
    ws_a = Workspace(world=world, rng=np.random.default_rng(9))
    ws_b = Workspace(world=world, rng=np.random.default_rng(5))
    out = execute_graph_batch([(0, ws_a, bad), (1, ws_b, good)])
    assert not out[0][0].ok and "ERROR" in out[0][0].text
    assert [(o.node_id, o.text, o.ok) for o in out[1]] == solo_obs
    assert ws_b.rng.bit_generator.state == solo_ws.rng.bit_generator.state
    assert (ws_b.handles, ws_b.map_layers, ws_b.last_answer) == \
        (solo_ws.handles, solo_ws.map_layers, solo_ws.last_answer)


def test_fused_pipeline_leaves_world_untouched(world, tasks, intent_map):
    """Cross-session fusion is only sound because the World is
    read-only; the fingerprint must not move across a fused run."""
    cfg = PlannerConfig(mode="cot", few_shot=False, compile_plans=True)
    gate = IntentGate(intent_map, ScriptedIntentClassifier(
        0.97, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
    before = world.fingerprint()
    GeckOptPipeline(Agent(DEFAULT_REGISTRY, world, cfg, gate=gate,
                          seed=0),
                    PipelineConfig(max_concurrent=8,
                                   engine_turns=False)).run(tasks[:12])
    assert world.fingerprint() == before


# ------------------------------------------------- engine prefix cache ----

def test_engine_prefix_cache_outputs_identical(planner):
    """Requests served off a cached prefix prefill must emit exactly the
    greedy tokens of a full per-request prefill."""
    cfg, params = planner
    prefix = ("You are the intent router of a geospatial Copilot "
              "platform. Classify the user query into exactly one "
              "intent and reply with the intent name only.")
    queries = ["plot sentinel2 images around Tampa Bay",
               "how many ships are docked near Singapore",
               "transcribe the meeting recording"]

    def serve(use_prefix):
        eng = InferenceEngine(cfg, params, max_batch=2, cache_len=256,
                              seed=0)
        if use_prefix:
            eng.register_prefix("gate", prefix)
        rids = [eng.add_request(f"{prefix} Query: {q}", max_new_tokens=4,
                                sampler=SamplerConfig(temperature=0.0),
                                prefix_key="gate" if use_prefix else None)
                for q in queries]
        done = {r.request_id: r.output for r in eng.run_until_done()}
        return [done[r] for r in rids], eng.throughput_stats()

    base, _ = serve(False)
    cached, stats = serve(True)
    assert base == cached
    assert stats["prefix_hits"] == len(queries)
    assert stats["prefix_tokens_saved"] > 0
    # only the one prefix prefill ran; every request rode the cache
    assert stats["prefills"] == 1


def test_engine_prefix_near_cache_end(planner):
    """Bucket padding must not write past cache_len: a suffix whose
    power-of-two pad would overflow the cache (dynamic_update_slice
    clamps the start and would corrupt prefix rows) is capped to the
    remaining room."""
    cfg, params = planner
    prefix_text = " ".join(["alpha beta gamma delta"] * 10)
    suffix_text = " " + " ".join(["query word"] * 10)

    def run(use_prefix):
        eng = InferenceEngine(cfg, params, max_batch=2, cache_len=64,
                              seed=0)
        if use_prefix:
            eng.register_prefix("p", prefix_text)
        eng.add_request(prefix_text + suffix_text, max_new_tokens=2,
                        sampler=SamplerConfig(temperature=0.0),
                        prefix_key="p" if use_prefix else None)
        return [r.output for r in eng.run_until_done()]

    assert run(False) == run(True)


def test_engine_prefix_miss_falls_back(planner):
    """A request whose prompt does not start with the registered prefix
    must be prefilled in full, not silently mis-served."""
    cfg, params = planner
    eng = InferenceEngine(cfg, params, max_batch=2, cache_len=256)
    eng.register_prefix("gate", "the registered system prefix")
    eng.add_request("a completely different prompt", max_new_tokens=2,
                    sampler=SamplerConfig(temperature=0.0),
                    prefix_key="gate")
    done = eng.run_until_done()
    assert len(done) == 1
    assert eng.throughput_stats()["prefix_hits"] == 0


def test_prefill_extend_matches_full_prefill(planner):
    """Chunked prefill: prefix prefill + multi-token extend must agree
    with one full prefill (greedy next token)."""
    cfg, params = planner
    ids = TOKENIZER.encode_with_specials(
        "classify intent: plot sentinel2 images around Tampa Bay => ")
    cut = len(ids) // 2
    full_logits, _ = prefill(params, cfg,
                             {"tokens": jnp.asarray(ids, jnp.int32)[None]},
                             cache_len=128)
    head_logits, cache = prefill(
        params, cfg, {"tokens": jnp.asarray(ids[:cut], jnp.int32)[None]},
        cache_len=128)
    cache = dict(cache)
    cache["pos"] = jnp.asarray(cut, jnp.int32)
    ext_logits, cache = prefill_extend(
        params, cfg, cache,
        {"tokens": jnp.asarray(ids[cut:], jnp.int32)[None]})
    assert int(cache["pos"]) == len(ids)
    assert int(jnp.argmax(full_logits[0])) == int(jnp.argmax(ext_logits[0]))


def test_prefill_extend_pad_bucket_equivalent(planner):
    """Bucket-padded extend (n_valid < S) must give the same logits
    position and cache pos as the exact-length call."""
    cfg, params = planner
    ids = TOKENIZER.encode_with_specials("plot images of Rotterdam")
    cut = 3

    def extended(pad):
        _, cache = prefill(
            params, cfg,
            {"tokens": jnp.asarray(ids[:cut], jnp.int32)[None]},
            cache_len=64)
        cache = dict(cache)
        cache["pos"] = jnp.asarray(cut, jnp.int32)
        tail = ids[cut:] + [0] * pad
        logits, cache = prefill_extend(
            params, cfg, cache,
            {"tokens": jnp.asarray(tail, jnp.int32)[None]},
            n_valid=len(ids) - cut)
        return logits, int(cache["pos"])

    exact, pos_a = extended(0)
    padded, pos_b = extended(5)
    assert pos_a == pos_b == len(ids)
    assert int(jnp.argmax(exact[0])) == int(jnp.argmax(padded[0]))


# --------------------------------------------------- engine mirroring ----

def test_pipeline_engine_mirroring(planner, world, intent_map):
    """With an engine attached, each gated session's first planner turn
    is served off a shared per-intent prefix."""
    cfg, params = planner
    engine = InferenceEngine(cfg, params, max_batch=2, cache_len=4096)
    tasks = make_benchmark(world, 4)
    gate = IntentGate(intent_map, ScriptedIntentClassifier(
        1.0, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
    agent = Agent(DEFAULT_REGISTRY, world,
                  PlannerConfig(mode="cot", few_shot=False), gate=gate,
                  seed=0)
    pipe = GeckOptPipeline(agent, PipelineConfig(max_concurrent=4,
                                                 engine_max_new_tokens=2),
                           engine=engine)
    results = pipe.run(tasks)
    assert len(results) == 4
    stats = engine.throughput_stats()
    assert pipe.stats.engine_turns == 4
    assert stats["prefix_hits"] == 4          # every turn rode a prefix
    assert len(engine.prefixes) <= 4          # intents shared prefixes
    assert all(es.idle for es in pipe._engine_sessions)
    assert all(len(es.turns) == 1 for es in pipe._engine_sessions)
