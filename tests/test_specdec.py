"""Speculative decoding (draft–verify): the engine's hard invariant is
BITWISE-identical output tokens AND finish reasons between speculative
and non-speculative decoding — at T=0 unconditionally, at T=0.8 for
seeded requests — in dense and paged KV modes, on reference and pallas
backends, and through paged preemption-and-resume. Plus the model-level
contract (verify_extend row r == the r'th sequential decode_step,
bitwise) and the flash_verify kernels against their oracles.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels.flash_verify import flash_verify, flash_verify_paged
from repro.kernels.ref import (paged_verify_attention_ref,
                               verify_attention_ref)
from repro.models.model import (decode_step, init_cache, init_paged_cache,
                                init_params, prefill, verify_extend)
from repro.serving.cluster import EngineCluster
from repro.serving.engine import InferenceEngine, _insert_slot, _paged_scatter
from repro.serving.sampling import SamplerConfig
from repro.serving.specdec import SpecConfig, SpecDecoder

BS = 16                        # paged block size under test
CACHE = 128


@pytest.fixture(scope="module")
def planner():
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def other_draft(planner):
    """An independently-initialized draft: near-zero agreement with the
    target — parity must hold regardless of acceptance."""
    cfg, _ = planner
    return init_params(jax.random.PRNGKey(7), cfg)


@pytest.fixture(scope="module")
def donors(planner):
    """Compile each engine flavor once: [0] plain, [1] spec-enabled."""
    cfg, params = planner
    spec = SpecConfig(draft_cfg=cfg, draft_params=params, k=3)
    return (InferenceEngine(cfg, params, max_batch=2, cache_len=CACHE),
            InferenceEngine(cfg, params, max_batch=2, cache_len=CACHE,
                            spec_decode=spec))


def make_engine(planner, donors, spec=None, **kw):
    cfg, params = planner
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", CACHE)
    eng = InferenceEngine(cfg, params, spec_decode=spec, **kw)
    donor = donors[1] if spec is not None else donors[0]
    if kw["cache_len"] == donor.cache_len and eng.backend == donor.backend:
        eng._prefill, eng._decode, eng._extend = \
            donor._prefill, donor._decode, donor._extend
        if spec is not None:
            eng._verify = donor._verify
            eng.spec.share_compiled(donor.spec)
    return eng


PREFIX = list(range(5, 25))


def _prompts(n, suffix_len=6):
    return [PREFIX + list(range(200 + suffix_len * i,
                                200 + suffix_len * (i + 1)))
            for i in range(n)]


def _serve(eng, prompts, max_new=11, temperature=0.0, seeds=True):
    eng.register_prefix("hot", PREFIX)
    rid_to_idx = {}
    for i, p in enumerate(prompts):
        rid = eng.add_request(
            p, max_new_tokens=max_new,
            sampler=SamplerConfig(temperature=temperature,
                                  top_k=40 if temperature else 0,
                                  seed=500 + i if seeds else None),
            prefix_key="hot")
        rid_to_idx[rid] = i
    done = eng.run_until_done()
    return {rid_to_idx[r.request_id]: (tuple(r.output), r.finish_reason)
            for r in done}


# ------------------------------------------------------- model level ----

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_verify_extend_matches_sequential_decode(planner, backend):
    """verify_extend's W logit rows are bitwise the W sequential
    decode_step logits — dense and paged — on both backends."""
    cfg, params = planner
    B, W = 2, 4
    toks = np.array([[3, 7, 11, 13], [4, 8, 12, 14]], np.int32)
    prompts = [list(range(5, 17)), list(range(30, 39))]

    def build_dense():
        cache = init_cache(cfg, B, CACHE)
        cache["pos"] = jnp.zeros((B,), jnp.int32)
        for b, p in enumerate(prompts):
            _, c1 = prefill(params, cfg,
                            {"tokens": jnp.asarray(p, jnp.int32)[None]},
                            cache_len=CACHE, backend=backend)
            cache = _insert_slot(cache, dict(c1), b)
            cache["pos"] = cache["pos"].at[b].set(len(p))
        return cache

    def build_paged():
        nb = B * CACHE // BS
        cache = init_paged_cache(cfg, B, CACHE, nb, BS)
        for b, p in enumerate(prompts):
            _, c1 = prefill(params, cfg,
                            {"tokens": jnp.asarray(p, jnp.int32)[None]},
                            cache_len=CACHE, backend=backend)
            need = -(-(len(p) + W + 1) // BS)
            ids = np.full((CACHE // BS,), nb, np.int32)
            ids[:need] = range(b * 8, b * 8 + need)
            cache["segments"] = _paged_scatter(
                cache["segments"], c1["segments"], jnp.asarray(ids))
            cache["block_tab"] = cache["block_tab"].at[b].set(
                jnp.asarray(ids))
            cache["pos"] = cache["pos"].at[b].set(len(p))
        return cache

    for build in (build_dense, build_paged):
        cache = build()
        seq, dcache = [], dict(cache)
        for j in range(W):
            lg, dcache = decode_step(
                params, cfg, dcache,
                {"tokens": jnp.asarray(toks[:, j:j + 1])},
                backend=backend)
            seq.append(np.asarray(lg))
        seq = np.stack(seq, axis=1)
        vlg, vcache = verify_extend(params, cfg, cache,
                                    {"tokens": jnp.asarray(toks)},
                                    backend=backend)
        assert np.array_equal(seq, np.asarray(vlg)), build.__name__
        # the written KV rows must match the sequential writes too
        k_seq = np.asarray(dcache["segments"][0][0]["k"])
        k_ver = np.asarray(vcache["segments"][0][0]["k"])
        assert np.array_equal(k_seq, k_ver), build.__name__


def test_verify_pos_rides_unchanged(planner):
    """verify_extend returns pos untouched — the engine owns the
    accepted-length advance (rollback-by-truncation)."""
    cfg, params = planner
    cache = init_cache(cfg, 2, CACHE)
    cache["pos"] = jnp.asarray([5, 9], jnp.int32)
    _, out = verify_extend(params, cfg, cache,
                           {"tokens": jnp.zeros((2, 3), jnp.int32)})
    assert np.array_equal(np.asarray(out["pos"]), [5, 9])


# ----------------------------------------------------------- kernels ----

def test_flash_verify_matches_oracle():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, W, Sk, hd = 3, 4, 2, 5, 64, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, W, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Sk, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Sk, hd)), jnp.float32)
    kv_len = jnp.asarray([7, 40, 64], jnp.int32)
    out = flash_verify(q, k, v, kv_len, interpret=True)
    ref = verify_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_verify_paged_matches_oracle():
    rng = np.random.default_rng(1)
    B, Hq, Hkv, W, hd, nb, bs, mb = 2, 4, 2, 4, 16, 12, 8, 6
    q = jnp.asarray(rng.normal(size=(B, Hq, W, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, Hkv, bs, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, Hkv, bs, hd)), jnp.float32)
    tab = jnp.asarray([[3, 0, 7, nb, nb, nb],
                       [5, 9, 1, 2, 6, nb]], jnp.int32)
    kv_len = jnp.asarray([19, 37], jnp.int32)
    out = flash_verify_paged(q, kp, vp, tab, kv_len, interpret=True)
    ref = paged_verify_attention_ref(q, kp, vp, tab, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------ engine parity ----

@pytest.mark.parametrize("kv_kw", [
    {},
    {"kv_mode": "paged", "kv_blocks": 16, "block_size": BS},
], ids=["dense", "paged"])
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_engine_parity(planner, donors, kv_kw, temperature):
    """Spec and non-spec engines emit bitwise-identical tokens and
    finish reasons, dense and paged, greedy and seeded T=0.8."""
    cfg, params = planner
    prompts = _prompts(5)
    base = _serve(make_engine(planner, donors, **kv_kw), prompts,
                  temperature=temperature)
    spec = SpecConfig(draft_cfg=cfg, draft_params=params, k=3)
    so = _serve(make_engine(planner, donors, spec=spec, **kv_kw),
                prompts, temperature=temperature)
    assert base == so


def test_engine_parity_pallas(planner, donors):
    """Parity through the flash_verify kernels (interpret mode): the
    fused verify read must reproduce flash_decode's bits row by row."""
    cfg, params = planner
    spec = SpecConfig(draft_cfg=cfg, draft_params=params, k=2)
    prompts = _prompts(2)
    for kv_kw in ({}, {"kv_mode": "paged", "kv_blocks": 16,
                       "block_size": BS}):
        base = _serve(make_engine(planner, donors, backend="pallas",
                                  **kv_kw), prompts, max_new=6)
        so = _serve(make_engine(planner, donors, spec=spec,
                                backend="pallas", **kv_kw), prompts,
                    max_new=6)
        assert base == so, kv_kw


def test_parity_survives_zero_agreement(planner, donors, other_draft):
    """A draft that never matches the target still yields exact outputs
    — acceptance only modulates speed. (Random independent weights:
    accept rate ~1/vocab.)"""
    cfg, params = planner
    prompts = _prompts(4)
    base = _serve(make_engine(planner, donors), prompts)
    spec = SpecConfig(draft_cfg=cfg, draft_params=other_draft, k=3)
    eng = make_engine(planner, donors, spec=spec)
    so = _serve(eng, prompts)
    assert base == so
    st = eng.throughput_stats()
    assert st["spec_accept_rate"] < 0.5
    # every round still emits >= 1 token per busy slot
    assert st["tokens_per_step"] >= 1.0


def test_parity_through_preempt_resume(planner, donors):
    """Paged spec decoding under memory pressure: preemptions fire, the
    draft cache is rebuilt on resume, outputs stay identical to the
    dense spec run. (Same pressure shape as the non-spec
    test_paged_engine preempt test: 3 long prompts vs a 7-block pool.)"""
    cfg, params = planner
    spec = SpecConfig(draft_cfg=cfg, draft_params=params, k=3)

    def run(**kw):
        eng = make_engine(planner, donors, spec=spec, **kw)
        rid_to_idx = {}
        for i in range(3):
            rid = eng.add_request(
                list(range(5, 45)), max_new_tokens=24,
                sampler=SamplerConfig(temperature=0.8, top_k=40,
                                      seed=77 + i))
            rid_to_idx[rid] = i
        done = eng.run_until_done()
        return {rid_to_idx[r.request_id]: (tuple(r.output),
                                           r.finish_reason)
                for r in done}, eng

    dense, _ = run()
    paged, eng = run(kv_mode="paged", kv_blocks=7, block_size=BS)
    assert eng.stats["preemptions"] > 0
    assert eng.stats["resumes"] == eng.stats["preemptions"]
    assert dense == paged


# -------------------------------------------------- speedup and stats ----

def test_self_draft_speedup_and_stats(planner, donors):
    """Perfect-agreement draft at T=0: accept rate 1.0 when windows
    never truncate, tokens/step > 1.5x the non-speculative run (the
    bench's acceptance bar, asserted here too)."""
    cfg, params = planner
    prompts = _prompts(4)
    base_eng = make_engine(planner, donors)
    base = _serve(base_eng, prompts, max_new=12)       # 12 = 3 windows
    spec = SpecConfig(draft_cfg=cfg, draft_params=params, k=3)
    eng = make_engine(planner, donors, spec=spec)
    so = _serve(eng, prompts, max_new=12)
    assert base == so
    st = eng.throughput_stats()
    bst = base_eng.throughput_stats()
    assert st["spec_accept_rate"] == 1.0
    assert st["spec_rounds"] == st["decode_steps"]
    assert st["spec_drafted"] > 0 and st["spec_drafted"] % 3 == 0
    assert st["tokens_per_step"] > 1.5 * bst["tokens_per_step"]
    assert st["spec_k"] == 3 and bst["spec_k"] == 0


def test_oversized_prompt_refused_upfront(planner, donors):
    """With spec on, a dense-mode prompt that can never fit finishes
    "cache_len" at admission (paged semantics) instead of crashing the
    draft admit or emitting clamped-overflow tokens."""
    cfg, params = planner
    spec = SpecConfig(draft_cfg=cfg, draft_params=params, k=2)
    eng = make_engine(planner, donors, spec=spec)
    rid = eng.add_request(list(range(5, 5 + CACHE + 10)),
                          max_new_tokens=4)
    done = eng.run_until_done()
    assert len(done) == 1 and done[0].request_id == rid
    assert done[0].finish_reason == "cache_len"
    assert done[0].output == []
    assert eng.is_idle()


def test_engine_reset_with_spec(planner, donors):
    cfg, params = planner
    spec = SpecConfig(draft_cfg=cfg, draft_params=params, k=3)
    eng = make_engine(planner, donors, spec=spec)
    first = _serve(eng, _prompts(3))
    eng.reset()
    assert eng.stats["spec_rounds"] == 0
    again = _serve(eng, _prompts(3))
    assert first == again


# ---------------------------------------------------------- cluster ----

def test_cluster_spec_aggregates(planner, donors):
    cfg, params = planner
    spec = SpecConfig(draft_cfg=cfg, draft_params=params, k=3)
    cluster = EngineCluster(cfg, params, 2, max_batch=2,
                            cache_len=CACHE, router="round_robin",
                            spec_decode=spec)
    for e in cluster.replicas:      # reuse the donor's compiled steps
        e._prefill, e._decode, e._extend = \
            donors[1]._prefill, donors[1]._decode, donors[1]._extend
        e._verify = donors[1]._verify
        e.spec.share_compiled(donors[1].spec)
    assert cluster.spec_k == 3
    for i, p in enumerate(_prompts(6)):
        cluster.submit(p, max_new_tokens=8,
                       sampler=SamplerConfig(seed=i))
    cluster.run_until_done()
    agg = cluster.throughput_stats()
    assert agg["spec_rounds"] > 0
    assert agg["spec_accept_rate"] == 1.0
    assert agg["tokens_per_step"] > 1.5
    assert agg["spec_k"] == 3


def test_cluster_engines_kwarg_refuses_spec(planner):
    cfg, params = planner
    eng = InferenceEngine(cfg, params, max_batch=2, cache_len=CACHE)
    spec = SpecConfig(draft_cfg=cfg, draft_params=params, k=2)
    with pytest.raises(ValueError, match="spec_decode"):
        EngineCluster(engines=[eng], spec_decode=spec)


# -------------------------------------------------------- validation ----

def test_spec_config_validation(planner):
    cfg, params = planner
    with pytest.raises(ValueError, match="k >= 1"):
        InferenceEngine(cfg, params, max_batch=2, cache_len=CACHE,
                        spec_decode=SpecConfig(draft_cfg=cfg,
                                               draft_params=params,
                                               k=0))


def test_spec_rejects_recurrent_stacks(planner):
    cfg, params = planner
    xcfg = get_smoke_config("xlstm-125m")
    xparams = init_params(jax.random.PRNGKey(0), xcfg)
    # recurrent TARGET: state cannot be rolled back by truncation
    with pytest.raises(ValueError, match="pure-attention"):
        InferenceEngine(xcfg, xparams, max_batch=2, cache_len=CACHE,
                        spec_decode=SpecConfig(draft_cfg=xcfg,
                                               draft_params=xparams,
                                               k=2))
    # recurrent DRAFT: same constraint
    with pytest.raises(ValueError, match="pure-attention"):
        SpecDecoder(SpecConfig(draft_cfg=xcfg, draft_params=xparams,
                               k=2),
                    max_batch=2, cache_len=CACHE, backend="reference")
