"""Paged KV-cache subsystem, end to end: the engine's hard invariant is
BITWISE-identical tokens between dense and paged modes — through prefix
sharing, admission gating, preemption-and-resume and eviction — plus
the paged flash-decode kernel against its gather oracle.

Prompts are explicit id lists (fixed lengths => few prefill retraces);
requests carry sampler seeds at T=0.8, so outputs are a pure function
of (prompt, seed) and any divergence is a memory-manager bug, not
sampling noise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels.flash_decode_paged import flash_decode_paged
from repro.kernels.ref import paged_decode_attention_ref
from repro.models.model import init_params
from repro.serving.engine import InferenceEngine
from repro.serving.sampling import SamplerConfig

BS = 16                       # block_size under test; cache_len = 128


@pytest.fixture(scope="module")
def planner():
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def base_engine(planner):
    """Compile the jitted steps once for cache_len=128 (both the dense
    and the paged cache structures trace through the same closures)."""
    cfg, params = planner
    return InferenceEngine(cfg, params, max_batch=2, cache_len=128)


def make_engine(planner, base, **kw):
    cfg, params = planner
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 128)
    eng = InferenceEngine(cfg, params, **kw)
    if kw["cache_len"] == base.cache_len:
        eng._prefill, eng._decode, eng._extend = \
            base._prefill, base._decode, base._extend
    return eng


PREFIX = list(range(5, 53))                  # 48 tokens = 3 full blocks


def _submit(eng, n=4, max_new=6, with_prefix=True, prompt_extra=0):
    if with_prefix:
        eng.register_prefix("p", PREFIX)
    for i in range(n):
        suffix = list(range(200 + 8 * i, 208 + 8 * i + prompt_extra))
        eng.add_request(PREFIX + suffix if with_prefix else suffix,
                        max_new_tokens=max_new,
                        sampler=SamplerConfig(temperature=0.8, top_k=40,
                                              seed=1000 + i),
                        prefix_key="p" if with_prefix else None)


def _outputs(eng):
    done = eng.run_until_done()
    return {r.request_id: (tuple(r.output), r.finish_reason)
            for r in done}


# ----------------------------------------------------- bitwise parity ----

def test_dense_vs_paged_bitwise_parity_with_prefix_sharing(
        planner, base_engine):
    """Same workload, same seeds: the paged engine (CoW-shared prefix
    blocks) emits exactly the dense engine's tokens at T=0.8."""
    dense = make_engine(planner, base_engine)
    _submit(dense)
    paged = make_engine(planner, base_engine, kv_mode="paged",
                        block_size=BS)
    _submit(paged)
    assert _outputs(dense) == _outputs(paged)
    st = paged.stats
    assert st["prefix_hits"] == 4 and st["preemptions"] == 0
    # the accounting invariant of the dense engine carries over
    assert st["admissions"] == st["prefix_hits"] + st["prefills"] \
        - st["prefix_registrations"]


def test_prefix_blocks_are_shared_not_copied(planner, base_engine):
    """While prefix-tagged requests are in flight, the prefix's three
    full blocks are refcount-shared — held once, by everyone."""
    eng = make_engine(planner, base_engine, kv_mode="paged",
                      block_size=BS)
    _submit(eng, n=2, max_new=8)
    eng.step()                                  # both admitted, in flight
    ks = eng.kv_memory_stats()
    assert ks["kv_blocks_shared"] == len(PREFIX) // BS == 3
    assert eng.stats["prefix_hits"] == 2
    eng.run_until_done()
    # drained: only the pinned prefix survives, nothing shared anymore
    ks = eng.kv_memory_stats()
    assert ks["kv_blocks_shared"] == 0
    assert ks["kv_blocks_used"] == eng.pool.blocks_needed(len(PREFIX))
    assert ks["kv_blocks_shared_peak"] >= 3


def test_preempt_resume_is_bit_reproducible(planner, base_engine):
    """A pool too small for the batch forces preempt-and-requeue; the
    swap round-trip must not change a single token vs dense."""
    def run(kv_mode, **kw):
        eng = make_engine(planner, base_engine, kv_mode=kv_mode, **kw)
        for i in range(3):
            eng.add_request(list(range(5, 45)), max_new_tokens=24,
                            sampler=SamplerConfig(temperature=0.8,
                                                  top_k=40,
                                                  seed=77 + i))
        return _outputs(eng), eng
    d, _ = run("dense")
    p, eng = run("paged", block_size=BS, kv_blocks=7)
    assert eng.stats["preemptions"] > 0
    assert eng.stats["resumes"] == eng.stats["preemptions"]
    assert d == p


def test_admission_waits_for_free_blocks(planner, base_engine):
    """Paged admission is gated on free blocks: with room for one
    request only, the second WAITS in queue (no drop, no preemption),
    runs after the first frees its blocks, and still emits the dense
    engine's seeded tokens."""
    dense = make_engine(planner, base_engine)
    _submit(dense, n=2, max_new=4, with_prefix=False, prompt_extra=20)
    d = _outputs(dense)
    eng = make_engine(planner, base_engine, kv_mode="paged",
                      block_size=BS, kv_blocks=3)   # one 28-tok prompt
    _submit(eng, n=2, max_new=4, with_prefix=False, prompt_extra=20)
    eng.step()
    assert eng.busy_slots() == 1 and eng.queue_depth() == 1
    p = _outputs(eng)
    assert p == d
    assert eng.stats["preemptions"] == 0


def test_oversize_prompt_finishes_cache_len_not_crash(planner):
    """A prompt at/over the logical cache_len cannot take a single
    decode write; paged mode refuses it up front with 'cache_len'
    (dense truncates and dies with the same reason), and the boundary
    prompt (cache_len - 1) still runs off the end of its table
    cleanly."""
    cfg, params = planner
    eng = InferenceEngine(cfg, params, max_batch=2, cache_len=64,
                          kv_mode="paged", block_size=BS)
    eng.add_request(list(range(5, 75)), max_new_tokens=4)    # 70 tokens
    eng.add_request(list(range(5, 68)), max_new_tokens=8)    # 63 tokens
    done = {r.request_id: r for r in eng.run_until_done()}
    assert done[0].finish_reason == "cache_len" and not done[0].output
    assert done[1].finish_reason == "cache_len"
    assert eng.pool.free_blocks() == eng.pool.n_blocks


def test_kv_oom_finishes_impossible_requests(planner, base_engine):
    """A request that can never fit the physical pool finishes with
    finish_reason='kv_oom' instead of deadlocking the queue."""
    # a deterministic injected clock (the engine never reads wall time
    # itself): strictly positive, monotonically increasing stamps
    ticks = iter(range(1, 10_000))
    eng = make_engine(planner, base_engine, kv_mode="paged",
                      block_size=BS, kv_blocks=2,
                      clock=lambda: float(next(ticks)))
    eng.add_request(list(range(5, 60)), max_new_tokens=4)   # needs 4 blk
    eng.add_request(list(range(5, 25)), max_new_tokens=2)   # fits
    done = {r.request_id: r for r in eng.run_until_done()}
    assert done[0].finish_reason == "kv_oom" and done[0].output == []
    assert done[1].finish_reason in ("eos", "max_new_tokens")
    # finished without ever sampling: no 0.0 first_token_t sentinel for
    # downstream TTFT math
    assert done[0].first_token_t == done[0].finish_t > 0


def test_infeasible_reservation_leaves_pins_alone(planner, base_engine):
    """_reserve evicts prefix pins only when eviction can actually
    satisfy the request — pins are never re-established, so destroying
    them for an unsatisfiable reservation would permanently end
    zero-copy sharing for nothing."""
    eng = make_engine(planner, base_engine, kv_mode="paged",
                      block_size=BS, kv_blocks=8)
    eng.register_prefix("pin", PREFIX)                 # 3 blocks, pinned
    eng.add_request(list(range(5, 69)), max_new_tokens=4,
                    sampler=SamplerConfig(seed=1))     # 64 tok -> 5 blk
    eng.step()                                         # pool now full
    eng.add_request(list(range(5, 70)), max_new_tokens=2,
                    sampler=SamplerConfig(seed=2))     # needs 5 blocks
    eng.step()
    # evicting the 3-block pin could never yield the 5 blocks the head
    # needs: the head waits and the pin survives untouched
    assert eng.queue_depth() == 1
    assert set(eng._prefix_tables) == {"pin"}
    assert eng.stats["prefix_evictions"] == 0
    done = eng.run_until_done()
    assert len(done) == 2 and eng.stats["prefix_evictions"] == 0


def test_cold_prefix_pins_are_lru_evicted(planner, base_engine):
    """Pinning a second prefix in a pool that can hold only one evicts
    the least-recently-used pin; the evicted prefix still serves hits
    (staged prefill), just without block sharing."""
    eng = make_engine(planner, base_engine, kv_mode="paged",
                      block_size=BS, kv_blocks=5)
    eng.register_prefix("a", PREFIX)                   # pins 3 blocks
    eng.register_prefix("b", list(range(60, 108)))     # needs the room
    assert eng.stats["prefix_evictions"] == 1
    assert set(eng._prefix_tables) == {"b"}
    eng.add_request(PREFIX + [200, 201], max_new_tokens=2,
                    sampler=SamplerConfig(seed=5), prefix_key="a")
    done = eng.run_until_done()
    assert eng.stats["prefix_hits"] == 1               # hit, unshared
    assert done[0].finish_reason in ("eos", "max_new_tokens")


# ------------------------------------------------------- kv accounting ----

def test_dense_mode_refuses_paged_sizing_kwargs(planner):
    """kv_blocks/block_size would be silently dropped in dense mode —
    refuse them, like EngineCluster refuses sizing kwargs with
    engines=."""
    cfg, params = planner
    with pytest.raises(ValueError, match="paged"):
        InferenceEngine(cfg, params, max_batch=2, cache_len=128,
                        block_size=BS)
    with pytest.raises(ValueError, match="paged"):
        InferenceEngine(cfg, params, max_batch=2, cache_len=128,
                        kv_blocks=8)


def test_kv_memory_stats_schema_both_modes(planner, base_engine):
    dense = make_engine(planner, base_engine)
    paged = make_engine(planner, base_engine, kv_mode="paged",
                        block_size=BS)
    dks, pks = dense.kv_memory_stats(), paged.kv_memory_stats()
    assert set(dks) == set(pks)
    # same model, same logical capacity => same physical reservation by
    # default (kv_blocks defaults to the dense budget)
    assert dks["kv_bytes_allocated"] == pks["kv_bytes_allocated"] > 0
    _submit(dense, n=2, with_prefix=False)
    dense.run_until_done()
    dks = dense.kv_memory_stats()
    assert dks["kv_bytes_peak"] == 2 * (dks["kv_bytes_allocated"] // 2)
    assert dense.throughput_stats()["kv_mode"] == "dense"


# ------------------------------------------------- paged kernel parity ----

def test_flash_decode_paged_matches_gather_oracle():
    rng = np.random.default_rng(3)
    B, Hq, Hkv, hd, nb, bs, mb = 3, 8, 2, 64, 12, 16, 4
    q = jnp.asarray(rng.standard_normal((B, Hq, hd), dtype=np.float32))
    kp = jnp.asarray(rng.standard_normal((nb, Hkv, bs, hd),
                                         dtype=np.float32) * 0.5
                     ).astype(jnp.bfloat16)
    vp = jnp.asarray(rng.standard_normal((nb, Hkv, bs, hd),
                                         dtype=np.float32) * 0.5
                     ).astype(jnp.bfloat16)
    tab = jnp.asarray(rng.permutation(nb)[:B * mb].reshape(B, mb)
                      .astype(np.int32))
    for kv_len, cap in (([17, 33, 64], 0.0), ([1, 16, 48], 30.0)):
        kvl = jnp.asarray(kv_len, jnp.int32)
        ref = paged_decode_attention_ref(q, kp, vp, tab, kvl, cap=cap)
        out = flash_decode_paged(q, kp, vp, tab, kvl, cap=cap,
                                 interpret=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=2e-2, rtol=2e-2)


def test_sentinel_table_entries_are_harmless():
    """Out-of-table sentinel entries (>= n_blocks) clamp in both the
    kernel and the oracle; rows past kv_len never contribute."""
    rng = np.random.default_rng(4)
    nb, bs, mb = 6, 16, 4
    q = jnp.asarray(rng.standard_normal((1, 4, 32), dtype=np.float32))
    kp = jnp.asarray(rng.standard_normal((nb, 2, bs, 32),
                                         dtype=np.float32))
    vp = jnp.asarray(rng.standard_normal((nb, 2, bs, 32),
                                         dtype=np.float32))
    tab_a = jnp.asarray([[2, 4, nb, nb]], jnp.int32)    # sentinels
    tab_b = jnp.asarray([[2, 4, 0, 1]], jnp.int32)      # arbitrary
    kvl = jnp.asarray([20], jnp.int32)                  # < 2 blocks
    for fn in (paged_decode_attention_ref,
               lambda *a, **k: flash_decode_paged(*a, interpret=True,
                                                  **k)):
        a = np.asarray(fn(q, kp, vp, tab_a, kvl), np.float32)
        b = np.asarray(fn(q, kp, vp, tab_b, kvl), np.float32)
        np.testing.assert_array_equal(a, b)


def test_paged_decode_step_pallas_close_to_reference(
        planner, base_engine):
    """One decode_step over a live mid-flight paged cache: the pallas
    path (paged flash-decode kernel, block-table scalar prefetch) stays
    allclose to the reference path (gather + masked attention) — the
    cross-backend contract; bitwise parity is the DENSE-vs-PAGED
    contract within a backend, covered above."""
    from repro.models.model import decode_step
    cfg, params = planner
    eng = make_engine(planner, base_engine, kv_mode="paged",
                      block_size=BS)
    _submit(eng, n=2, max_new=8)
    eng.step()
    eng.step()                  # a few rows past the shared prefix
    batch = {"tokens": eng._last_tokens}
    ref, _ = decode_step(params, cfg, eng.cache, batch,
                         backend="reference")
    pal, _ = decode_step(params, cfg, eng.cache, batch,
                         backend="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=5e-2, rtol=5e-2)
