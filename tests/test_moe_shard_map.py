"""Numerical equivalence of the shard_map expert-parallel MoE dispatch.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(device count locks at first jax init, so the main pytest process — which
must see ONE device for every other test — cannot host it)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.common.config import ModelConfig, MoEConfig
from repro.common.perf import PerfFlags, set_flags
from repro.models import moe as M

cfg = ModelConfig(
    name="t", family="moe", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=2, d_head=16, d_ff=0, vocab_size=64,
    segments=((("moe",), 2),), mlp_act="silu_glu",
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=48,
                  capacity_factor=8.0))   # big: no token drops
p = M.moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)

mesh = jax.make_mesh((2, 4), ("data", "model"))
set_flags(PerfFlags())
y_ref, aux_ref = M.moe_ffn(p, x, cfg, dispatch="einsum")

with mesh:
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    ps = jax.device_put(p, NamedSharding(mesh, P()))
    fn = jax.jit(lambda p, x: M.moe_ffn(p, x, cfg, dispatch="shard_map"))
    y_sm, aux_sm = fn(ps, xs)

np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sm),
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(float(aux_ref), float(aux_sm), rtol=1e-5)

# gradients too
def loss_einsum(p, x):
    return M.moe_ffn(p, x, cfg, dispatch="einsum")[0].sum()
def loss_sm(p, x):
    return M.moe_ffn(p, x, cfg, dispatch="shard_map")[0].sum()
g_ref = jax.grad(loss_einsum)(p, x)
with mesh:
    g_sm = jax.jit(jax.grad(loss_sm))(ps, xs)
for k in ("w_gate", "w_up", "w_down"):
    np.testing.assert_allclose(np.asarray(g_ref[k]), np.asarray(g_sm[k]),
                               rtol=5e-4, atol=5e-4)
print("SHARD_MAP_OK")
"""


def test_shard_map_dispatch_matches_einsum():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARD_MAP_OK" in out.stdout, (out.stdout[-2000:],
                                          out.stderr[-2000:])
