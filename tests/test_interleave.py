"""Stall-free scheduling: chunked-prefill/decode interleaving, SLA-aware
admission and the latency accounting that measures them.

The tentpole invariant is BITWISE token parity: splitting an admission
prefill into budgeted attn_chunk-aligned pieces — at any budget, under
either admission policy, interleaved with decode or run to completion,
on dense or paged KV, with or without speculative decode — must not
change a single generated token vs the monolithic admission path. The
chunk-seam parity of ``prefill_extend`` (DESIGN.md §Prefix caching)
plus slot-independent decode math and per-request sampler seeds carry
the argument; these tests enforce it at every chunk-boundary shape.

Prompts are explicit id lists. The module pins ``attn_chunk=8`` so a
few-dozen-token prompt spans several chunks; monolithic-prefill
baselines only see chunk-aligned (or single-chunk) prompt lengths —
the legacy prefill path asserts ``Sq % attn_chunk == 0`` above one
chunk, which is exactly why the budgeted path exists.
"""
import dataclasses

import jax
import pytest

from repro.common.perf import get_flags, set_flags
from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serving.engine import InferenceEngine, Request
from repro.serving.sampling import SamplerConfig
from repro.serving.sched import (NO_DEADLINE, AdmissionQueue,
                                 deadline_step, victim_key)

CHUNK = 8                     # attn_chunk pinned for this module
BS = 16                       # paged block size; cache_len = 128


@pytest.fixture(scope="module", autouse=True)
def small_chunks():
    """Pin attn_chunk=8 so short prompts exercise multi-chunk prefill;
    restore the session flags afterwards."""
    saved = get_flags()
    set_flags(dataclasses.replace(saved, attn_chunk=CHUNK))
    yield
    set_flags(saved)


@pytest.fixture(scope="module")
def planner():
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def base_engine(planner):
    """Compile the jitted steps once for cache_len=128."""
    cfg, params = planner
    return InferenceEngine(cfg, params, max_batch=2, cache_len=128)


def make_engine(planner, base=None, **kw):
    cfg, params = planner
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 128)
    eng = InferenceEngine(cfg, params, **kw)
    if base is not None and kw["cache_len"] == base.cache_len:
        eng._prefill, eng._decode, eng._extend = \
            base._prefill, base._decode, base._extend
    return eng


# chunk-aligned / single-chunk lengths: legal for the monolithic
# baseline AND every chunk-boundary case of the budgeted path —
# 1 chunk exact, < 1 chunk, multi-chunk exact, odd short
ALIGNED_LENS = (8, 5, 24, 16, 40)
# non-aligned lengths (chunks + remainder): budgeted paths only
RAGGED_LENS = (23, 9, 33, 17, 37)


def _submit(eng, lens, max_new=6, sla=None):
    for i, n in enumerate(lens):
        eng.add_request(list(range(5, 5 + n)), max_new_tokens=max_new,
                        sampler=SamplerConfig(temperature=0.8,
                                              top_k=40, seed=900 + i),
                        sla_ticks=None if sla is None else sla[i])


def _outputs(eng, lens, **kw):
    _submit(eng, lens, **kw)
    return {r.request_id: (tuple(r.output), r.finish_reason)
            for r in eng.run_until_done()}


# --------------------------------------------------- chunk-seam parity ----

def test_budget_parity_vs_monolithic_all_boundaries(planner,
                                                    base_engine):
    """Budgets of exactly one chunk, two chunks, and below one chunk
    (whole-chunk fallback) all emit the monolithic path's tokens,
    interleaved or run-to-completion."""
    ref = _outputs(make_engine(planner, base_engine), ALIGNED_LENS)
    for budget in (CHUNK, 2 * CHUNK, CHUNK - 5):
        for interleave in (True, False):
            eng = make_engine(planner, base_engine,
                              prefill_budget=budget,
                              interleave=interleave)
            assert _outputs(eng, ALIGNED_LENS) == ref, \
                (budget, interleave)
            assert eng.stats["prefill_chunks"] > 0


def test_budget_parity_ragged_dense_paged_slack(planner, base_engine):
    """Non-chunk-aligned prompts (the lengths the monolithic prefill
    cannot even serve above one chunk): dense and paged engines, both
    schedules, fifo and slack admission — one identical answer."""
    ref = None
    for kv in ({}, {"kv_mode": "paged", "block_size": BS}):
        for interleave in (True, False):
            for admission in ("fifo", "slack"):
                eng = make_engine(planner, base_engine,
                                  prefill_budget=CHUNK,
                                  interleave=interleave,
                                  admission=admission, **kv)
                out = _outputs(eng, RAGGED_LENS)
                ref = ref or out
                assert out == ref, (kv, interleave, admission)


def test_budget_parity_with_prefix_hits(planner, base_engine):
    """A prefix hit seeds the pending prefill mid-prompt; the resumed
    chunk stream still matches the monolithic prefix path, and the
    admission accounting invariant carries over."""
    prefix = list(range(5, 29))                 # 24 tokens = 3 chunks

    def run(**kw):
        eng = make_engine(planner, base_engine, **kw)
        eng.register_prefix("p", prefix)
        for i, extra in enumerate((3, 11, 8)):
            eng.add_request(prefix + list(range(60, 60 + extra)),
                            max_new_tokens=5, prefix_key="p",
                            sampler=SamplerConfig(temperature=0.8,
                                                  seed=70 + i))
        out = {r.request_id: tuple(r.output)
               for r in eng.run_until_done()}
        return out, eng.stats

    ref, _ = run()
    for kw in ({"prefill_budget": CHUNK},
               {"prefill_budget": CHUNK, "interleave": False},
               {"prefill_budget": CHUNK, "kv_mode": "paged",
                "block_size": BS}):
        out, st = run(**kw)
        assert out == ref, kw
        assert st["prefix_hits"] == 3
        assert st["admissions"] == st["prefix_hits"] + st["prefills"] \
            - st["prefix_registrations"]


def test_budget_parity_with_spec_decode(planner):
    """Chunked admission hands off into speculative decoding without
    changing a token: the pending slot rides through draft rounds
    untouched until its cache installs. (Aligned prompt lengths: the
    non-budget reference admits through the monolithic prefill.)"""
    from repro.serving.specdec import SpecConfig
    cfg, params = planner

    def run(**kw):
        eng = InferenceEngine(cfg, params, max_batch=2, cache_len=128,
                              spec_decode=SpecConfig(draft_cfg=cfg,
                                                     draft_params=params,
                                                     k=3), **kw)
        return _outputs(eng, ALIGNED_LENS, max_new=8)

    ref = run()
    out = run(prefill_budget=CHUNK)
    assert out == ref
    assert run(prefill_budget=CHUNK, interleave=False) == ref


def test_budget_parity_through_paged_preemption(planner, base_engine):
    """A pool too small for the batch forces preempt-and-resume around
    in-flight chunked prefills; the swap round-trip plus the chunk
    seams change nothing vs the dense budgeted engine."""
    def run(kv_mode, **kw):
        eng = make_engine(planner, base_engine, kv_mode=kv_mode,
                          prefill_budget=CHUNK, **kw)
        for i in range(3):
            eng.add_request(list(range(5, 45)), max_new_tokens=24,
                            sampler=SamplerConfig(temperature=0.8,
                                                  top_k=40,
                                                  seed=77 + i))
        return ({r.request_id: tuple(r.output)
                 for r in eng.run_until_done()}, eng)

    d, _ = run("dense")
    p, eng = run("paged", block_size=BS, kv_blocks=7)
    assert eng.stats["preemptions"] > 0
    assert eng.stats["resumes"] == eng.stats["preemptions"]
    assert d == p


def test_oversize_prompt_refused_up_front(planner, base_engine):
    """Budget mode screens prompts >= cache_len at admission (like
    paged/spec modes) instead of crashing mid-chunk."""
    eng = make_engine(planner, base_engine, prefill_budget=CHUNK)
    eng.add_request(list(range(5, 140)), max_new_tokens=4)  # 135 >= 128
    eng.add_request(list(range(5, 30)), max_new_tokens=4)
    done = {r.request_id: r for r in eng.run_until_done()}
    assert done[0].finish_reason == "cache_len" and not done[0].output
    assert len(done[1].output) == 4


def test_budget_validation(planner, base_engine):
    with pytest.raises(ValueError, match="prefill_budget"):
        make_engine(planner, base_engine, prefill_budget=0)


# ------------------------------------------------ scheduling semantics ----

def test_rtc_stalls_interleave_does_not(planner, base_engine):
    """Run-to-completion pays stall ticks (decode frozen while a
    prefill drains) and a longer makespan; interleaving serves the
    same requests in fewer steps with zero stalls — same tokens."""
    lens = (40, 5, 24, 9)

    def run(interleave):
        eng = make_engine(planner, base_engine, prefill_budget=CHUNK,
                          interleave=interleave)
        out = _outputs(eng, lens, max_new=8)
        return out, eng.stats["stall_ticks"], eng.step_no

    out_i, stalls_i, steps_i = run(True)
    out_r, stalls_r, steps_r = run(False)
    assert out_i == out_r
    assert stalls_i == 0
    assert stalls_r > 0
    assert steps_r > steps_i


def test_pending_round_robin_lets_short_pass_long(planner, base_engine):
    """Deficit round-robin over pendings: a 1-chunk prompt admitted
    beside a 5-chunk prompt drains within a couple of turns instead of
    queuing behind the whole long prefill — its first token lands
    strictly earlier than the long prompt's."""
    eng = make_engine(planner, base_engine, prefill_budget=CHUNK)
    eng.add_request(list(range(5, 45)), max_new_tokens=4,   # 5 chunks
                    sampler=SamplerConfig(temperature=0.0))
    eng.add_request(list(range(50, 58)), max_new_tokens=4,  # 1 chunk
                    sampler=SamplerConfig(temperature=0.0))
    done = {r.request_id: r for r in eng.run_until_done()}
    long_req, short_req = done[0], done[1]
    assert short_req.first_token_step < long_req.first_token_step
    # both admitted on step 0; the short one's single chunk lands on
    # its round-robin turn (step 1), not after the long drain
    assert short_req.admit_step == long_req.admit_step == 0
    assert short_req.first_token_step <= 2


def test_tick_stamps_are_monotonic(planner, base_engine):
    """enqueue <= admit <= first_token <= finish on every request, and
    first_token_step is when the FIRST output token appeared — the
    quantity the cluster's true-TTFT metric is derived from."""
    eng = make_engine(planner, base_engine, prefill_budget=CHUNK)
    _submit(eng, (24, 9, 16, 5), max_new=5)
    done = eng.run_until_done()
    assert len(done) == 4
    for r in done:
        assert 0 <= r.enqueue_step <= r.admit_step \
            <= r.first_token_step <= r.finish_step
    # with 2 slots, the later requests were queued: enqueue < admit
    assert any(r.enqueue_step < r.admit_step for r in done)


# -------------------------------------------------- SLA-aware admission ----

def _req(rid, sla=None, enq=0, out=()):
    r = Request(request_id=rid, prompt=[1, 2], max_new_tokens=4,
                sampler=SamplerConfig(), sla_ticks=sla)
    r.enqueue_step = enq
    r.output = list(out)
    return r


def test_admission_queue_fifo_and_slack_orders():
    """fifo pops arrival order; slack pops earliest deadline first
    (enqueue_step + sla_ticks, ties by request id, no-SLA last) —
    and iteration previews pop order without mutating."""
    reqs = [_req(0, sla=None), _req(1, sla=50), _req(2, sla=10),
            _req(3, sla=10, enq=5)]
    fifo = AdmissionQueue("fifo")
    slack = AdmissionQueue("slack")
    for r in reqs:
        fifo.push(r)
        slack.push(r)
    assert [r.request_id for r in fifo] == [0, 1, 2, 3]
    assert [r.request_id for r in slack] == [2, 3, 1, 0]
    assert [r.request_id for r in slack] == [2, 3, 1, 0]  # non-mutating
    assert [slack.pop().request_id for _ in range(4)] == [2, 3, 1, 0]
    # a preempted request re-queues at the FRONT under fifo
    fifo.push(reqs[2]); fifo.push(reqs[1], front=True)
    assert fifo.peek().request_id == 1
    assert deadline_step(reqs[0]) == NO_DEADLINE
    assert deadline_step(reqs[3]) == 15


def test_victim_key_policies():
    """fifo preempts the latest-admitted victim (seed rule); slack
    preempts the laxest deadline — a no-SLA request before any
    deadline-carrying one."""
    a, b, c = _req(5, sla=10), _req(7, sla=99), _req(6, sla=None)
    pool = [a, b, c]
    assert max(pool, key=lambda r: victim_key(r, "fifo")) is b
    assert max(pool, key=lambda r: victim_key(r, "slack")) is c


def test_slack_admission_is_deterministic_edf(planner, base_engine):
    """Same arrivals => same admission order, and that order is EDF:
    with one slot, the tightest-deadline request is served first even
    though it enqueued last."""
    def run():
        eng = make_engine(planner, base_engine, max_batch=1,
                          admission="slack")
        _submit(eng, (16, 16, 16), max_new=3, sla=(200, 100, 50))
        done = eng.run_until_done()
        return [r.request_id for r in
                sorted(done, key=lambda r: r.admit_step)]

    assert run() == [2, 1, 0]
    assert run() == run()


def test_expired_queued_requests_drop_deterministically(planner,
                                                        base_engine):
    """A request whose deadline passes while it is still QUEUED is
    dropped at pop time with finish_reason='sla_expired' and no
    tokens; requests that got a slot serve to completion."""
    eng = make_engine(planner, base_engine, max_batch=1)
    _submit(eng, (16, 16, 16), max_new=8, sla=(None, 2, 500))
    done = {r.request_id: r for r in eng.run_until_done()}
    assert len(done) == 3
    assert done[1].finish_reason == "sla_expired"
    assert done[1].output == []
    # no 0/None sentinel left for TTFT math: a served-nothing drop
    # stamps first_token == finish
    assert done[1].first_token_step == done[1].finish_step
    assert done[0].finish_reason in ("eos", "max_new_tokens")
    assert done[2].finish_reason in ("eos", "max_new_tokens")
    assert eng.stats["sla_expired"] == 1
    assert eng.stats["admissions"] == 2


def test_preempted_requests_never_expire(planner, base_engine):
    """Expiry applies to FRESH queued requests only: a preempted
    request already holds generated tokens and always resumes, even
    past its deadline (dropping it would lose emitted output)."""
    eng = make_engine(planner, base_engine, kv_mode="paged",
                      block_size=BS, kv_blocks=7, prefill_budget=CHUNK)
    for i in range(3):
        eng.add_request(list(range(5, 45)), max_new_tokens=24,
                        sla_ticks=3,
                        sampler=SamplerConfig(temperature=0.8,
                                              top_k=40, seed=77 + i))
    done = {r.request_id: r for r in eng.run_until_done()}
    assert eng.stats["preemptions"] > 0
    # every preempted-and-resumed request finished with its tokens
    resumed = [r for r in done.values()
               if r.finish_reason != "sla_expired"]
    assert all(len(r.output) == 24 or r.finish_reason == "eos"
               for r in resumed)
    assert eng.stats["resumes"] == eng.stats["preemptions"]


# ------------------------------------------------- latency accounting ----

def test_pct_empty_series_is_none():
    from repro.serving.cluster import _pct
    assert _pct([], 95) is None
    assert _pct([3.0], 50) == 3.0


def test_cluster_true_ttft_vs_admit_wait(planner):
    """The cluster's ttft_* percentiles come from first_token_tick
    (true TTFT); admit_wait_* keeps the old queue-exit proxy. A
    budgeted multi-chunk admission makes them visibly different:
    first_token_tick > admit_tick for the long prompt."""
    from repro.serving.cluster import ClusterStats, EngineCluster
    cfg, params = planner
    cluster = EngineCluster(cfg, params, 1, max_batch=2, cache_len=128,
                            prefill_budget=CHUNK)
    eng = cluster.replicas[0]
    r, rid = cluster.submit(list(range(5, 45)), max_new_tokens=4,
                            sampler=SamplerConfig(temperature=0.0))
    cluster.run_until_done()
    t = cluster.traces[(r, rid)]
    assert t.first_token_tick is not None
    # 40-token prompt = 5 chunks at one chunk/step: admitted tick 0,
    # first token only once the last chunk lands
    assert t.admit_tick == 0
    assert t.first_token_tick >= t.admit_tick + 4
    assert eng.stats["prefill_chunks"] == 5
    s = ClusterStats(ticks=cluster.tick,
                     traces=list(cluster.traces.values()),
                     per_replica=[dict(eng.stats)]).summary()
    assert s["ttft_p50"] >= s["admit_wait_p50"] + 4
