"""KV block-pool allocator invariants (serving/kvpool.py), as a unit.

The engine-level dense-vs-paged parity tests (test_paged_engine.py)
exercise the allocator only along serving paths; here random
alloc/fork/cow/grow/free sequences hammer it directly: refcounts always
mirror the live tables, the free list never holds a referenced block,
free+used is conserved, and double-frees raise instead of corrupting.
Driven twice — seeded random sequences (always run) and hypothesis
(when installed, skipped cleanly otherwise like test_properties.py).
"""
import random
from collections import Counter

import pytest

from repro.serving.kvpool import BlockTable, KVBlockPool, KVPoolExhausted

N_BLOCKS, BLOCK_SIZE = 8, 4


# ----------------------------------------------------------- unit tests ----

def test_alloc_is_deterministic_lowest_id_first():
    pool = KVBlockPool(N_BLOCKS, BLOCK_SIZE)
    t = pool.alloc(3 * BLOCK_SIZE)
    assert t.blocks == [0, 1, 2] and t.n_tokens == 3 * BLOCK_SIZE
    pool.free(t)
    # freed blocks come back lowest-id-first, not in LIFO order
    t2 = pool.alloc(2 * BLOCK_SIZE + 1)
    assert t2.blocks == [0, 1, 2]


def test_blocks_needed_is_ceil_div():
    pool = KVBlockPool(N_BLOCKS, BLOCK_SIZE)
    assert [pool.blocks_needed(n) for n in (0, 1, 4, 5, 8)] \
        == [0, 1, 1, 2, 2]


def test_fork_shares_and_cow_privatizes():
    pool = KVBlockPool(N_BLOCKS, BLOCK_SIZE)
    prefix = pool.alloc(10)                    # blocks [0,1,2], 2 full
    fork = pool.fork(prefix, n_tokens=14)
    assert fork.blocks == prefix.blocks
    assert pool.shared_blocks() == 3 and pool.used_blocks() == 3
    # CoW the partial tail (logical block 2) => fresh block, prefix keeps
    # its own copy; the two full blocks stay shared
    changed = pool.cow_from(fork, 2)
    assert changed == [2] and fork.blocks[:2] == prefix.blocks[:2]
    assert fork.blocks[2] != prefix.blocks[2]
    assert pool.shared_blocks() == 2
    pool.grow(fork, 17)                        # needs a 5th logical block
    assert len(fork.blocks) == 5 and fork.n_tokens == 17
    # cow_from keeps already-exclusive entries: a fully-owned table is
    # untouched
    solo = pool.alloc(2 * BLOCK_SIZE)
    assert pool.cow_from(solo, 0) == []
    pool.free(solo)
    pool.free(fork)
    assert pool.used_blocks() == 3 and pool.shared_blocks() == 0
    pool.free(prefix)
    assert pool.free_blocks() == N_BLOCKS


def test_exhaustion_and_double_free_raise():
    pool = KVBlockPool(2, BLOCK_SIZE)
    t = pool.alloc(2 * BLOCK_SIZE)
    with pytest.raises(KVPoolExhausted):
        pool.alloc(1)
    with pytest.raises(KVPoolExhausted):
        pool.append_block(t)
    pool.free(t)
    pool.free(t)                 # freed tables hold no blocks: a no-op
    with pytest.raises(KVPoolExhausted):
        pool._release(0)         # but releasing a free block raises


def test_append_block_does_not_advance_tokens():
    pool = KVBlockPool(N_BLOCKS, BLOCK_SIZE)
    t = pool.alloc(BLOCK_SIZE)
    b = pool.append_block(t)
    assert t.blocks == [0, b] and t.n_tokens == BLOCK_SIZE


# ------------------------------------------------- property sequences ----

def _apply_ops(ops):
    """Interpret (code, a, b) triples as pool operations against a live
    mirror; check pool invariants and the refcount mirror after every
    op. Exhaustion is a legal outcome, corruption is not."""
    pool = KVBlockPool(N_BLOCKS, BLOCK_SIZE)
    live = []

    def crosscheck():
        pool.check_invariants()
        refs = Counter(b for t in live for b in t.blocks)
        assert refs == Counter({b: r for b, r in enumerate(pool.ref)
                                if r > 0}), (refs, pool.ref)
        assert pool.used_blocks() + pool.free_blocks() == N_BLOCKS

    for code, a, b in ops:
        op = code % 5
        try:
            if op == 0:                                        # alloc
                live.append(pool.alloc(1 + a % (N_BLOCKS * BLOCK_SIZE)))
            elif op == 1 and live:                             # fork
                live.append(pool.fork(live[a % len(live)]))
            elif op == 2 and live:                             # cow
                t = live[a % len(live)]
                pool.cow_from(t, b % (len(t.blocks) + 1))
            elif op == 3 and live:                             # grow
                t = live[a % len(live)]
                pool.grow(t, t.n_tokens + b % (2 * BLOCK_SIZE))
            elif op == 4 and live:                             # free
                pool.free(live.pop(a % len(live)))
        except KVPoolExhausted:
            pass
        crosscheck()
    for t in live:
        pool.free(t)
    pool.check_invariants()
    # every refcount returned to zero: the pool is whole again
    assert pool.free_blocks() == N_BLOCKS
    assert all(r == 0 for r in pool.ref)


def test_random_op_sequences_preserve_invariants():
    for seed in range(20):
        rng = random.Random(seed)
        ops = [(rng.randrange(5), rng.randrange(64), rng.randrange(64))
               for _ in range(60)]
        _apply_ops(ops)


def test_hypothesis_op_sequences_preserve_invariants():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    @hyp.given(st.lists(st.tuples(st.integers(0, 4),
                                  st.integers(0, 63),
                                  st.integers(0, 63)),
                        max_size=80))
    @hyp.settings(max_examples=150, deadline=None)
    def prop(ops):
        _apply_ops(ops)
    prop()
