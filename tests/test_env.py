"""Environment + evaluator tests: tools behave, metrics computed right."""
import numpy as np
import pytest

from repro.env.evaluator import rouge_l
from repro.env.tasks import make_benchmark
from repro.env.tools_impl import ToolError, Workspace, execute_tool
from repro.env.world import build_world


@pytest.fixture(scope="module")
def world():
    return build_world(0, n_images=200)


def _ws(world, seed=0):
    return Workspace(world=world, rng=np.random.default_rng(seed))


def test_sql_query_filters(world):
    ws = _ws(world)
    out = execute_tool(ws, "sql_query_images",
                       {"sensor": "xview1", "max_cloud": 0.3})
    assert "image_ids" in out
    # all returned ids satisfy the filter
    import ast
    ids = ast.literal_eval(out.split("…")[0].replace("'", '"')
                           if out.endswith("…") else out)["image_ids"] \
        if not out.endswith("…") else None
    if ids:
        for i in ids:
            rec = world.images[i]
            assert rec.sensor == "xview1"
            assert rec.cloud <= 0.3


def test_load_then_detect_flow(world):
    ws = _ws(world)
    ids = sorted(world.images)[:4]
    execute_tool(ws, "load_images", {"image_ids": ids})
    assert ws.handles == ids
    execute_tool(ws, "detect_objects", {"classes": ["airplane"]})
    assert set(ws.detections) == set(ids)
    for h in ids:
        det = ws.detections[h]["airplane"]
        assert det["tp"] <= det["gt"]
        assert det["pred"] == det["tp"] + det["fp"]


def test_tools_error_on_empty_workspace(world):
    ws = _ws(world)
    for name in ("plot_map", "detect_objects", "classify_landcover",
                 "mosaic"):
        with pytest.raises(ToolError):
            execute_tool(ws, name, {})


def test_unknown_tool_raises(world):
    with pytest.raises(ToolError):
        execute_tool(_ws(world), "no_such_tool", {})


def test_landcover_noise_bounded(world):
    ws = _ws(world)
    ids = sorted(world.images)[:6]
    execute_tool(ws, "load_images", {"image_ids": ids})
    execute_tool(ws, "classify_landcover", {})
    for h in ids:
        gt = world.images[h].landcover
        pred = ws.landcover[h]
        assert abs(sum(pred.values()) - 1.0) < 1e-6
        for c in gt:
            assert abs(pred[c] - gt[c]) < 0.12


def test_benchmark_deterministic(world):
    a = make_benchmark(world, 32, seed=5)
    b = make_benchmark(world, 32, seed=5)
    assert [t.query for t in a] == [t.query for t in b]
    assert [t.intent for t in a] == [t.intent for t in b]
    c = make_benchmark(world, 32, seed=6)
    assert [t.query for t in a] != [t.query for t in c]


def test_benchmark_covers_all_intents(world):
    tasks = make_benchmark(world, 64)
    intents = {t.intent for t in tasks}
    assert len(intents) == 8


def test_detection_f1_reasonable(world):
    """The seeded detector noise lands in the paper's F1 band."""
    ws = _ws(world, seed=2)
    ids = sorted(world.images)[:50]
    execute_tool(ws, "load_images", {"image_ids": ids})
    execute_tool(ws, "detect_objects", {"classes": ["airplane", "ship"]})
    tp = fp = fn = 0
    for h in ids:
        for cls in ("airplane", "ship"):
            det = ws.detections[h][cls]
            tp += det["tp"]
            fp += det["fp"]
            fn += det["gt"] - det["tp"]
    f1 = 2 * tp / (2 * tp + fp + fn)
    assert 0.75 < f1 < 0.97
