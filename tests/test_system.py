"""End-to-end system tests: the full GeckOpt pipeline in miniature —
Table-2 harness, benchmark scripts, neural gate, dry-run skip logic."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def test_table2_pipeline_small():
    from benchmarks.table2 import run
    out = run(n_tasks=48, tag="table2_test")
    for name, rec in out.items():
        assert 5 < rec["token_reduction_pct"] < 50, (name, rec)
        assert abs(rec["success_delta_pct"]) < 10


def test_steps_tools_pipeline():
    from benchmarks.steps_tools import run
    out = run(n_tasks=48)
    assert out["step_reduction_pct"] > 0
    assert out["tools_per_step_gain_pct"] > 0


def test_gating_sweep_monotone_fallback():
    from benchmarks.gating import run
    out = run(n_tasks=48)
    sw = out["sweep"]
    # lower gate accuracy => more fallbacks
    assert sw[0.5]["fallback_rate_pct"] >= sw[1.0]["fallback_rate_pct"]
    # perfect gate keeps success within noise
    assert abs(sw[1.0]["success_delta_pp"]) < 8


def test_neural_intent_classifier_smoke():
    """Untrained proxy scores intents (wiring test); training happens in
    examples/train_planner.py."""
    import jax
    from repro.configs import get_smoke_config
    from repro.core.intents import INTENTS
    from repro.models.model import init_params
    from repro.serving.neural_planner import NeuralIntentClassifier

    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    clf = NeuralIntentClassifier(cfg, params)
    intent, completion = clf.classify("plot images around Tampa Bay")
    assert intent in INTENTS


def test_dryrun_skip_logic():
    from repro.common.config import INPUT_SHAPES
    from repro.configs import ARCH_IDS, get_config
    from repro.launch.dryrun import skip_reason

    long = INPUT_SHAPES["long_500k"]
    runs = [a for a in ARCH_IDS if not skip_reason(get_config(a), long)]
    skips = [a for a in ARCH_IDS if skip_reason(get_config(a), long)]
    assert set(runs) == {"hymba-1.5b", "xlstm-125m", "starcoder2-3b",
                         "gemma2-2b"}
    assert len(skips) == 6
    # every other shape runs everywhere
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        assert all(not skip_reason(get_config(a), INPUT_SHAPES[s])
                   for a in ARCH_IDS)


def test_dryrun_artifacts_green_if_present():
    """If the committed dry-run sweep results exist, they must be clean."""
    import glob
    import json
    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    files = glob.glob(os.path.join(d, "*.json"))
    if not files:
        pytest.skip("dry-run sweep not yet executed")
    bad = []
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        if rec["status"] == "error":
            bad.append(os.path.basename(f))
    assert not bad, bad
