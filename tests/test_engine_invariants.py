"""Property-style invariants of the continuous-batching engine.

The cluster layer (serving/cluster.py) multiplies every engine bug by N
replicas, so the core scheduling invariants get their own test layer:
slot recycling, finish-reason classification, admission accounting,
admission-queue semantics and bit-reproducibility.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serving.engine import InferenceEngine
from repro.serving.sampling import SamplerConfig
from repro.serving.sched import AdmissionQueue
from repro.serving.tokenizer import SPECIALS


@pytest.fixture(scope="module")
def planner():
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def base_engine(planner):
    """Compile the jitted steps once for cache_len=128."""
    cfg, params = planner
    return InferenceEngine(cfg, params, max_batch=2, cache_len=128)


def make_engine(planner, base=None, **kw):
    """Fresh engine; shares the base engine's jitted step functions when
    the cache_len matches (the closures bind cfg/cache_len/backend)."""
    cfg, params = planner
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 128)
    eng = InferenceEngine(cfg, params, **kw)
    if base is not None and kw["cache_len"] == base.cache_len:
        eng._prefill, eng._decode, eng._extend = \
            base._prefill, base._decode, base._extend
    return eng


# ------------------------------------------------------ queue semantics ----

def test_queue_is_deque_with_fifo_admission(planner, base_engine):
    """The O(n) list.pop(0) queue is gone: the default fifo
    AdmissionQueue pops in arrival order (and iterates in pop order)."""
    eng = make_engine(planner, base_engine)
    assert isinstance(eng.queue, AdmissionQueue)
    assert eng.queue.policy == "fifo"
    rids = [eng.add_request(f"queued request number {i}",
                            max_new_tokens=6) for i in range(5)]
    eng.step()               # admits exactly max_batch=2, FIFO
    in_slots = sorted(s.request_id for s in eng.slots if s is not None)
    assert in_slots == rids[:2]
    assert [r.request_id for r in eng.queue] == rids[2:]
    done = eng.run_until_done()
    assert sorted(r.request_id for r in done) == rids


def test_load_accessors(planner, base_engine):
    """The router-facing introspection surface: busy + free == max_batch
    and load == busy + queued, live through a request's lifecycle."""
    eng = make_engine(planner, base_engine)
    assert eng.is_idle() and eng.load() == 0
    for i in range(3):
        eng.add_request(f"load accessor probe {i}", max_new_tokens=4)
    assert eng.queue_depth() == 3 and eng.load() == 3
    eng.step()                       # admits 2 of 3
    assert eng.busy_slots() == 2 and eng.free_slot_count() == 0
    assert eng.queue_depth() == 1 and eng.load() == 3
    assert not eng.is_idle()
    eng.run_until_done()
    assert eng.is_idle() and eng.busy_slots() == 0
    assert eng.free_slot_count() == eng.max_batch


# -------------------------------------------------------- slot recycling ----

def test_slot_recycling_never_leaks(planner, base_engine):
    """Freed slots come back with pos reset; a recycled slot serves its
    next tenant exactly as a fresh engine would (stale cache rows are
    overwritten / masked, never read)."""
    eng = make_engine(planner, base_engine)
    prompts = [f"recycled slot request {i} about maps" for i in range(6)]
    for p in prompts:
        eng.add_request(p, max_new_tokens=3,
                        sampler=SamplerConfig(temperature=0.0))
    done = {r.request_id: r for r in eng.run_until_done()}
    assert len(done) == 6                      # 3 waves over 2 slots
    assert all(s is None for s in eng.slots)
    assert jnp.all(eng.cache["pos"] == 0)      # freed slots reset
    # the LAST wave ran in twice-recycled slots; its outputs must equal
    # a fresh engine serving the same prompts alone
    fresh = make_engine(planner, base_engine)
    for p in prompts[4:]:
        fresh.add_request(p, max_new_tokens=3,
                          sampler=SamplerConfig(temperature=0.0))
    fresh_done = {tuple(r.prompt): r.output
                  for r in fresh.run_until_done()}
    assert len(fresh_done) == 2
    matched = 0
    for r in done.values():
        if tuple(r.prompt) in fresh_done:
            assert r.output == fresh_done[tuple(r.prompt)]
            matched += 1
    assert matched == 2


# --------------------------------------------------------- finish reason ----

def test_finish_reason_exactly_one(planner, base_engine):
    """Every finished request records exactly one terminal cause, and
    the recorded cause is consistent with its output."""
    eng = make_engine(planner, base_engine)
    eng.add_request("finish by budget please", max_new_tokens=3)
    eng.add_request("another budget bounded request", max_new_tokens=5)
    tiny = make_engine(planner, cache_len=48)   # force cache exhaustion
    tiny.add_request("short prompt long generation", max_new_tokens=512)
    done = eng.run_until_done() + tiny.run_until_done()
    assert len(done) == 3
    for r in done:
        assert r.done and r.finish_reason in ("eos", "max_new_tokens",
                                              "cache_len")
        if r.finish_reason == "eos":
            assert r.output[-1] == SPECIALS["<eos>"]
        elif r.finish_reason == "max_new_tokens":
            assert len(r.output) == r.max_new_tokens
        else:
            assert len(r.output) < r.max_new_tokens
    assert done[2].finish_reason == "cache_len"


def test_admission_token_can_be_terminal(planner, base_engine):
    """A max_new_tokens=1 request finishes ON its admission token —
    exactly one output token, never decoded past, and the slot it was
    prefilled into is immediately available to the next queued request."""
    eng = make_engine(planner, base_engine)
    rids = [eng.add_request(f"one token budget request {i}",
                            max_new_tokens=1) for i in range(3)]
    done = eng.step()
    # 2 slots, but terminal admissions recycle the slot within _admit:
    # all three one-token requests finish in the first step
    assert sorted(r.request_id for r in done) == rids
    for r in done:
        assert len(r.output) == 1
        assert r.finish_reason in ("eos", "max_new_tokens")
    assert eng.is_idle() and eng.stats["decode_steps"] == 0
    assert eng.stats["admissions"] == 3


# -------------------------------------------------- admission accounting ----

def test_prefix_hits_plus_prefills_equals_admissions(planner, base_engine):
    """Every admission is served by exactly one of: a prefix-cache hit
    or a full prefill. register_prefix's own prefill is counted in
    ``prefills`` AND ``prefix_registrations``, so:
    admissions == prefix_hits + prefills - prefix_registrations."""
    prefix = "shared system prefix words here"

    def check(eng):
        st = eng.stats
        assert (st["admissions"]
                == st["prefix_hits"] + st["prefills"]
                - st["prefix_registrations"]), st
        return st

    eng = make_engine(planner, base_engine)
    for i in range(4):
        eng.add_request(f"no prefix request {i}", max_new_tokens=2)
    eng.run_until_done()
    st = check(eng)
    assert st["admissions"] == 4 and st["prefix_hits"] == 0

    eng = make_engine(planner, base_engine)
    eng.register_prefix("p", prefix)
    for i in range(3):
        eng.add_request(f"{prefix} query {i}", max_new_tokens=2,
                        prefix_key="p")
    eng.add_request("entirely different prompt", max_new_tokens=2,
                    prefix_key="p")           # miss -> full prefill
    eng.run_until_done()
    st = check(eng)
    assert st["prefix_registrations"] == 1
    assert st["prefix_hits"] == 3 and st["admissions"] == 4


# ------------------------------------------------------- reproducibility ----

def test_run_until_done_bit_reproducible(planner, base_engine):
    """Two engines, same seed, same requests => identical tokens, stats
    and finish reasons (stochastic sampling included)."""

    def run(seed):
        eng = make_engine(planner, base_engine, seed=seed)
        for i in range(5):
            eng.add_request(f"reproducibility probe {i} over the bay",
                            max_new_tokens=4,
                            sampler=SamplerConfig(temperature=0.7,
                                                  top_k=40))
        done = sorted(eng.run_until_done(), key=lambda r: r.request_id)
        return ([r.output for r in done],
                [r.finish_reason for r in done], dict(eng.stats))

    assert run(11) == run(11)
    # different engine seed => different sampling stream (sanity that
    # the assertion above is not vacuous)
    assert run(11)[0] != run(12)[0]


def test_seeded_sampler_decouples_from_engine_stream(planner, base_engine):
    """With per-request sampler seeds, outputs are independent of the
    ENGINE seed and of co-tenant traffic — the property the cluster's
    cross-policy token parity rests on."""

    def run(engine_seed, extra_traffic):
        eng = make_engine(planner, base_engine, seed=engine_seed)
        rid = eng.add_request("seeded request about harbors",
                              max_new_tokens=5,
                              sampler=SamplerConfig(temperature=0.9,
                                                    seed=1234))
        if extra_traffic:
            eng.add_request("noisy neighbour request", max_new_tokens=5,
                            sampler=SamplerConfig(temperature=0.9))
        return {r.request_id: r.output
                for r in eng.run_until_done()}[rid]

    a = run(0, extra_traffic=False)
    b = run(99, extra_traffic=True)
    assert a == b
