"""Tests for the §Perf hillclimb knobs: every flagged code path must be
numerically identical to the baseline path (they only change layout,
sharding, or what gets rematerialized — never semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import MoEConfig
from repro.common.perf import FLAGS, PerfFlags, get_flags, set_flags
from repro.models import moe as M
from repro.models.layers import attention


@pytest.fixture(autouse=True)
def _reset_flags():
    yield
    set_flags(PerfFlags())


def _qkv(B=2, Hq=4, Hkv=2, S=256, hd=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, Hq, S, hd), jnp.float32),
            jax.random.normal(ks[1], (B, Hkv, S, hd), jnp.float32),
            jax.random.normal(ks[2], (B, Hkv, S, hd), jnp.float32))


# ------------------------------------------------------- apply_overrides ----

def test_apply_overrides_types():
    f = PerfFlags().apply_overrides(
        "ssm_scan_chunk=128,moe_capacity_factor=1.5,attn_constraint=auto")
    assert f.ssm_scan_chunk == 128
    assert f.moe_capacity_factor == 1.5
    assert f.attn_constraint == "auto"


def test_apply_overrides_empty_is_default():
    assert PerfFlags().apply_overrides("") == PerfFlags()


# ------------------------------------------------------ window-slice attn ----

@pytest.mark.parametrize("window,cap", [(96, 0.0), (64, 30.0), (200, 0.0)])
def test_window_slice_matches_masked(window, cap):
    q, k, v = _qkv(S=256)
    set_flags(PerfFlags(attn_chunk=64, attn_window_slice="off"))
    ref = attention(q, k, v, causal=True, window=window, cap=cap)
    set_flags(PerfFlags(attn_chunk=64, attn_window_slice="on"))
    out = attention(q, k, v, causal=True, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=2e-5, atol=2e-5)


def test_window_slice_grad_matches():
    q, k, v = _qkv(S=256)
    f = lambda q: attention(q, k, v, causal=True, window=96).sum()
    set_flags(PerfFlags(attn_chunk=64))
    g_ref = jax.grad(f)(q)
    set_flags(PerfFlags(attn_chunk=64, attn_window_slice="on",
                        attn_chunk_remat="on"))
    g_out = jax.grad(f)(q)
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_out),
                               rtol=2e-4, atol=2e-4)


def test_chunk_remat_matches():
    q, k, v = _qkv(S=256)
    f = lambda q: attention(q, k, v, causal=True).sum()
    set_flags(PerfFlags(attn_chunk=64))
    ref, g_ref = f(q), jax.grad(f)(q)
    set_flags(PerfFlags(attn_chunk=64, attn_chunk_remat="on"))
    out, g_out = f(q), jax.grad(f)(q)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_out),
                               rtol=2e-4, atol=2e-4)


def test_attn_constraint_noop_without_mesh():
    q, k, v = _qkv(S=128)
    set_flags(PerfFlags(attn_chunk=64))
    ref = attention(q, k, v, causal=True)
    set_flags(PerfFlags(attn_chunk=64, attn_constraint="auto"))
    out = attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), rtol=1e-6)


# --------------------------------------------------------------- moe pins ----

def _moe_setup(seed=0):
    from repro.common.config import ModelConfig
    cfg = ModelConfig(
        name="t", family="moe", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_head=16, d_ff=0, vocab_size=64,
        segments=((("moe",), 2),), mlp_act="silu_glu",
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=48,
                      capacity_factor=1.5))
    p = M.moe_init(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, 32),
                          jnp.float32)
    return cfg, p, x


def test_moe_constraint_noop_without_mesh():
    cfg, p, x = _moe_setup()
    set_flags(PerfFlags())
    y0, a0 = M.moe_ffn(p, x, cfg)
    set_flags(PerfFlags(moe_constraint="auto"))
    y1, a1 = M.moe_ffn(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-6)
    np.testing.assert_allclose(float(a0), float(a1), rtol=1e-6)


def test_moe_gather_pin_noop_without_mesh():
    cfg, p, x = _moe_setup()
    set_flags(PerfFlags(moe_dispatch="gather"))
    y0, _ = M.moe_ffn(p, x, cfg)
    set_flags(PerfFlags(moe_dispatch="gather", moe_constraint="auto"))
    y1, _ = M.moe_ffn(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-6)


def test_moe_capacity_override_changes_drops():
    cfg, p, x = _moe_setup()
    set_flags(PerfFlags(moe_capacity_factor=8.0))   # huge: nothing dropped
    y_full, _ = M.moe_ffn(p, x, cfg)
    set_flags(PerfFlags(moe_capacity_factor=0.1))   # tiny: most dropped
    y_tiny, _ = M.moe_ffn(p, x, cfg)
    # with most tokens dropped the output should differ materially
    assert not np.allclose(np.asarray(y_full), np.asarray(y_tiny),
                           rtol=1e-3, atol=1e-4)


def test_capacity_flag_restores_config_default():
    mcfg = MoEConfig(n_experts=4, top_k=2, d_expert=48, capacity_factor=1.5)
    set_flags(PerfFlags())
    c_default = M.capacity(mcfg, 64)
    set_flags(PerfFlags(moe_capacity_factor=1.5))
    assert M.capacity(mcfg, 64) == c_default


# ------------------------------------------------------------ ssm chunks ----

@pytest.mark.parametrize("chunk", [16, 64, 256])
def test_ssm_scan_chunk_invariance(chunk):
    from repro.common.config import ModelConfig, SSMConfig
    from repro.models.ssm import ssm_forward, ssm_init
    cfg = ModelConfig(
        name="t", family="ssm", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_head=16, d_ff=64, vocab_size=64,
        segments=((("hymba_w",), 1),),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=1))
    p = ssm_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 32), jnp.float32)
    set_flags(PerfFlags(ssm_scan_chunk=128))
    y_ref, s_ref = ssm_forward(p, x, cfg)
    set_flags(PerfFlags(ssm_scan_chunk=chunk))
    y, s = ssm_forward(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_ref["h"]), np.asarray(s["h"]),
                               rtol=2e-4, atol=2e-4)


# -------------------------------------------------------- parse_strategy ----

def test_parse_strategy():
    import os
    os.environ.setdefault("XLA_FLAGS", "")
    from repro.launch.dryrun import parse_strategy
    s = parse_strategy("prefill_seq_axis=model,fsdp=False")
    assert s.prefill_seq_axis == "model"
    assert s.fsdp is False
    assert parse_strategy("").fsdp is True
