"""launch/profiles.py: tuned flag profiles resolve and parse cleanly."""
import pytest

from repro.common.perf import PerfFlags
from repro.configs import ARCH_IDS
from repro.common.config import INPUT_SHAPES
from repro.launch.profiles import BASE_PERF, PAIR_OVERRIDES, resolve


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_resolve_parses_for_every_pair(arch, shape):
    perf, strategy = resolve(arch, shape)
    flags = PerfFlags().apply_overrides(perf)    # must not raise
    assert flags.attn_chunk_remat == "on"
    import os
    os.environ.setdefault("XLA_FLAGS", "")
    from repro.launch.dryrun import parse_strategy
    parse_strategy(strategy)                     # must not raise


def test_pair_overrides_win_over_base():
    perf, strategy = resolve("qwen1.5-110b", "prefill_32k")
    assert PerfFlags().apply_overrides(perf).attn_constraint == "off"
    perf, strategy = resolve("gemma2-2b", "prefill_32k")
    assert PerfFlags().apply_overrides(perf).attn_chunk == 4096
    assert "prefill_seq_axis=model" in strategy


def test_moe_archs_get_shard_map():
    for arch in ("kimi-k2-1t-a32b", "arctic-480b"):
        perf, _ = resolve(arch, "train_4k")
        assert PerfFlags().apply_overrides(perf).moe_dispatch == "shard_map"
    perf, _ = resolve("gemma2-2b", "train_4k")
    assert PerfFlags().apply_overrides(perf).moe_dispatch == "einsum"


def test_overrides_reference_known_pairs():
    for arch, shape in PAIR_OVERRIDES:
        assert arch in ARCH_IDS
        assert shape in INPUT_SHAPES
