"""Observability layer: tracer semantics, metrics registry, Perfetto
export, and the determinism contracts they must uphold.

Four claims under test (DESIGN.md §Observability):

  * well-formedness — spans pair B/E per track, seq is strictly
    increasing, a drained run leaves no open spans;
  * byte identity — same seed ⇒ byte-identical serialized trace
    (single engine AND a 2-replica cluster, through both exporters);
  * zero perturbation — tokens are bitwise identical with the tracer
    on vs the NullTracer default (tracing never branches control flow);
  * reset audit — ``engine.reset()``/``cluster.reset()`` zero the FULL
    counter surface (ENGINE_STAT_KEYS is pinned here so a new counter
    cannot silently leak across runs).
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier
from repro.core.intents import build_intent_map
from repro.core.planner import PlannerConfig
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.tasks import make_benchmark
from repro.env.world import build_world
from repro.models.model import init_params
from repro.obs import (NULL_TRACER, MetricsRegistry, NullTracer,
                       StatsView, Tracer, percentile)
from repro.obs.export import (chrome_trace, dump_chrome_trace,
                              dump_jsonl, jsonl_lines,
                              load_and_validate, validate_chrome_trace,
                              write_trace)
from repro.serving.cluster import EngineCluster
from repro.serving.engine import ENGINE_STAT_KEYS, InferenceEngine
from repro.serving.pipeline import GeckOptPipeline, PipelineConfig
from repro.serving.workload import (WorkloadConfig, make_workload,
                                    register_workload_prefixes,
                                    uniform_mix)

# every name the engine/pipeline instrumentation may emit
EVENT_VOCAB = {
    "enqueue", "admit", "resume", "first_token", "finish", "preempt",
    "sla_expired", "kv_evict", "cow_fork", "prefill_chunk", "stall",
    "decode", "spec_round", "request", "gate", "plan", "execute_wave"}


@pytest.fixture(scope="module")
def planner():
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def base_engine(planner):
    cfg, params = planner
    return InferenceEngine(cfg, params, max_batch=2, cache_len=128)


def make_engine(planner, base, **kw):
    cfg, params = planner
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 128)
    eng = InferenceEngine(cfg, params, **kw)
    if kw["cache_len"] == base.cache_len:
        eng._prefill, eng._decode, eng._extend = \
            base._prefill, base._decode, base._extend
    return eng


def serve_prompts(eng, n=3, max_new=6, temperature=0.8):
    from repro.serving.sampling import SamplerConfig
    for i in range(n):
        eng.add_request(f"trace probe request number {i}",
                        max_new_tokens=max_new,
                        sampler=SamplerConfig(temperature=temperature,
                                              seed=7 + i))
    return eng.run_until_done()


# ------------------------------------------------------ tracer semantics ----

def test_tracer_seq_strictly_increasing_and_tick_stamped():
    t = Tracer()
    h = t.begin("request", tick=0, group=0, lane=1, request=5)
    t.event("first_token", tick=2, group=0, lane=1, request=5)
    t.end(h, tick=4, tokens=3)
    seqs = [r.seq for r in t.records]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert [r.ph for r in t.records] == ["B", "i", "E"]
    # the end record reuses the begin's identity for pairing
    assert t.records[2].name == "request"
    assert t.records[2].lane == 1
    assert t.open_spans() == []


def test_tracer_args_are_key_sorted_and_wall_free_by_default():
    t = Tracer()
    t.event("enqueue", tick=0, zebra=1, alpha=2)
    (rec,) = t.records
    assert rec.args == (("alpha", 2), ("zebra", 1))
    assert rec.wall is None          # no clock bound -> byte-stable
    # bind_clock(None) is a no-op: the engine always forwards its
    # clock=, tracers only go wall when a REAL clock arrives
    t.bind_clock(None)
    t.event("enqueue", tick=1)
    assert t.records[1].wall is None
    t.bind_clock(lambda: 12.5)
    t.event("enqueue", tick=2)
    assert t.records[2].wall == 12.5


def test_tracer_end_before_begin_tick_rejected():
    t = Tracer()
    h = t.begin("request", tick=5)
    with pytest.raises(ValueError, match="before its begin"):
        t.end(h, tick=3)


def test_tracer_lane_of_and_clear():
    t = Tracer()
    h = t.begin("request", tick=0, lane=1)
    assert t.lane_of(h) == 1
    assert t.lane_of(12345) is None
    t.clear()
    assert t.records == () and t.open_spans() == []
    assert t.begin("request", tick=0) == 0      # seq restarts


def test_null_tracer_is_inert():
    nt = NullTracer()
    assert not nt.enabled and nt.records == ()
    assert nt.event("enqueue", tick=0) == -1
    h = nt.begin("request", tick=0)
    nt.end(h, tick=1)
    nt.bind_clock(lambda: 1.0)
    assert nt.records == () and nt.open_spans() == []
    assert not NULL_TRACER.enabled


# ------------------------------------------------------ metrics registry ----

def test_registry_get_or_create_and_label_identity():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.counter("a") is not reg.counter("a", replica=0)
    assert reg.counter("a", replica=0, x=1) \
        is reg.counter("a", x=1, replica=0)    # label order-insensitive
    reg.counter("a").inc(3)
    reg.gauge("g").max(5)
    reg.gauge("g").max(2)                      # peak keeps 5
    reg.histogram("h").observe(1.0)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 3
    assert snap["gauges"]["g"] == 5
    assert snap["histograms"]["h"]["count"] == 1


def test_empty_histogram_reports_none_never_zero():
    reg = MetricsRegistry()
    h = reg.histogram("ttft")
    assert h.mean() is None and h.percentile(50) is None
    assert percentile([], 95) is None
    snap = reg.snapshot()["histograms"]["ttft"]
    assert snap["mean"] is None and snap["p50"] is None
    assert snap["count"] == 0
    h.observe(4.0)
    assert h.percentile(50) == 4.0


def test_labeled_registry_reset_scopes_to_its_own_metrics():
    reg = MetricsRegistry()
    r0, r1 = reg.labeled(replica=0), reg.labeled(replica=1)
    r0.counter("admissions").inc(2)
    r1.counter("admissions").inc(5)
    r0.reset()
    assert r0.counter("admissions").value == 0
    assert r1.counter("admissions").value == 5  # sibling slice intact
    snap = reg.snapshot()
    assert snap["counters"]["admissions{replica=1}"] == 5


def test_stats_view_is_dict_compatible():
    reg = MetricsRegistry()
    view = StatsView(reg, ("a", "b"))
    view["a"] += 2
    view["b"] = 7
    assert dict(view) == {"a": 2, "b": 7}
    assert {**view, "c": 1} == {"a": 2, "b": 7, "c": 1}
    assert view == {"a": 2, "b": 7}
    assert "a" in view and view.get("zz", -1) == -1
    assert list(view.keys()) == ["a", "b"]     # declaration order
    # late-declared keys join the view (and its reset sweep)
    view["late"] = 9
    assert reg.counter("late").value == 9
    view.reset()
    assert view.values() == [0, 0, 0]


# ------------------------------------------- engine lifecycle tracing ------

def test_engine_defaults_to_null_tracer(planner, base_engine):
    eng = make_engine(planner, base_engine)
    assert eng.tracer is NULL_TRACER
    serve_prompts(eng, n=1)
    assert eng.tracer.records == ()


def test_traced_run_is_well_formed(planner, base_engine):
    t = Tracer()
    eng = make_engine(planner, base_engine, tracer=t)
    done = serve_prompts(eng, n=3)
    assert len(done) == 3 and t.records
    assert t.open_spans() == []                 # drained run: all closed
    seqs = [r.seq for r in t.records]
    assert seqs == list(range(len(seqs)))
    assert {r.name for r in t.records} <= EVENT_VOCAB
    ticks = [r.tick for r in t.records]
    assert ticks == sorted(ticks)               # stamped by a monotone clock
    # per-request lifecycle order: enqueue -> admit -> span begin ->
    # first_token -> span end, with one "request" span per residency
    for rid in (0, 1, 2):
        by_name = {}
        for r in t.records:
            if ("request", rid) in r.args and r.name != "request":
                by_name.setdefault(r.name, r.seq)
        spans = [r for r in t.records
                 if r.name == "request" and ("request", rid) in r.args]
        assert by_name["enqueue"] < by_name["admit"] \
            < by_name["first_token"]
        assert len(spans) == 1 and spans[0].ph == "B"
        assert spans[0].lane in (0, 1)          # a slot lane
    ends = [r for r in t.records if r.ph == "E"]
    assert len(ends) == 3
    assert all(dict(r.args)["reason"] in
               ("eos", "max_new_tokens") for r in ends)


def test_tokens_bitwise_identical_tracer_on_vs_off(planner, base_engine):
    out = []
    for tracer in (None, Tracer()):
        eng = make_engine(planner, base_engine, tracer=tracer)
        done = serve_prompts(eng, n=3, temperature=0.8)
        out.append({r.request_id: list(r.output) for r in done})
    assert out[0] == out[1]


def test_same_seed_engine_trace_byte_identical(planner, base_engine,
                                               tmp_path):
    paths = []
    for i in range(2):
        t = Tracer()
        eng = make_engine(planner, base_engine, tracer=t)
        serve_prompts(eng, n=3)
        paths.append(dump_chrome_trace(t, tmp_path / f"run{i}.json"))
        # the JSONL exporter must agree with itself too
        dump_jsonl(t, tmp_path / f"run{i}.jsonl")
    assert paths[0].read_bytes() == paths[1].read_bytes()
    assert (tmp_path / "run0.jsonl").read_bytes() \
        == (tmp_path / "run1.jsonl").read_bytes()


def test_perfetto_export_round_trip(planner, base_engine, tmp_path):
    t = Tracer()
    eng = make_engine(planner, base_engine, tracer=t)
    serve_prompts(eng, n=2)
    path = write_trace(t, tmp_path / "trace.json")
    doc, errors = load_and_validate(path)
    assert errors == []
    events = doc["traceEvents"]
    # metadata names every process and event track
    meta = [e for e in events if e["ph"] == "M"]
    assert {e["name"] for e in meta} == {"process_name", "thread_name"}
    assert meta[0]["args"]["name"] == "replica 0"
    lanes = {e["args"]["name"] for e in meta
             if e["name"] == "thread_name"}
    assert "queue" in lanes and "engine" in lanes
    # JSONL round-trips record-per-line
    jl = write_trace(t, tmp_path / "trace.jsonl")
    lines = [json.loads(ln) for ln in jl.read_text().splitlines()]
    assert len(lines) == len(t.records)
    assert lines[0]["name"] == "enqueue" and lines[0]["seq"] == 0


def test_validator_catches_malformed_documents():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"nope": 1}) != []
    bad_pair = {"traceEvents": [
        {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
         "args": {"name": "p"}},
        {"ph": "M", "name": "thread_name", "pid": 0, "tid": 0,
         "args": {"name": "t"}},
        {"ph": "E", "name": "request", "pid": 0, "tid": 0, "ts": 1}]}
    assert any("E with no open B" in e
               for e in validate_chrome_trace(bad_pair))
    unclosed = {"traceEvents": [
        {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
         "args": {"name": "p"}},
        {"ph": "M", "name": "thread_name", "pid": 0, "tid": 0,
         "args": {"name": "t"}},
        {"ph": "B", "name": "request", "pid": 0, "tid": 0, "ts": 1}]}
    assert any("unclosed" in e for e in validate_chrome_trace(unclosed))
    decreasing = {"traceEvents": [
        {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
         "args": {"name": "p"}},
        {"ph": "M", "name": "thread_name", "pid": 0, "tid": 0,
         "args": {"name": "t"}},
        {"ph": "i", "name": "a", "pid": 0, "tid": 0, "ts": 5, "s": "t"},
        {"ph": "i", "name": "b", "pid": 0, "tid": 0, "ts": 2, "s": "t"}]}
    assert any("decreases" in e
               for e in validate_chrome_trace(decreasing))
    unnamed = {"traceEvents": [
        {"ph": "i", "name": "a", "pid": 3, "tid": 0, "ts": 0, "s": "t"}]}
    errs = validate_chrome_trace(unnamed)
    assert any("no process_name" in e for e in errs)
    assert any("no thread_name" in e for e in errs)


# ----------------------------------------------------------- reset audit ----

def test_engine_stat_keys_pinned():
    """The full counter surface engine.reset() must zero. Adding a stat
    to the engine without extending this pin (and therefore the reset
    sweep assertions below) fails here on purpose."""
    assert set(ENGINE_STAT_KEYS) == {
        "decode_steps", "prefills", "tokens_generated", "prefix_hits",
        "prefix_tokens_saved", "admissions", "prefix_registrations",
        "preemptions", "resumes", "prefix_evictions", "prefill_chunks",
        "stall_ticks", "sla_expired", "spec_rounds", "spec_drafted",
        "spec_accepted"}
    assert len(ENGINE_STAT_KEYS) == len(set(ENGINE_STAT_KEYS))


def test_engine_reset_zeroes_full_counter_surface(planner, base_engine):
    eng = make_engine(planner, base_engine)
    serve_prompts(eng, n=3)
    assert eng.stats["admissions"] == 3
    assert eng.stats["tokens_generated"] > 0
    assert set(eng.stats.keys()) >= set(ENGINE_STAT_KEYS)
    eng.reset()
    assert all(v == 0 for v in eng.stats.values())
    snap = eng.metrics.snapshot()
    leaked = {k: v for k, v in snap["counters"].items() if v != 0}
    assert leaked == {}, f"counters surviving reset: {leaked}"
    assert all(h["count"] == 0 for h in snap["histograms"].values())
    # a reset engine serves warm and re-accumulates from zero
    serve_prompts(eng, n=1)
    assert eng.stats["admissions"] == 1


def test_cluster_reset_zeroes_registry_and_replica_slices(planner):
    cfg, params = planner
    cluster = EngineCluster(cfg, params, 2, max_batch=2, cache_len=192,
                            seed=0)
    reqs = make_workload(WorkloadConfig(
        n_sessions=4, seed=2, intent_mix=uniform_mix(),
        profile="poisson", max_turns=1, max_new_tokens=3,
        temperature=0.8))
    register_workload_prefixes(cluster, reqs)
    stats = cluster.run_workload(reqs)
    assert stats.summary()["finished"] == len(reqs)
    snap = cluster.metrics.snapshot()
    assert snap["counters"]["cluster_requests_routed"] == len(reqs)
    assert snap["counters"]["admissions{replica=0}"] \
        + snap["counters"]["admissions{replica=1}"] >= len(reqs)
    assert snap["histograms"]["cluster_ttft_ticks"]["count"] == len(reqs)
    cluster.reset()
    snap = cluster.metrics.snapshot()
    assert all(v == 0 for v in snap["counters"].values())
    assert all(h["count"] == 0 for h in snap["histograms"].values())
    # kv gauges are re-published by the recreated pools, never negative
    assert all(v >= 0 for v in snap["gauges"].values())
    for e in cluster.replicas:
        assert all(v == 0 for v in e.stats.values())


# ------------------------------------------------------- cluster tracing ----

@pytest.fixture(scope="module")
def traced_workload():
    return make_workload(WorkloadConfig(
        n_sessions=6, seed=4, intent_mix=uniform_mix(),
        profile="poisson", max_turns=1, max_new_tokens=4,
        temperature=0.8))


@pytest.fixture(scope="module")
def cluster_pool(planner):
    cfg, params = planner
    return EngineCluster(cfg, params, 2, max_batch=2, cache_len=192,
                         seed=0).replicas


def run_cluster(pool, reqs, tracer):
    for e in pool:
        e.reset()
    cluster = EngineCluster(engines=pool, router="intent_affinity",
                            tracer=tracer)
    register_workload_prefixes(cluster, reqs)
    return cluster.run_workload(reqs)


def test_cluster_trace_byte_identical_and_tokens_unperturbed(
        cluster_pool, traced_workload, tmp_path):
    """The acceptance criteria in one place: a fixed-seed 2-replica
    run traces byte-identically across invocations, the export
    validates, and tokens match the untraced run bitwise."""
    outs, paths = [], []
    for i in range(2):
        t = Tracer()
        stats = run_cluster(cluster_pool, traced_workload, t)
        outs.append(stats.outputs())
        assert t.open_spans() == []
        paths.append(dump_chrome_trace(t, tmp_path / f"c{i}.json"))
        if i == 0:
            doc, errors = load_and_validate(paths[0])
            assert errors == []
            pids = {e["pid"] for e in doc["traceEvents"]}
            assert pids == {0, 1}       # one Perfetto process per replica
            groups = {r.group for r in t.records}
            assert groups == {0, 1}     # both replicas actually traced
    assert paths[0].read_bytes() == paths[1].read_bytes()
    # tracer off (NULL_TRACER wipes the shared pool's tracer hookup)
    untraced = run_cluster(cluster_pool, traced_workload, NULL_TRACER)
    assert untraced.outputs() == outs[0] == outs[1]


# ------------------------------------------------------ pipeline tracing ----

def test_pipeline_spans_share_the_trace(planner):
    world = build_world(0)
    tasks = make_benchmark(world, 6)
    imap = build_intent_map(tasks, DEFAULT_REGISTRY)
    gate = IntentGate(imap, ScriptedIntentClassifier(
        0.97, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
    agent = Agent(DEFAULT_REGISTRY, world,
                  PlannerConfig(mode="react", few_shot=False),
                  gate=gate, seed=0)
    t = Tracer()
    pipe = GeckOptPipeline(agent, PipelineConfig(max_concurrent=4),
                           tracer=t)
    results = pipe.run(tasks)
    assert len(results) == len(tasks)
    assert t.open_spans() == []
    names = {r.name for r in t.records}
    assert {"gate", "plan"} <= names
    assert all(r.group == "pipeline" for r in t.records)
    gates = [r for r in t.records if r.name == "gate" and r.ph == "B"]
    assert sum(dict(r.args)["batch"] for r in gates) == len(tasks)
    assert pipe.stats.gate_batches == len(gates)
    # registry-backed PipelineStats: summary matches the span record
    ps = pipe.stats.summary()
    assert ps["admitted"] == len(tasks)
    assert ps["mean_gate_batch"] == pytest.approx(
        len(tasks) / len(gates))
    # the whole doc still validates with string group/lane labels
    assert validate_chrome_trace(chrome_trace(t)) == []


def test_pipeline_stats_empty_summary_uses_none():
    from repro.serving.pipeline import PipelineStats
    ps = PipelineStats()
    assert ps.summary()["mean_gate_batch"] is None
    ps.observe_gate_batch(4)
    assert ps.summary()["mean_gate_batch"] == 4.0
    assert ps.gate_batch_sizes == [4]
