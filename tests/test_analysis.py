"""Static-analysis suite tests (src/repro/analysis).

Three layers:

  * fixture corpus — every rule id RL001-RL205 is seeded exactly once
    per `# expect: RL###` marker in tests/fixtures/analysis/bad_*.py
    and must be caught at *that* line; clean_*.py must stay silent
    (false-positive guard);
  * semantics — suppression precedence (inline > file > baseline),
    baseline round-trips, CLI exit codes / --json / --format github;
  * repo gate — the full `run_repo` sweep reports zero unsuppressed
    findings (the CI invariant), and the runtime mirrors
    (core.tools.validate_effects, kernels.backend.OP_SURFACE checks)
    reject the same drift the analyzers lint for.
"""
import dataclasses
import json
import re
from pathlib import Path

import pytest

from repro.analysis import findings as F
from repro.analysis.backend_check import analyze_backend_registry
from repro.analysis.cli import main as cli_main
from repro.analysis.runner import repo_root, run_paths, run_repo
from repro.core.toolgraph import ToolEffects
from repro.core.tools import (EffectsCoverageError, Tool, ToolRegistry,
                              validate_effects)
from repro.kernels import backend as KB

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"
BAD = ["bad_effects.py", "bad_determinism.py", "bad_kernel.py"]
CLEAN = ["clean_effects.py", "clean_determinism.py", "clean_kernel.py"]
# RL106 fixtures are linted under a tmp src/repro/<pkg>/ tree copy:
# under the fixtures path itself, full scope applies (RL101, not RL106)
BOUNDARY_BAD = ["bad_clock_boundary.py"]
BOUNDARY_CLEAN = ["clean_clock_boundary.py"]

_MARKER = re.compile(r"#\s*expect:\s*(RL\d{3}(?:\s*,\s*RL\d{3})*)")


def expected_markers(path: Path):
    """(line, rule) pairs pinned by `# expect: RL###[, RL###]`."""
    out = set()
    for i, line in enumerate(path.read_text().splitlines(), 1):
        m = _MARKER.search(line)
        if m:
            for rule in m.group(1).split(","):
                out.add((i, rule.strip()))
    return out


def found_pairs(findings):
    return {(f.line, f.rule) for f in findings}


# ------------------------------------------------------ fixture corpus ----

@pytest.mark.parametrize("name", BAD)
def test_bad_fixture_caught_at_exact_lines(name):
    path = FIXTURES / name
    expected = expected_markers(path)
    assert expected, f"{name} has no expect markers"
    findings = run_paths([path])
    assert found_pairs(findings) == expected
    assert not any(f.suppressed for f in findings)


def test_corpus_covers_every_file_rule():
    seeded = set()
    for name in BAD + BOUNDARY_BAD:
        seeded |= {rule for _, rule in expected_markers(FIXTURES / name)}
    file_rules = {r for r in F.RULES if not r.startswith("RL3")}
    assert seeded == file_rules


@pytest.mark.parametrize("name", CLEAN)
def test_clean_fixture_has_no_false_positives(name):
    assert run_paths([FIXTURES / name]) == []


# --------------------------------------- RL106 injected-clock boundary ----

def _run_as(tmp_path, name, rel_dir):
    """Lint a fixture as if it lived at <repo>/<rel_dir>/<name>."""
    tree = tmp_path / rel_dir
    tree.mkdir(parents=True, exist_ok=True)
    dst = tree / name
    dst.write_text((FIXTURES / name).read_text())
    return run_paths([dst], root=tmp_path)


@pytest.mark.parametrize("name", BOUNDARY_BAD)
def test_boundary_fixture_caught_at_exact_lines(tmp_path, name):
    expected = expected_markers(FIXTURES / name)
    assert expected, f"{name} has no expect markers"
    findings = _run_as(tmp_path, name, "src/repro/models")
    assert found_pairs(findings) == expected
    assert {f.rule for f in findings} == {"RL106"}
    assert all(f.hint for f in findings)


@pytest.mark.parametrize("name", BOUNDARY_CLEAN)
def test_clean_boundary_fixture_silent(tmp_path, name):
    # RL103/RL104/RL105 bait in the fixture must NOT fire here
    assert _run_as(tmp_path, name, "src/repro/training") == []


@pytest.mark.parametrize("rel_dir", ["src/repro/obs", "src/repro/launch"])
def test_clock_providers_are_allowlisted(tmp_path, rel_dir):
    assert _run_as(tmp_path, "bad_clock_boundary.py", rel_dir) == []


def test_full_scope_dirs_flag_same_reads_as_rl101(tmp_path):
    findings = _run_as(tmp_path, "bad_clock_boundary.py",
                       "src/repro/serving")
    assert {f.rule for f in findings} == {"RL101"}
    assert {f.line for f in findings} == \
        {line for line, _ in expected_markers(
            FIXTURES / "bad_clock_boundary.py")}


def test_wallclock_scope_dispatch():
    from repro.analysis.determinism import wallclock_scope
    assert wallclock_scope("src/repro/serving/engine.py") == "full"
    assert wallclock_scope("src/repro/core/gate.py") == "full"
    assert wallclock_scope("tests/fixtures/analysis/x.py") == "full"
    assert wallclock_scope("src/repro/obs/tracer.py") == "allow"
    assert wallclock_scope("src/repro/launch/serve.py") == "allow"
    assert wallclock_scope("src/repro/training/loop.py") == "boundary"
    assert wallclock_scope("src/repro/models/model.py") == "boundary"
    assert wallclock_scope("src/repro/analysis/runner.py") == "boundary"


def test_findings_carry_hints_and_severity():
    findings = run_paths([FIXTURES / "bad_effects.py"])
    assert findings and all(f.hint for f in findings)
    assert {f.severity for f in findings} <= {"error", "warning"}
    # RL003 (over-declaration) is the one warning-severity rule: it
    # must not gate --fail-on error but must gate --fail-on warning
    rules_at_error = {f.rule for f in F.active(findings, "error")}
    rules_at_warn = {f.rule for f in F.active(findings, "warning")}
    assert "RL003" not in rules_at_error
    assert "RL003" in rules_at_warn


# -------------------------------------------------- suppression layers ----

def _suppressed_fixture_findings():
    return run_paths([FIXTURES / "suppressed.py"])


def test_inline_and_file_suppression():
    findings = _suppressed_fixture_findings()
    by_msg = {f.message: f for f in findings}
    assert by_msg["import random"].suppressed == "inline"
    assert by_msg["list() over an unordered set expression"] \
        .suppressed == "file"
    active = F.active(findings)
    assert [f.message for f in active] == \
        ["stdlib random call random.choice()"]


def test_baseline_matches_on_message_not_line(tmp_path):
    leftover = F.active(_suppressed_fixture_findings())[0]
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"accepted": [
        {"rule": leftover.rule, "path": leftover.path,
         # line intentionally absent: baseline survives line drift
         "message": leftover.message}]}))
    findings = run_paths([FIXTURES / "suppressed.py"], baseline=bl)
    assert F.active(findings) == []
    assert {f.suppressed for f in findings} == \
        {"inline", "file", "baseline"}


def test_write_baseline_round_trip(tmp_path):
    findings = _suppressed_fixture_findings()
    bl = tmp_path / "baseline.json"
    F.write_baseline(bl, findings)
    triples = F.load_baseline(bl)
    # only the unsuppressed finding is accepted into the baseline
    assert len(triples) == 1
    assert F.active(F.apply_baseline(findings, triples)) == []


# ------------------------------------------------------------------ CLI ----

def test_cli_exit_codes(capsys):
    bad = str(FIXTURES / "bad_determinism.py")
    assert cli_main([bad]) == 1
    assert cli_main([bad, "--fail-on", "never"]) == 0
    assert cli_main([str(FIXTURES / "clean_determinism.py")]) == 0
    out = capsys.readouterr().out
    assert "0 error(s), 0 warning(s), 0 suppressed" in out


def test_cli_json_report(tmp_path):
    report = tmp_path / "report.json"
    assert cli_main([str(FIXTURES / "bad_determinism.py"),
                     "--json", str(report)]) == 1
    data = json.loads(report.read_text())
    assert data["summary"]["errors"] == len(data["findings"]) > 0
    assert set(data["summary"]["rules"]) == \
        {"RL101", "RL102", "RL103", "RL104", "RL105"}
    sample = data["findings"][0]
    assert {"rule", "severity", "path", "line", "message",
            "hint", "suppressed"} <= set(sample)


def test_cli_github_format(capsys):
    assert cli_main([str(FIXTURES / "bad_determinism.py"),
                     "--format", "github"]) == 1
    out = capsys.readouterr().out
    assert "::error file=tests/fixtures/analysis/bad_determinism.py," \
        in out
    assert "title=RL101" in out


def test_cli_write_then_use_baseline(tmp_path):
    bad = str(FIXTURES / "bad_determinism.py")
    bl = tmp_path / "accepted.json"
    assert cli_main([bad, "--baseline", str(bl),
                     "--write-baseline"]) == 0
    # with every current finding accepted, the same scope now passes
    assert cli_main([bad, "--baseline", str(bl)]) == 0
    # and a different file's findings still fail
    assert cli_main([str(FIXTURES / "bad_effects.py"),
                     "--baseline", str(bl)]) == 1


# -------------------------------------------------------- repo CI gate ----

def test_repo_sweep_is_clean():
    findings = run_repo()
    unsuppressed = [f for f in findings if not f.suppressed]
    assert unsuppressed == [], "\n".join(f.render() for f in unsuppressed)


def test_backend_registry_check_is_clean():
    root = repo_root()
    assert analyze_backend_registry(root / "src/repro/kernels") == []


def test_rl302_flags_orphan_kernel_module(tmp_path):
    (tmp_path / "backend.py").write_text(
        "from repro.kernels import ref as R\n"
        "from repro.kernels.flash_decode import flash_decode\n")
    (tmp_path / "flash_decode.py").write_text("def flash_decode(): pass\n")
    (tmp_path / "orphan.py").write_text("def orphan_kernel(): pass\n")
    findings = analyze_backend_registry(tmp_path)
    assert [f.rule for f in findings] == ["RL302"]
    assert "'orphan'" in findings[0].message


# ---------------------------------------------------- runtime mirrors ----

def _mini_registry(*names):
    reg = ToolRegistry()
    for n in names:
        reg.register(Tool(n, "lib", "doc", ()))
    return reg


def test_validate_effects_accepts_exact_coverage():
    validate_effects(_mini_registry("a", "b"),
                     {"a": ToolEffects(writes=frozenset({"handles"})),
                      "b": ToolEffects(reads=frozenset({"map"}))})


def test_validate_effects_rejects_coverage_gaps():
    with pytest.raises(EffectsCoverageError, match="without effects"):
        validate_effects(_mini_registry("a", "b"),
                         {"a": ToolEffects()})
    with pytest.raises(EffectsCoverageError, match="unregistered"):
        validate_effects(_mini_registry("a"),
                         {"a": ToolEffects(), "ghost": ToolEffects()})
    with pytest.raises(EffectsCoverageError, match="unknown resources"):
        validate_effects(_mini_registry("a"),
                         {"a": ToolEffects(writes=frozenset({"nope"}))})


def test_op_surface_signature_checks():
    ok = lambda q, k, v, *, causal=True, window=0, cap=0.0, \
        scale=0.0, q_offset=0: None
    assert KB.check_op_signature("attention", ok) is None
    # extra defaulted params are allowed (the reference attention's
    # kv_len rides on exactly this rule)
    extra_ok = lambda q, k, v, kv_len=None, *, causal=True, window=0, \
        cap=0.0, scale=0.0, q_offset=0: None
    assert KB.check_op_signature("attention", extra_ok) is None
    renamed = lambda query, k, v, *, causal=True, window=0, cap=0.0, \
        scale=0.0, q_offset=0: None
    assert "positional params" in KB.check_op_signature(
        "attention", renamed)
    undefaulted_extra = lambda q, k, v, block_k, *, causal=True, \
        window=0, cap=0.0, scale=0.0, q_offset=0: None
    assert "without a default" in KB.check_op_signature(
        "attention", undefaulted_extra)
    missing_kw = lambda q, k, v, *, causal=True: None
    assert "missing keyword" in KB.check_op_signature(
        "attention", missing_kw)


def test_register_backend_rejects_drifted_impl():
    ref = KB.get_backend("reference")
    broken = dataclasses.replace(
        ref, name="broken",
        router_topk=lambda wrong_name, k: None)
    assert "router_topk" in KB.validate_backend(broken)
    with pytest.raises(KB.BackendContractError, match="router_topk"):
        KB.register_backend(broken)
    assert "broken" not in KB.available_backends()
    # the missing-impl defect maps to RL303's "not implemented"
    hollow = dataclasses.replace(ref, name="hollow", mlstm_scan=None)
    assert "not implemented" in KB.validate_backend(hollow)["mlstm_scan"]


def test_both_required_backends_validate_clean():
    for name in ("reference", "pallas"):
        assert KB.validate_backend(KB.get_backend(name)) == {}
