"""Sharding-rule unit tests: divisibility guards and spec structure.

Uses AbstractMesh so no 256-device runtime is needed; the full lower+
compile path is exercised by launch/dryrun.py (results committed under
results/dryrun)."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="dev dependency; see "
                                         "requirements-dev.txt")
from hypothesis import given, settings, strategies as st
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.common.config import INPUT_SHAPES
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.distributed import sharding as shd
from repro.models.model import param_shapes

MESH = AbstractMesh((16, 16), ("data", "model"))
MESH3 = AbstractMesh((2, 16, 16), ("pod", "data", "model"))


def _axis_prod(mesh, axes):
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
    return n


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH, MESH3])
def test_param_specs_divisible(arch, mesh):
    """Every sharded dim must be divisible by its mesh-axis product."""
    cfg = get_config(arch)
    shapes = param_shapes(cfg)
    strategy = shd.ShardingStrategy()
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    n_sharded = 0
    for path, leaf in flat:
        keys = tuple(str(getattr(k, "key", getattr(k, "name",
                                                   getattr(k, "idx", k))))
                     for k in path)
        spec = shd.param_spec(keys, leaf, cfg, mesh, strategy)
        assert len(spec) <= len(leaf.shape)
        for dim, axes in zip(leaf.shape, spec):
            prod = _axis_prod(mesh, axes)
            assert dim % prod == 0, (keys, leaf.shape, spec)
            n_sharded += prod > 1
    assert n_sharded > 0, "nothing sharded at all"


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "arctic-480b"])
def test_big_models_fully_sharded(arch):
    """≥100B configs must shard weights over both data and model axes
    (FSDP), or they cannot fit 16GB/chip."""
    cfg = get_config(arch)
    shapes = param_shapes(cfg)
    strategy = shd.ShardingStrategy(fsdp=True)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    big = [(p, l) for p, l in flat if l.size * 2 > 2 ** 28]  # >256MB bf16
    for path, leaf in big:
        keys = tuple(str(getattr(k, "key", getattr(k, "name",
                                                   getattr(k, "idx", k))))
                     for k in path)
        spec = shd.param_spec(keys, leaf, cfg, MESH, strategy)
        total = 1
        for dim, axes in zip(leaf.shape, spec):
            total *= _axis_prod(MESH, axes)
        assert total >= 16, (keys, leaf.shape, spec)


def test_moe_experts_sharded_over_model():
    cfg = get_config("kimi-k2-1t-a32b")
    strategy = shd.ShardingStrategy()
    leaf = jax.ShapeDtypeStruct((60, 384, 7168, 2048), jnp.bfloat16)
    spec = shd.param_spec(("segments", "1", "0", "moe", "w_gate"), leaf,
                          cfg, MESH, strategy)
    assert spec[1] == "model"          # expert axis


def test_kv_not_divisible_stays_replicated():
    """hymba kv=5 heads: kv projections can't shard 5 heads over 16."""
    cfg = get_config("hymba-1.5b")
    strategy = shd.ShardingStrategy(fsdp=False)
    leaf = jax.ShapeDtypeStruct((2, 1600, 5 * 64), jnp.bfloat16)
    spec = shd.param_spec(("segments", "0", "0", "attn", "wk"), leaf, cfg,
                          MESH, strategy)
    # kv_dim=320 divisible by 16 → sharded on head_dim splits; allowed.
    # qwen1.5-32b kv_dim=5120 % 16 == 0 as well; test a truly indivisible
    # case:
    leaf2 = jax.ShapeDtypeStruct((2, 1600, 5 * 13), jnp.bfloat16)
    spec2 = shd.param_spec(("segments", "0", "0", "attn", "wk"), leaf2,
                           cfg, MESH, strategy)
    assert spec2[-1] is None


@given(st.integers(1, 4096))
@settings(max_examples=100, deadline=None)
def test_fit_always_divides(dim):
    axes = shd._fit(MESH, dim, ("data", "model"))
    prod = _axis_prod(MESH, axes)
    assert dim % prod == 0


def test_batch_sharding_decode_batch_one():
    """long_500k (batch=1) must not shard the batch axis."""
    cfg = get_smoke_config("gemma2-2b")
    shape = INPUT_SHAPES["long_500k"]
    batch = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    sh = shd.batch_sharding(batch, cfg, shape,
                            jax.make_mesh((1, 1), ("data", "model")),
                            shd.ShardingStrategy())
    assert sh["tokens"].spec[0] is None or sh["tokens"].spec == P(None, None)
