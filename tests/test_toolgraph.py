"""Tool-graph compiler: DAG validation/scheduling invariants and the
fused-execution ≡ sequential-execution parity contract.

Driven twice — seeded random graphs and call streams (always run) and
hypothesis property tests (run when the dev dependency is installed) —
plus end-to-end parity sweeps over real benchmark tasks: the compiled
planner and the fused batch executor must be bitwise invisible to every
observable (workspace state, rng stream, observations, history,
quality metrics).
"""
import numpy as np
import pytest

from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier
from repro.core.intents import build_intent_map
from repro.core.planner import CompiledStep, PlannerConfig, ScriptedPlanner
from repro.core.tools import DEFAULT_REGISTRY
from repro.core.toolgraph import (CycleError, DuplicateNodeError,
                                  ToolEffects, ToolGraph, ToolGraphError,
                                  ToolNode, UnknownDepError,
                                  UnknownToolError, compile_calls,
                                  infer_deps)
from repro.env.tasks import ToolCall, make_benchmark
from repro.env.tools_impl import (TOOL_EFFECTS, ToolError, Workspace,
                                  WorkspaceHazardError, execute_graph,
                                  execute_graph_batch, execute_tool,
                                  tool_effects)
from repro.env.world import build_world


@pytest.fixture(scope="module")
def world():
    return build_world(0, n_images=200)


@pytest.fixture(scope="module")
def tasks(world):
    return make_benchmark(world, 32)


def _ws(world, seed=0):
    return Workspace(world=world, rng=np.random.default_rng(seed))


def _ws_state(ws):
    """Every observable a tool can touch, rng stream included."""
    return (ws.handles, ws.map_layers, ws.detections, ws.landcover,
            ws.artifacts, ws.last_answer, ws.ui_state,
            ws.rng.bit_generator.state)


# ------------------------------------------------------ DAG validation ----

def test_schedule_waves_respect_deps_and_order():
    g = ToolGraph([ToolNode(0, "a"), ToolNode(1, "b", deps=(0,)),
                   ToolNode(2, "c"), ToolNode(3, "d", deps=(1, 2))])
    assert g.wave_schedule() == [[0, 2], [1], [3]]


def test_schedule_is_input_order_independent():
    nodes = [ToolNode(0, "a"), ToolNode(1, "b", deps=(0,)),
             ToolNode(2, "c", deps=(0,)), ToolNode(3, "d", deps=(1, 2))]
    want = ToolGraph(nodes).wave_schedule()
    assert ToolGraph(nodes[::-1]).wave_schedule() == want
    assert ToolGraph([nodes[2], nodes[0], nodes[3], nodes[1]]
                     ).wave_schedule() == want


def test_cycle_raises_typed_error():
    g = ToolGraph([ToolNode(0, "a", deps=(1,)),
                   ToolNode(1, "b", deps=(0,))])
    with pytest.raises(CycleError):
        g.wave_schedule()
    with pytest.raises(ToolGraphError):       # subclass relationship
        g.validate()


def test_self_dependency_raises():
    with pytest.raises(CycleError):
        ToolGraph([ToolNode(0, "a", deps=(0,))]).wave_schedule()


def test_unknown_tool_raises_at_validate_and_compile():
    g = ToolGraph([ToolNode(0, "no_such_tool")])
    with pytest.raises(UnknownToolError):
        g.validate(known_tools=DEFAULT_REGISTRY.names())
    with pytest.raises(UnknownToolError):
        DEFAULT_REGISTRY.validate_graph(g)
    with pytest.raises(UnknownToolError):
        compile_calls([ToolCall("no_such_tool", {})], TOOL_EFFECTS)
    # the env-side lookup mirrors execute_tool semantics instead
    with pytest.raises(ToolError):
        tool_effects("no_such_tool")


def test_dangling_dep_and_duplicate_id_raise():
    with pytest.raises(UnknownDepError):
        ToolGraph([ToolNode(0, "a", deps=(7,))]).validate()
    with pytest.raises(DuplicateNodeError):
        ToolGraph([ToolNode(0, "a"), ToolNode(0, "b")]).validate()


def test_random_dags_schedule_invariants():
    """Seeded random DAGs: every wave schedule is a permutation of the
    node ids, no node is scheduled before a dependency, and waves are
    exactly the longest-chain depths."""
    rng = np.random.default_rng(7)
    for _ in range(50):
        n = int(rng.integers(1, 14))
        nodes = []
        for i in range(n):
            k = int(rng.integers(0, min(i, 3) + 1))
            deps = tuple(sorted(rng.choice(i, size=k, replace=False))
                         ) if i and k else ()
            nodes.append(ToolNode(i, f"t{i}", deps=deps))
        g = ToolGraph(nodes)
        waves = g.validate().wave_schedule()
        flat = [i for w in waves for i in w]
        assert sorted(flat) == list(range(n))
        pos = {nid: w for w, wave in enumerate(waves) for nid in wave}
        for node in nodes:
            for d in node.deps:
                assert pos[d] < pos[node.node_id]
            want = (max((pos[d] for d in node.deps), default=-1) + 1)
            assert pos[node.node_id] == want


def test_hypothesis_random_dags_schedule_invariants():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(deadline=None, max_examples=60)
    @hyp.given(st.data())
    def run(data):
        n = data.draw(st.integers(1, 12))
        nodes = []
        for i in range(n):
            deps = tuple(data.draw(st.sets(st.integers(0, i - 1),
                                           max_size=3))) if i else ()
            nodes.append(ToolNode(i, f"t{i}", deps=deps))
        waves = ToolGraph(nodes).validate().wave_schedule()
        pos = {nid: w for w, wave in enumerate(waves) for nid in wave}
        flat = [i for w in waves for i in w]
        assert sorted(flat) == list(range(n))
        for node in nodes:
            assert all(pos[d] < pos[node.node_id] for d in node.deps)

    run()


# ------------------------------------------------------- dep inference ----

def test_effects_table_covers_exactly_the_registry():
    assert set(TOOL_EFFECTS) == set(DEFAULT_REGISTRY.names())


def test_infer_deps_serializes_hazards():
    calls = [ToolCall("load_images", {"image_ids": [0]}),   # writes handles
             ToolCall("filter_clouds", {}),                 # rw handles
             ToolCall("wiki_search", {"query": "x"}),       # writes answer
             ToolCall("detect_objects", {})]                # reads handles
    g = compile_calls(calls, TOOL_EFFECTS)
    assert g.node(1).deps == (0,)            # RAW+WAW on handles
    assert g.node(2).deps == ()              # pure catalog read
    assert 1 in g.node(3).deps               # reads handles after writer
    assert 2 not in g.node(3).deps           # no shared resource


def test_infer_deps_rng_serializes_stochastic_tools():
    """Every pair of rng-writing tools must be chained, whatever other
    resources they touch — their relative order changes draws."""
    calls = [ToolCall("transcribe_audio", {}),   # answer+rng writer
             ToolCall("change_detection", {})]   # rng-only writer
    g = compile_calls(calls, TOOL_EFFECTS)
    assert g.node(1).deps == (0,)
    assert g.wave_schedule() == [[0], [1]]


def test_infer_deps_war_orders_reader_before_writer():
    eff = {"r": ToolEffects(reads=frozenset({"x"})),
           "w": ToolEffects(writes=frozenset({"x"}))}
    g = infer_deps([ToolCall("w", {}), ToolCall("r", {}),
                    ToolCall("w", {})], eff)
    assert g.node(1).deps == (0,)
    assert g.node(2).deps == (0, 1)          # WAW on 0, WAR on 1


def test_infer_deps_accepts_callable_effects():
    g = infer_deps([ToolCall("anything", {})],
                   lambda t: ToolEffects())
    assert g.node(0).deps == ()


# ------------------------------------ fused ≡ sequential execution --------

def _random_call_stream(rng, n):
    names = DEFAULT_REGISTRY.names()
    return [ToolCall(names[int(rng.integers(0, len(names)))], {})
            for _ in range(n)]


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_graph_execution_bitwise_equals_sequential(world, seed):
    """Any compiled call stream: wave execution must leave the workspace
    (rng stream included) and the observation list bitwise identical to
    naive emission-order execution."""
    rng = np.random.default_rng(seed)
    calls = _random_call_stream(rng, int(rng.integers(2, 12)))
    graph = compile_calls(calls, TOOL_EFFECTS)

    ws_seq = _ws(world, seed)
    seq_obs = []
    for i, c in enumerate(calls):
        try:
            out = execute_tool(ws_seq, c.tool, c.args)
            seq_obs.append((i, f"{c.tool} -> {out}", True))
        except Exception as e:
            seq_obs.append((i, f"{c.tool} -> ERROR: {e}", False))

    ws_dag = _ws(world, seed)
    dag_obs = [(o.node_id, o.text, o.ok)
               for o in execute_graph(ws_dag, graph)]
    assert dag_obs == seq_obs
    assert _ws_state(ws_dag) == _ws_state(ws_seq)


def test_batch_execution_matches_solo_and_sorts_observations(world):
    """A fused multi-session batch must reproduce each session's solo
    run exactly, return observations sorted by node id, and be invariant
    to entry order."""
    def entry(seed):
        rng = np.random.default_rng(100 + seed)
        calls = _random_call_stream(rng, 6)
        return _ws(world, seed), compile_calls(calls, TOOL_EFFECTS)

    solo = {}
    for s in range(4):
        ws, g = entry(s)
        solo[s] = ([(o.node_id, o.text, o.ok)
                    for o in execute_graph(ws, g)], _ws_state(ws))

    for order in ([0, 1, 2, 3], [3, 1, 0, 2]):
        entries = {}
        for s in order:
            ws, g = entry(s)
            entries[s] = (ws, g)
        out = execute_graph_batch(
            [(s, entries[s][0], entries[s][1]) for s in order])
        assert sorted(out) == [0, 1, 2, 3]
        for s in order:
            obs = [(o.node_id, o.text, o.ok) for o in out[s]]
            assert obs == solo[s][0]
            assert obs == sorted(obs)             # node-id order
            assert _ws_state(entries[s][0]) == solo[s][1]


def test_batch_rejects_aliased_workspaces_and_duplicate_keys(world):
    ws = _ws(world)
    g = compile_calls([ToolCall("wiki_search", {"query": "x"})],
                      TOOL_EFFECTS)
    with pytest.raises(WorkspaceHazardError):
        execute_graph_batch([(0, ws, g), (1, ws, g)])
    with pytest.raises(WorkspaceHazardError):
        execute_graph_batch([(0, ws, g), (0, _ws(world, 1), g)])


def test_tool_error_does_not_cancel_independent_nodes(world):
    """A failing node reports ERROR like the linear loop and its
    non-dependent siblings still execute."""
    calls = [ToolCall("detect_objects", {}),      # fails: no handles
             ToolCall("wiki_search", {"query": "port of rotterdam"})]
    graph = compile_calls(calls, TOOL_EFFECTS)
    assert graph.node(1).deps == ()                # truly independent
    ws = _ws(world)
    obs = execute_graph(ws, graph)
    assert [o.ok for o in obs] == [False, True]
    assert "ERROR" in obs[0].text
    assert obs[1].text.startswith("wiki_search -> ")   # sibling ran


# --------------------------------- compiled planner end-to-end parity -----

@pytest.mark.parametrize("mode,accuracy", [("react", 0.97), ("cot", 0.0)])
def test_compiled_agent_bitwise_equals_linear(world, tasks, mode,
                                              accuracy):
    """compile_plans must not change ANY observable task outcome —
    workspace end-state, rng stream, executed tools, completion,
    fallback — across gate-accuracy regimes (0.0 forces the
    TOOL_NOT_FOUND fallback path under compilation)."""
    imap = build_intent_map(tasks, DEFAULT_REGISTRY)
    libs = DEFAULT_REGISTRY.libraries()
    for i, t in enumerate(tasks[:12]):
        res = {}
        for cp in (False, True):
            cfg = PlannerConfig(mode=mode, few_shot=False,
                                compile_plans=cp)
            gate = IntentGate(imap, ScriptedIntentClassifier(
                accuracy, np.random.default_rng(i)), libs)
            res[cp] = Agent(DEFAULT_REGISTRY, world, cfg, gate=gate,
                            seed=0).run_task(t, task_seed=i)
        lin, comp = res[False], res[True]
        assert _ws_state(lin.workspace) == _ws_state(comp.workspace)
        assert lin.executed_tools == comp.executed_tools
        assert lin.completed_plan == comp.completed_plan
        assert lin.fallback_used == comp.fallback_used
        assert lin.intent_predicted == comp.intent_predicted
        # the budget is charged in virtual steps, not round-trips
        assert comp.ledger.n_virtual_steps == lin.ledger.n_plan_steps
        assert comp.ledger.n_round_trips <= lin.ledger.n_round_trips


def test_compiled_planner_emits_validated_graphs(world, tasks):
    cfg = PlannerConfig(mode="react", few_shot=False, compile_plans=True)
    p = ScriptedPlanner(cfg, DEFAULT_REGISTRY, seed=3)
    p.start_task(tasks[0])
    step = p.next_compiled_step(tasks[0], dict(DEFAULT_REGISTRY.tools),
                                [], cfg.max_steps)
    assert isinstance(step, CompiledStep)
    DEFAULT_REGISTRY.validate_graph(step.graph)    # typed errors if not
    assert step.n_virtual >= len(step.graph.nodes) > 0
    # the serialized completion prices the DAG (ids + deps included)
    assert '"deps"' in p.serialize_completion(step)
