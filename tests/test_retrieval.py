"""Tool-catalog scaling + semantic retrieval (core/catalog.py,
core/retriever.py): deterministic catalog generation, retrieval
ranking, miss-and-widen fallback, toolset prefix sharing on the engine,
and the bitwise-outcome invariant the whole layer rests on.
"""
import copy
import json
import os
from pathlib import Path

import jax
import numpy as np
import pytest

from benchmarks.check_regression import compare
from benchmarks.retrieval_bench import _outcome_fingerprint
from repro.analysis.effects_check import analyze_effects
from repro.configs import get_smoke_config
from repro.core.agent import Agent
from repro.core.catalog import (FAMILIES, build_catalog,
                                catalog_intent_libraries,
                                catalog_intent_map, family_of)
from repro.core.gate import IntentGate, ScriptedIntentClassifier
from repro.core.planner import PlannerConfig
from repro.core.retriever import ToolRetriever, ToolsetExposure
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.tools_impl import (CATALOG_FAMILY_EFFECTS, Workspace,
                                  catalog_effects, execute_tool)
from repro.env.tasks import make_benchmark
from repro.env.world import build_world
from repro.models.model import init_params
from repro.serving.engine import InferenceEngine
from repro.serving.pipeline import GeckOptPipeline, PipelineConfig

SIZES = (8, 32, 128, 512)
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
TOOLS_IMPL = (Path(__file__).parent.parent / "src" / "repro" / "env"
              / "tools_impl.py")


@pytest.fixture(scope="module")
def world():
    return build_world(0)


@pytest.fixture(scope="module")
def tasks(world):
    return make_benchmark(world, 10, seed=0)


def _agent(world, registry, exposure, acc=0.97, k=8, seed=0):
    imap = catalog_intent_map(registry)
    gate = IntentGate(imap, ScriptedIntentClassifier(
        acc, np.random.default_rng(seed)), registry.libraries())
    retriever = (ToolRetriever(registry,
                               catalog_intent_libraries(registry), k=k)
                 if exposure == "retrieved" else None)
    return Agent(registry, world,
                 PlannerConfig(mode="react", few_shot=False),
                 gate=gate, seed=seed, retriever=retriever,
                 exposure=exposure)


# ------------------------------------------------------------ catalog ----

def test_catalog_deterministic_and_sized():
    for n in (1, 8, 48, 128, 512):
        a, b = build_catalog(n, seed=0), build_catalog(n, seed=0)
        assert len(a.tools) == n
        assert a.catalog_text() == b.catalog_text()
    # n <= base: a registration-order prefix of the hand-written registry
    base_order = list(DEFAULT_REGISTRY.tools)
    assert list(build_catalog(8).tools) == base_order[:8]
    # past the base, every family contributes
    big = build_catalog(512, seed=0)
    libs = set(big.libraries())
    for fam in FAMILIES:
        assert fam.library in libs


def test_catalog_intent_libraries_track_presence():
    for n in SIZES:
        reg = build_catalog(n, seed=0)
        present = set(reg.libraries())
        for intent, lib_names in catalog_intent_libraries(reg).items():
            assert lib_names, intent
            assert set(lib_names) <= present


def test_generated_tools_execute_and_declare_effects(world):
    reg = build_catalog(512, seed=0)
    effects = catalog_effects(reg)
    assert set(effects) == set(reg.tools)
    # one member of each family actually dispatches against a workspace
    done = set()
    for name in reg.tools:
        fam = family_of(name)
        if fam is None or fam in done:
            continue
        ws = Workspace(world, np.random.default_rng(0),
                       handles=sorted(world.images)[:2])
        obs = execute_tool(ws, name, {"handles": ws.handles})
        assert isinstance(obs, str) and obs
        done.add(fam)
    assert done == set(CATALOG_FAMILY_EFFECTS)


# ---------------------------------------------------------- retrieval ----

def test_ranking_deterministic_and_batch_matches_single():
    reg = build_catalog(128, seed=0)
    r = ToolRetriever(reg, catalog_intent_libraries(reg), k=8)
    queries = ["plot xview1 images near Tampa Bay",
               "how many ships are in the harbor",
               "transcribe the briefing recording"]
    intents = ["load_filter_plot", "detection_analysis", None]
    batch = r.rank_batch(queries, intents)
    for q, it, ranked in zip(queries, intents, batch):
        assert ranked == r.rank(q, it)            # batch == single
        assert ranked == r.rank(q, it)            # and stable
        assert sorted(ranked) == sorted(reg.tools)  # a full permutation


def test_exposure_key_and_widen_semantics():
    reg = build_catalog(64, seed=0)
    r = ToolRetriever(reg, catalog_intent_libraries(reg), k=4)
    exp = r.retrieve("count the images", "load_filter_plot")
    assert exp.k == 4 and exp.exposed == tuple(sorted(exp.ranking[:4]))
    assert exp.key_str.startswith("toolset:")
    # same toolset from a different exposure object -> same prefix key
    assert exp.key_str == ToolsetExposure(list(exp.ranking), 4).key_str
    exp.widen_once()
    assert (exp.k, exp.widens) == (8, 1)
    exp.widen_full()
    assert exp.k == len(reg.tools)
    # at k == n the serialized subset IS the full catalog, byte-for-byte
    assert exp.catalog_text(reg) == reg.catalog_text()
    # k0 clamps to the catalog size
    assert ToolsetExposure(list(exp.ranking), 10_000).k == len(reg.tools)


def test_agent_exposure_validation(world):
    with pytest.raises(ValueError):
        Agent(DEFAULT_REGISTRY, world, PlannerConfig(),
              exposure="retrieved")
    with pytest.raises(AssertionError):
        Agent(DEFAULT_REGISTRY, world, PlannerConfig(),
              exposure="bogus")


# -------------------------------------------------- outcome invariance ----

@pytest.mark.parametrize("acc", [0.0, 0.5, 0.97])
def test_outcomes_bitwise_identical_across_exposures(world, tasks, acc):
    """The planner's decision stream reads the gated visible toolset,
    never the serialized catalog text — so retrieval (even under a
    fully wrong gate, where every task takes the fallback) replays the
    all-tools run bitwise."""
    reg = build_catalog(128, seed=0)
    all_res = [_agent(world, reg, "all", acc=acc)
               .run_task(t, task_seed=i) for i, t in enumerate(tasks)]
    ret_res = [_agent(world, reg, "retrieved", acc=acc)
               .run_task(t, task_seed=i) for i, t in enumerate(tasks)]
    for a, b in zip(all_res, ret_res):
        assert _outcome_fingerprint(a) == _outcome_fingerprint(b)
        assert b.toolset is not None and a.toolset is None


def test_miss_and_widen_recovers_and_charges(world, tasks):
    """k=1 guarantees misses: widening must recover every executed tool
    without touching outcomes, and each escalation must be charged to
    the ledger as a 'widen' entry."""
    reg = build_catalog(128, seed=0)
    base = [_agent(world, reg, "all").run_task(t, task_seed=i)
            for i, t in enumerate(tasks)]
    tiny = [_agent(world, reg, "retrieved", k=1).run_task(t, task_seed=i)
            for i, t in enumerate(tasks)]
    assert sum(r.widens for r in tiny) > 0
    for a, b in zip(base, tiny):
        assert _outcome_fingerprint(a) == _outcome_fingerprint(b)
        assert b.ledger.summary()["widens"] == b.widens
        widen_entries = [e for e in b.ledger.entries
                        if e.kind == "widen"]
        assert len(widen_entries) == b.widens
        # escalations cost tokens but no planner round-trips
        assert all(e.prompt_tokens > 0 and e.tool_calls == 0
                   for e in widen_entries)


def test_pipeline_retrieval_matches_sequential(world, tasks):
    reg = build_catalog(96, seed=0)
    solo = [_agent(world, reg, "retrieved").run_task(t, task_seed=i)
            for i, t in enumerate(tasks)]
    pipe = GeckOptPipeline(
        _agent(world, reg, "retrieved"),
        PipelineConfig(max_concurrent=4, engine_turns=False))
    fused = pipe.run(tasks)
    assert pipe.stats.summary()["retrievals"] == len(tasks)
    assert (pipe.stats.summary()["retrieval_widens"]
            == sum(r.widens for r in fused))
    for s, f in zip(solo, fused):
        # batched wave retrieval == per-task retrieval, down to tokens
        assert s.toolset == f.toolset
        assert _outcome_fingerprint(s) == _outcome_fingerprint(f)
        assert s.ledger.total_tokens == f.ledger.total_tokens


# ------------------------------------------- engine prefix sharing ----

@pytest.mark.parametrize("kv_mode", ["dense", "paged"])
def test_toolset_prefix_sharing_on_engine(world, kv_mode):
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = InferenceEngine(cfg, params, max_batch=4, cache_len=4096,
                             kv_mode=kv_mode)
    reg = build_catalog(64, seed=0)
    tasks16 = make_benchmark(world, 16, seed=0)
    pipe = GeckOptPipeline(_agent(world, reg, "retrieved", k=8),
                           PipelineConfig(max_concurrent=8),
                           engine=engine)
    results = pipe.run(tasks16)
    assert len(results) == 16
    keys = set(engine.prefixes)
    assert keys and all(k.startswith("toolset:") for k in keys)
    # tasks retrieving the same toolset share one prefix prefill
    assert len(keys) < 16
    st = engine.throughput_stats()
    assert st["prefix_hits"] == 16
    assert st["prefix_tokens_saved"] > 0
    if kv_mode == "paged":
        # shared prefixes are CoW block refs, not copies
        assert st["kv_shared_frac"] > 0


# --------------------------------------------------- CI gate plumbing ----

def _load_baseline():
    path = os.path.join(RESULTS, "retrieval_bench_tiny.json")
    with open(path) as f:
        return json.load(f)


def test_retrieval_regression_gate_is_not_vacuous():
    base = _load_baseline()
    assert compare("retrieval", base, base)[0] == []
    worse = copy.deepcopy(base)
    worse["meta"]["token_savings_512"] -= 0.2      # tol is 0.05
    assert compare("retrieval", worse, base)[0] == ["token_savings_512"]
    broken = copy.deepcopy(base)
    broken["meta"]["outcomes_identical"] = False   # equality-gated
    assert compare("retrieval", broken, base)[0] == ["outcomes_identical"]
    better = copy.deepcopy(base)
    better["meta"]["recall_at_k"] = 1.0
    assert compare("retrieval", better, base)[0] == []


def test_family_effects_analyzer_pass_not_vacuous():
    """The CATALOG_FAMILY_EFFECTS pass of the effects race detector:
    clean on the real source, and a family whose declaration is dropped
    fails the sweep (so growing the catalog can't open a coverage gap)."""
    source = TOOLS_IMPL.read_text()
    names = [f.name for f in FAMILIES]
    clean = analyze_effects(Path("tools_impl.py"), source,
                            registry_names=names,
                            table_name="CATALOG_FAMILY_EFFECTS",
                            name_param="family")
    assert [f for f in clean if f.rule.startswith("RL0")] == []
    # drop the terrain declaration: the dispatch branch still exists,
    # so the analyzer must flag the undeclared family
    broken = source.replace(
        '    "terrain":   _eff(reads="handles", writes="landcover rng"),',
        "")
    assert broken != source, "perturbation did not match the source"
    findings = analyze_effects(Path("tools_impl.py"), broken,
                               registry_names=names,
                               table_name="CATALOG_FAMILY_EFFECTS",
                               name_param="family")
    assert any("terrain" in f.message for f in findings), findings
