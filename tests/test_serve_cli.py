"""Argparse smoke over launch/serve.py: flag combinations parse to the
expected namespaces, invalid combinations error out BEFORE any model is
built (SystemExit from argparse), and one tiny single-engine run plus
one tiny spec-decode cluster run exercise the two serving paths end to
end.
"""
import pytest

from repro.launch.serve import build_parser, main, validate_args


def parse(argv):
    ap = build_parser()
    return validate_args(ap, ap.parse_args(argv))


# ------------------------------------------------------------ parsing ----

def test_defaults():
    args = parse([])
    assert args.arch == "planner-proxy-100m"
    assert args.replicas == 1 and args.router == "intent_affinity"
    assert args.kv_mode == "dense"
    assert args.kv_blocks is None and args.block_size is None
    assert not args.spec_decode and args.draft_k == 4


@pytest.mark.parametrize("argv", [
    ["--replicas", "4", "--router", "least_loaded", "--profile",
     "bursty", "--skew", "0.7", "--turns", "2"],
    ["--kv-mode", "paged", "--kv-blocks", "64", "--block-size", "16"],
    ["--kv-mode", "paged"],                  # paged defaults are fine
    ["--backend", "pallas", "--spec-decode", "--draft-k", "2"],
    ["--spec-decode"],                       # default k
    ["--replicas", "2", "--spec-decode", "--kv-mode", "paged"],
    ["--skew", "1.0"],                       # boundary is valid
])
def test_valid_combinations_parse(argv):
    parse(argv)


@pytest.mark.parametrize("argv", [
    ["--kv-blocks", "64"],                   # paged-only kwarg on dense
    ["--block-size", "16"],
    ["--kv-mode", "dense", "--kv-blocks", "8"],
    ["--spec-decode", "--draft-k", "0"],     # spec decode with k < 1
    ["--spec-decode", "--draft-k", "-3"],
    ["--skew", "1.5"],                       # out of range
    ["--skew", "-0.1"],
    ["--replicas", "0"],
    ["--router", "bogus"],                   # argparse choices
    ["--kv-mode", "slab"],
    ["--backend", "cuda"],
    ["--profile", "steady"],
])
def test_invalid_combinations_error(argv):
    with pytest.raises(SystemExit):
        parse(argv)


# ------------------------------------------------------- tiny real runs ----

def test_single_engine_run(capsys):
    main(["--smoke", "--requests", "2", "--max-new", "2",
          "--max-batch", "2", "--cache-len", "128"])
    out = capsys.readouterr().out
    assert "served 2 requests" in out
    assert "kv[dense]" in out


def test_cluster_spec_decode_run(capsys):
    main(["--smoke", "--replicas", "2", "--requests", "4",
          "--max-new", "4", "--max-batch", "2", "--cache-len", "128",
          "--temperature", "0.0", "--spec-decode", "--draft-k", "2",
          "--router", "intent_affinity", "--skew", "0.7"])
    out = capsys.readouterr().out
    assert "spec-decode[k=2]" in out
    assert "accept rate" in out