"""Serving engine: continuous batching correctness + training substrate."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_cache, init_params, prefill
from repro.serving.engine import InferenceEngine
from repro.serving.sampling import SamplerConfig
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.data import PackedLMDataset, synthetic_docs
from repro.training.loop import train
from repro.training.optimizer import adamw_init, adamw_update


@pytest.fixture(scope="module")
def planner():
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_matches_single_request_decode(planner):
    """Continuous batching must produce the same greedy tokens as a lone
    prefill+decode for the same prompt."""
    cfg, params = planner
    prompt = "plot sentinel2 images around Tampa Bay"
    # lone reference: B=1 greedy decode
    from repro.serving.tokenizer import TOKENIZER
    ids = TOKENIZER.encode_with_specials(prompt)
    logits, cache = prefill(params, cfg, {
        "tokens": jnp.asarray(ids, jnp.int32)[None]}, cache_len=128)
    ref = [int(jnp.argmax(logits[0]))]
    cache["pos"] = jnp.asarray([len(ids)], jnp.int32)
    tok = jnp.asarray([[ref[-1]]], jnp.int32)
    for _ in range(5):
        logits, cache = decode_step(params, cfg, cache, {"tokens": tok})
        ref.append(int(jnp.argmax(logits[0])))
        tok = jnp.asarray([[ref[-1]]], jnp.int32)

    # engine with interleaved other requests
    eng = InferenceEngine(cfg, params, max_batch=3, cache_len=128)
    rid = eng.add_request(prompt, max_new_tokens=6)
    eng.add_request("unrelated filler request about ships", max_new_tokens=6)
    eng.add_request("another request to fill the batch", max_new_tokens=6)
    done = {r.request_id: r for r in eng.run_until_done()}
    assert done[rid].output == ref


def test_engine_queue_exceeds_slots(planner):
    cfg, params = planner
    eng = InferenceEngine(cfg, params, max_batch=2, cache_len=96)
    n = 7
    for i in range(n):
        eng.add_request(f"request number {i}", max_new_tokens=4,
                        sampler=SamplerConfig(temperature=0.5))
    done = eng.run_until_done()
    assert len(done) == n
    assert all(len(r.output) >= 1 for r in done)


def test_training_reduces_loss():
    cfg = get_smoke_config("planner-proxy-100m")
    data = PackedLMDataset(synthetic_docs(cfg.vocab_size, seed=0), 4, 64,
                           cfg.vocab_size)
    params, opt, hist = train(cfg, iter(data), n_steps=30, lr=1e-3,
                              log_every=29)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1, hist


def test_adamw_updates_all_leaves():
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
    state = adamw_init(params)
    new, state2, gnorm = adamw_update(params, grads, state, lr=1e-2)
    changed = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new)
    assert all(jax.tree.leaves(changed))
    assert float(gnorm) > 0
    assert int(state2.step) == 1


def test_checkpoint_roundtrip(planner):
    cfg, params = planner
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, params)
        loaded = load_checkpoint(path, jax.tree.map(lambda x: x, params))
        same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), params,
                            loaded)
        assert all(jax.tree.leaves(same))


def test_cache_insertion_isolated(planner):
    """Inserting a request into one slot must not perturb other slots."""
    cfg, params = planner
    eng = InferenceEngine(cfg, params, max_batch=2, cache_len=96)
    eng.add_request("first prompt about maps", max_new_tokens=8)
    eng.step()
    kv_leaves = [l for l in jax.tree.leaves(eng.cache) if l.ndim >= 4]
    k_before = kv_leaves[0][:, 0].copy()
    eng.add_request("second prompt about ships", max_new_tokens=8)
    eng._admit()
    kv_leaves = [l for l in jax.tree.leaves(eng.cache) if l.ndim >= 4]
    assert jnp.allclose(k_before, kv_leaves[0][:, 0])
