"""Seeded-bad fixture for the pallas kernel contract checker
(RL201-RL205), written in the repo kernels' idiom (local grid_spec +
functools.partial kernel binding) so the checker's Name resolution is
exercised.

Each `# expect: RL###` marker pins the exact line the analyzer must
report. Never imported at runtime — parsed only.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, x_ref, o_ref):                  # expect: RL203
    p = jnp.exp(x_ref[...])                        # expect: RL205
    o_ref[...] = p.astype(o_ref.dtype)


def bad_call(x, s):
    kernel = functools.partial(_kernel)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(4, 2),
        in_specs=[
            pl.BlockSpec((None, 8), lambda i, j: (i, 0)),   # expect: RL202
        ],
        out_specs=pl.BlockSpec((None, 8), lambda i, j, s0: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((8, 8), jnp.bfloat16),               # expect: RL201
        ],
    )
    out = pl.pallas_call(                          # expect: RL203, RL204
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((4, 8), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=True,
    )(x)
    return out
