"""Known-clean fixture for the effects race detector: helpers,
write-subsumes-read, rng-as-write, readonly attrs. The analyzer must
report nothing here. Never imported at runtime — parsed only.
"""
WORKSPACE_RESOURCE_ATTRS = {
    "handles": "handles",
    "artifacts": "artifacts",
    "answer": "last_answer",
    "rng": "rng",
}
READONLY_WORKSPACE_ATTRS = frozenset({"world", "temperature"})


def _eff(reads="", writes=""):
    return (frozenset(reads.split()), frozenset(writes.split()))


def _pick(ws, ids):
    return [i for i in ids if i in ws.world.images]


def execute_tool(ws, name, args):
    if name == "loader":
        ids = _pick(ws, args.get("ids", []))
        ws.handles.extend(i for i in ids if i not in ws.handles)
        return "ok"
    if name == "sampler":
        n = int(ws.rng.integers(1, 4))
        ws.last_answer = str(n)
        return "ok"
    if name == "export":
        if not ws.handles:
            return "empty"
        ws.artifacts.append({"inputs": list(ws.handles)})
        return "ok"
    return "?"


TOOL_EFFECTS = {
    "loader": _eff(writes="handles"),
    "sampler": _eff(writes="answer rng"),
    "export": _eff(reads="handles", writes="artifacts"),
}
