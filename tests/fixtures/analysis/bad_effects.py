"""Seeded-bad fixture for the effects race detector (RL001-RL005).

Each `# expect: RL###` marker pins the exact line the analyzer must
report. Never imported at runtime — parsed only.
"""
WORKSPACE_RESOURCE_ATTRS = {
    "handles": "handles",
    "artifacts": "artifacts",
    "answer": "last_answer",
    "rng": "rng",
}
READONLY_WORKSPACE_ATTRS = frozenset({"world"})


def _eff(reads="", writes=""):
    return (frozenset(reads.split()), frozenset(writes.split()))


def execute_tool(ws, name, args):
    if name == "racy_write":
        ws.artifacts.append({"op": name})          # expect: RL001
        return "ok"
    if name == "sneaky_read":
        return list(ws.handles)                    # expect: RL002
    if name == "rogue_attr":
        ws.scratchpad = 1                          # expect: RL005
        return "ok"
    if name == "over_declared":
        ws.artifacts.append({"op": name})
        return "ok"
    if name == "no_entry":                         # expect: RL004
        return "ok"
    return "?"


TOOL_EFFECTS = {
    "racy_write": _eff(),
    "sneaky_read": _eff(),
    "rogue_attr": _eff(),
    "over_declared": _eff(writes="answer artifacts"),   # expect: RL003
    "lazy_declare": _eff(writes="answer"),              # expect: RL004
}
