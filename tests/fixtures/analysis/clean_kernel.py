"""Known-clean fixture for the pallas kernel contract checker: correct
prefetch-aware index_maps, fp32 scratch, fp32-promoted softmax,
operand order, dimension_semantics. The analyzer must report nothing
here. Never imported at runtime — parsed only.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(kvlen_ref, x_ref, o_ref, acc_scr, *, nk):
    x = x_ref[...].astype(jnp.float32)
    acc_scr[...] = jnp.exp(x)
    o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def clean_call(x, kv_len):
    kernel = functools.partial(_kernel, nk=4)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(4, 4),
        in_specs=[
            pl.BlockSpec((None, 128), lambda b, k, kvl: (b, 0)),
        ],
        out_specs=pl.BlockSpec((None, 128), lambda b, k, kvl: (b, 0)),
        scratch_shapes=[
            pltpu.VMEM((8, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((4, 128), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=True,
    )(kv_len, x)
