"""False-positive guard for the RL106 boundary scope: the injected-
clock convention, plus constructs the FULL determinism battery would
flag (env reads, raw set iteration, float dict keys) that are legal
in boundary-scope packages. Linted via a tmp ``src/repro/training/``
tree copy, like ``bad_clock_boundary.py``."""
import os


def train_like(n, clock=None):
    # the legal pattern: wall time only through an injected callable
    clock = clock or (lambda: 0.0)
    t0 = clock()
    seen = [x for x in {n, n + 1}]         # RL104 in full scope only
    flag = os.getenv("REPRO_DEBUG", "")    # RL103 in full scope only
    table = {0.5: "half"}                  # RL105 in full scope only
    return clock() - t0, seen, flag, table
