"""Suppression-semantics fixture: one inline disable, one file-wide
disable, and one violation left active. Never imported at runtime —
parsed only.
"""
# repro-lint: disable-file=RL104
import random  # repro-lint: disable=RL102


def pick(items):
    return list({i for i in items})


def draw():
    return random.choice([1, 2])
