"""Known-clean fixture for the determinism lint: seeded generators,
sorted-set iteration, os.path (not os.environ), non-float dict keys.
The analyzer must report nothing here. Never imported at runtime —
parsed only.
"""
import os.path

import numpy as np


def ordered(items):
    return [x for x in sorted({i for i in items})]


def seeded(seed):
    rng = np.random.default_rng(seed)
    return float(rng.random())


def join(base, name):
    return os.path.join(base, name)


def tick_latency(enqueue_tick, finish_tick):
    return finish_tick - enqueue_tick


TABLE = {1: "one", "two": 2}
