"""Seeded-bad fixture for the determinism lint (RL101-RL105).

Each `# expect: RL###` marker pins the exact line the analyzer must
report. Never imported at runtime — parsed only.
"""
import os
import random                                      # expect: RL102
import time
from datetime import datetime


def stamp():
    return time.time()                             # expect: RL101


def when():
    return datetime.now()                          # expect: RL101


def env_mode():
    return os.environ["MODE"]                      # expect: RL103


def env_get():
    return os.getenv("MODE", "fast")               # expect: RL103


def draw():
    return random.random()                         # expect: RL102


def ordered(items):
    out = []
    for x in {i for i in items}:                   # expect: RL104
        out.append(x)
    return out


def listed():
    return list({3, 1, 2})                         # expect: RL104


BAD_TABLE = {0.5: "half", 1.5: "sesqui"}           # expect: RL105
