"""Seeded RL106 corpus: wall-clock reads outside the injected-clock
boundary.

Only meaningful when linted under a *boundary-scope* path — a
``src/repro`` package outside ``core/serving/env/kernels`` and the
``obs//launch/`` allowlist — so the tests copy this file into a
throwaway ``src/repro/models/`` tree before running the analyzer
(under the fixtures path itself, full scope applies and these same
reads would be RL101)."""
import time
from datetime import datetime


def stamp_history(history):
    t0 = time.time()                                    # expect: RL106
    history.append({"at": datetime.now().isoformat()})  # expect: RL106
    return time.perf_counter() - t0                     # expect: RL106
