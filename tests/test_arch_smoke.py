"""Per-architecture smoke tests (deliverable f): reduced config, one
forward/train step on CPU, asserting output shapes + no NaNs; plus
prefill→decode cache consistency against the full-sequence oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ShapeConfig
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import layers as L
from repro.models.inputs import make_batch
from repro.models.model import (_apply_stack, _embed_inputs, _logits,
                                decode_step, init_params, prefill,
                                train_loss)

B, S = 2, 24


def _extras(cfg, rng, S1):
    ex = {}
    if cfg.family == "vlm":
        ex["patch_embeds"] = jnp.asarray(rng.standard_normal(
            (B, cfg.n_vision_tokens, cfg.d_model), dtype=np.float32),
            jnp.bfloat16)
        ex["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(S1, dtype=jnp.int32), (3, B, S1))
    if cfg.family == "audio":
        ex["frames"] = jnp.asarray(rng.standard_normal(
            (B, 32, cfg.d_model), dtype=np.float32), jnp.bfloat16)
    return ex


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S),
                                      dtype=np.int32))
    batch = {"tokens": tokens, "labels": tokens, **_extras(cfg, rng, S)}
    if cfg.family == "audio":
        batch["labels"] = batch["tokens"]
    loss = jax.jit(lambda p, b: train_loss(p, cfg, b, remat=False))(
        params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), arch
    # gradients flow and are finite
    g = jax.grad(lambda p: train_loss(p, cfg, batch, remat=True))(params)
    gn = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(g))
    assert jnp.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:  # capacity drops differ by construction
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=100.0))
    rng = np.random.default_rng(3)
    params = init_params(jax.random.PRNGKey(1), cfg)
    tokens = jnp.asarray(rng.integers(5, cfg.vocab_size, (B, S + 1),
                                      dtype=np.int32))
    ex = _extras(cfg, rng, S + 1)

    def full_logits(t):
        batch = {"tokens": tokens, **ex}
        memory = None
        if cfg.n_enc_layers:
            from repro.models.model import _encode
            memory = _encode(params, cfg, batch["frames"])
        x, positions = _embed_inputs(params, cfg, batch)
        x, _, _ = _apply_stack(params, cfg, x, mode="train",
                               positions=positions, memory=memory,
                               remat=False)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return _logits(params, cfg, x)[:, t]

    pb = {"tokens": tokens[:, :S]}
    if cfg.family == "vlm":
        pb.update(patch_embeds=ex["patch_embeds"],
                  mrope_pos=ex["mrope_pos"][:, :, :S])
    if cfg.family == "audio":
        pb.update(frames=ex["frames"])
    logits_p, cache = prefill(params, cfg, pb, cache_len=S + 8)
    db = {"tokens": tokens[:, S:S + 1]}
    if cfg.family == "vlm":
        db["mrope_pos"] = ex["mrope_pos"][:, :, S:S + 1]
    logits_d, _ = decode_step(params, cfg, cache, db)

    scale = float(jnp.max(jnp.abs(full_logits(S)))) + 1e-6
    assert float(jnp.max(jnp.abs(logits_p - full_logits(S - 1)))) \
        < 0.05 * scale + 0.05
    assert float(jnp.max(jnp.abs(logits_d - full_logits(S)))) \
        < 0.05 * scale + 0.05


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dims_match_assignment(arch):
    cfg = get_config(arch)
    assigned = {
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == assigned, (arch, got, assigned)


def test_moe_config_details():
    arctic = get_config("arctic-480b")
    assert arctic.moe.n_experts == 128 and arctic.moe.top_k == 2
    assert arctic.moe.dense_residual_ff == 4864
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.moe.n_experts == 384 and kimi.moe.top_k == 8
