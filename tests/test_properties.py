"""Hypothesis property tests on system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dependency; see "
                                         "requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.env.evaluator import rouge_l
from repro.kernels.ref import attention_ref
from repro.models.layers import attention
from repro.serving.tokenizer import TOKENIZER, count_tokens

# --------------------------------------------------------------- tokenizer --

texts = st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126),
                min_size=0, max_size=200)


@given(texts)
@settings(max_examples=100, deadline=None)
def test_tokenizer_deterministic_and_bounded(t):
    a, b = TOKENIZER.encode(t), TOKENIZER.encode(t)
    assert a == b
    assert all(0 <= i < TOKENIZER.vocab_size for i in a)
    # token count grows at most ~linearly with characters
    assert len(a) <= max(4, len(t))


@given(texts, texts)
@settings(max_examples=50, deadline=None)
def test_tokenizer_concat_superadditive(a, b):
    """Concatenation cannot produce fewer tokens than the longer part."""
    whole = count_tokens(a + " " + b)
    assert whole >= max(count_tokens(a), count_tokens(b))


# ------------------------------------------------------------------ rouge --

@given(st.lists(st.sampled_from("abcdefg"), min_size=1, max_size=30))
@settings(max_examples=60, deadline=None)
def test_rouge_identity(words):
    s = " ".join(words)
    assert rouge_l(s, s) == pytest.approx(1.0)


@given(st.lists(st.sampled_from("ab"), min_size=1, max_size=15),
       st.lists(st.sampled_from("cd"), min_size=1, max_size=15))
@settings(max_examples=40, deadline=None)
def test_rouge_disjoint_zero(a, b):
    assert rouge_l(" ".join(a), " ".join(b)) == 0.0


@given(st.lists(st.sampled_from("abcde"), min_size=1, max_size=20),
       st.lists(st.sampled_from("abcde"), min_size=1, max_size=20))
@settings(max_examples=60, deadline=None)
def test_rouge_symmetric_bounded(a, b):
    r1 = rouge_l(" ".join(a), " ".join(b))
    r2 = rouge_l(" ".join(b), " ".join(a))
    assert 0.0 <= r1 <= 1.0
    assert r1 == pytest.approx(r2)


# -------------------------------------------------- chunked attention ------

@given(st.integers(1, 2), st.sampled_from([1, 2, 4]),
       st.sampled_from([64, 96, 128]), st.sampled_from([16, 32]),
       st.booleans(), st.sampled_from([0, 32]))
@settings(max_examples=25, deadline=None)
def test_chunked_attention_matches_ref(B, G, S, hd, causal, window):
    """The scan-chunked attention (model fast path) must equal the naive
    masked-softmax oracle for any shape/mask combination."""
    rng = np.random.default_rng(B * 1000 + G * 100 + S + hd)
    Hkv = 2
    Hq = Hkv * G
    q = jnp.asarray(rng.standard_normal((B, Hq, S, hd), dtype=np.float32))
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, hd), dtype=np.float32))
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, hd), dtype=np.float32))
    out = attention(q, k, v, causal=causal, window=window, chunk=32)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-3


# ----------------------------------------------------------- MoE invariants --

@given(st.integers(2, 4), st.sampled_from([8, 16]), st.sampled_from([1, 2]))
@settings(max_examples=20, deadline=None)
def test_moe_router_weights_normalized(B, E, k):
    from repro.kernels.ref import router_topk_ref
    rng = np.random.default_rng(B * 31 + E + k)
    logits = jnp.asarray(rng.standard_normal((B * 8, E), dtype=np.float32))
    w, idx, probs = router_topk_ref(logits, k)
    assert jnp.allclose(jnp.sum(w, -1), 1.0, atol=1e-5)
    # indices are distinct per token
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == k


# ------------------------------------------------------------ data packing --

@given(st.integers(1, 4), st.sampled_from([32, 64]))
@settings(max_examples=20, deadline=None)
def test_packing_shapes_and_alignment(batch, seq):
    from repro.training.data import PackedLMDataset, synthetic_docs
    ds = PackedLMDataset(synthetic_docs(512, seed=1), batch, seq, 512)
    b = next(iter(ds))
    assert b["tokens"].shape == (batch, seq)
    assert b["labels"].shape == (batch, seq)
    # labels are next-token-shifted tokens
    chunk_flat = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    assert np.array_equal(b["labels"][:, :-1], chunk_flat[:, 1:-1])


# ------------------------------------------------------------- accounting --

@given(st.lists(st.tuples(texts, texts), min_size=1, max_size=10))
@settings(max_examples=30, deadline=None)
def test_ledger_totals(entries):
    from repro.core.accounting import TokenLedger
    led = TokenLedger()
    for p, c in entries:
        led.record("plan", p, c)
    assert led.total_tokens == led.prompt_tokens + led.completion_tokens
    assert led.n_requests == len(entries)
    assert led.total_tokens == sum(count_tokens(p) + count_tokens(c)
                                   for p, c in entries)
