"""Benchmark evaluator: the paper's Table-2 metric suite.

  Correct. Rate — the task's primary outcome is right (answer/artifact);
  Success Rate — full task success: plan completed AND all required side
                 effects (map layers, pages, artifacts) present;
  Obj. Det F1  — micro-F1 of detections vs world ground truth;
  LCC R        — Pearson correlation of predicted vs true land-cover
                 fractions (pooled over tasks);
  VQA Rouge-L  — Rouge-L F between the agent's answer and ground truth;
  Tokens/Task  — mean total tokens from the ledger (prompt+completion).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.agent import Agent, TaskResult
from repro.env.tasks import Task


def rouge_l(pred: str, ref: str) -> float:
    a, b = pred.split(), ref.split()
    if not a or not b:
        return 0.0
    dp = np.zeros((len(a) + 1, len(b) + 1), np.int32)
    for i, wa in enumerate(a):
        for j, wb in enumerate(b):
            dp[i + 1, j + 1] = (dp[i, j] + 1 if wa == wb
                                else max(dp[i, j + 1], dp[i + 1, j]))
    lcs = dp[-1, -1]
    p, r = lcs / len(a), lcs / len(b)
    return 0.0 if p + r == 0 else 2 * p * r / (p + r)


def _task_correct(res: TaskResult) -> bool:
    t, ws = res.task, res.workspace
    c = t.checker
    if t.intent == "load_filter_plot":
        plotted = any(l["type"] == "images" for l in ws.map_layers)
        return plotted and bool(ws.handles)
    if t.intent == "detection_analysis":
        return bool(ws.detections)
    if t.intent == "landcover_analysis":
        return ws.last_answer == c.get("gt_dominant")
    if t.intent in ("information_seeking", "visual_qa",
                    "speech_transcription"):
        return bool(ws.last_answer)
    if t.intent == "ui_web_navigation":
        return ws.ui_state.get("page") == c.get("expect_page")
    if t.intent == "code_analysis":
        return any(a.get("op") == "tabulate" for a in ws.artifacts)
    return False


def _task_success(res: TaskResult) -> bool:
    t, ws = res.task, res.workspace
    if not res.completed_plan:
        return False
    if not _task_correct(res):
        return False
    if t.checker.get("expect_map") and not ws.map_layers:
        return False
    needed = {c.tool for stage in t.plan for c in stage}
    return needed.issubset(set(res.executed_tools))


@dataclass
class EvalReport:
    name: str
    correct_rate: float
    success_rate: float
    det_f1: float
    lcc_r: float
    vqa_rouge_l: float
    tokens_per_task: float
    steps_per_task: float
    tools_per_step: float
    fallback_rate: float
    gate_tokens: float
    n_tasks: int

    def row(self) -> Dict[str, float]:
        return {
            "Correct.Rate": round(100 * self.correct_rate, 2),
            "SuccessRate": round(100 * self.success_rate, 2),
            "ObjDetF1": round(100 * self.det_f1, 2),
            "LCC_R": round(100 * self.lcc_r, 2),
            "VQA_RougeL": round(100 * self.vqa_rouge_l, 2),
            "Tokens/Task": round(self.tokens_per_task / 1000, 2),
            "Steps/Task": round(self.steps_per_task, 2),
            "Tools/Step": round(self.tools_per_step, 2),
            "Fallback%": round(100 * self.fallback_rate, 2),
        }


def evaluate(agent: Agent, tasks: Sequence[Task], name: str = "run"
             ) -> EvalReport:
    """Sequential harness: run tasks one at a time, then score. The
    concurrent path (serving.pipeline.evaluate_pipeline) produces the
    same TaskResults via interleaved sessions and shares
    ``evaluate_results``."""
    return evaluate_results(
        [agent.run_task(t, task_seed=i) for i, t in enumerate(tasks)],
        name)


def evaluate_results(results: Sequence[TaskResult], name: str = "run"
                     ) -> EvalReport:
    correct = [float(_task_correct(r)) for r in results]
    success = [float(_task_success(r)) for r in results]

    # detection quality over images the detector actually ran on (the
    # benchmark's F1 scores the detector, not plan completion — plan
    # failures already show up in success rate)
    tp = fp = fn = 0
    for r in results:
        if r.task.metric_family != "detection":
            continue
        cls = r.task.checker["class"]
        for h in r.task.checker["handles"]:
            det = r.workspace.detections.get(h, {}).get(cls)
            if det is None:
                continue
            gt = r.workspace.world.images[h].objects.get(cls, 0)
            tp += det["tp"]
            fp += det["fp"]
            fn += gt - det["tp"]
    det_f1 = 2 * tp / max(2 * tp + fp + fn, 1)

    pred_fracs, gt_fracs = [], []
    for r in results:
        if r.task.metric_family != "landcover":
            continue
        gt = r.task.checker["gt_fractions"]
        if not r.workspace.landcover:
            continue
        agg = {c: float(np.mean([lc[c] for lc in
                                 r.workspace.landcover.values()]))
               for c in gt}
        for c in gt:
            pred_fracs.append(agg[c])
            gt_fracs.append(gt[c])
    if len(pred_fracs) >= 2:
        lcc_r = float(np.corrcoef(pred_fracs, gt_fracs)[0, 1])
    else:
        lcc_r = 0.0

    rouges = []
    for r in results:
        if r.task.metric_family != "vqa":
            continue
        ans = r.workspace.last_answer or ""
        rouges.append(rouge_l(ans, r.task.checker["gt_text"]))
    vqa = float(np.mean(rouges)) if rouges else 0.0

    tokens = [r.ledger.total_tokens for r in results]
    steps = [r.ledger.n_plan_steps for r in results]
    tools = [len(r.executed_tools) / max(r.ledger.n_plan_steps, 1)
             for r in results]
    gate_toks = [sum(e.prompt_tokens + e.completion_tokens
                     for e in r.ledger.entries if e.kind == "gate")
                 for r in results]

    return EvalReport(
        name=name,
        correct_rate=float(np.mean(correct)),
        success_rate=float(np.mean(success)),
        det_f1=det_f1,
        lcc_r=lcc_r,
        vqa_rouge_l=vqa,
        tokens_per_task=float(np.mean(tokens)),
        steps_per_task=float(np.mean(steps)),
        tools_per_step=float(np.mean(tools)),
        fallback_rate=float(np.mean([r.fallback_used for r in results])),
        gate_tokens=float(np.mean(gate_toks)),
        n_tasks=len(results),
    )
