"""Synthetic GeoLLM-Engine world: imagery catalog, knowledge base, web,
audio clips — all seeded/deterministic, all queryable through the tool
implementations in env/tools_impl.py.

The world carries *ground truth* (object counts, land-cover fractions,
article contents) so the evaluator can score detection F1, LCC R and
Rouge-L against reality rather than against the agent's own outputs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

SENSORS = ("xview1", "sentinel2", "landsat8", "naip", "worldview3")
CITIES = ("Tampa Bay, FL", "Seattle, WA", "Rotterdam", "Singapore",
          "Cape Town", "Mumbai", "Osaka", "Hamburg", "Valparaiso",
          "Anchorage, AK", "Doha", "Gdansk")
OBJECT_CLASSES = ("airplane", "ship", "storage tank", "vehicle", "helipad",
                  "bridge", "crane")
LANDCOVER_CLASSES = ("water", "trees", "crops", "built", "bare", "grass")


@dataclass
class ImageRecord:
    image_id: str
    sensor: str
    region: str
    date: str            # ISO yyyy-mm-dd
    cloud: float
    objects: Dict[str, int]
    landcover: Dict[str, float]
    caption: str


@dataclass
class World:
    images: Dict[str, ImageRecord]
    regions: Dict[str, Tuple[float, float, float, float]]
    wiki: Dict[str, str]
    web: Dict[str, Dict[str, str]]        # url -> {title, text}
    audio: Dict[str, str]                 # clip id -> transcript
    seed: int

    def catalog_rows(self) -> List[ImageRecord]:
        return list(self.images.values())

    def fingerprint(self) -> str:
        """Stable digest of all shared world state.

        The tool-graph compiler's cross-session fusion is only sound
        because the World is READ-ONLY at execution time — every mutable
        resource lives in the per-session Workspace (the hazard alphabet
        in env/tools_impl.TOOL_EFFECTS). Parity tests snapshot this
        before/after fused runs to hold the executors to that contract.
        """
        import hashlib
        h = hashlib.sha256()
        for iid in sorted(self.images):
            r = self.images[iid]
            h.update(repr((r.image_id, r.sensor, r.region, r.date,
                           r.cloud, sorted(r.objects.items()),
                           sorted(r.landcover.items()),
                           r.caption)).encode())
        for part in (sorted(self.regions.items()), sorted(self.wiki.items()),
                     sorted((u, sorted(p.items()))
                            for u, p in self.web.items()),
                     sorted(self.audio.items()), self.seed):
            h.update(repr(part).encode())
        return h.hexdigest()


def _date(rng) -> str:
    y = int(rng.integers(2019, 2024))
    m = int(rng.integers(1, 13))
    d = int(rng.integers(1, 28))
    return f"{y:04d}-{m:02d}-{d:02d}"


def build_world(seed: int = 0, n_images: int = 600) -> World:
    rng = np.random.default_rng(seed)
    regions = {c: tuple(np.round(rng.uniform(-60, 60, 4), 3)) for c in CITIES}
    images: Dict[str, ImageRecord] = {}
    for i in range(n_images):
        sensor = SENSORS[int(rng.integers(0, len(SENSORS)))]
        region = CITIES[int(rng.integers(0, len(CITIES)))]
        objects = {c: int(rng.poisson(3.0)) for c in OBJECT_CLASSES
                   if rng.random() < 0.5}
        lc_raw = rng.dirichlet(np.ones(len(LANDCOVER_CLASSES)))
        landcover = {c: float(np.round(f, 4))
                     for c, f in zip(LANDCOVER_CLASSES, lc_raw)}
        main_obj = max(objects, key=objects.get) if objects else "terrain"
        caption = (f"{sensor} scene over {region} showing {main_obj} "
                   f"near the waterfront")
        images[f"img_{i:05d}"] = ImageRecord(
            image_id=f"img_{i:05d}", sensor=sensor, region=region,
            date=_date(rng), cloud=float(np.round(rng.uniform(0, 0.9), 3)),
            objects=objects, landcover=landcover, caption=caption)

    wiki = {}
    topics = ["object detection models", "NDVI", "synthetic aperture radar",
              "land cover classification", "cloud masking",
              "image georeferencing", "xview dataset", "sentinel-2 bands",
              "prompting techniques", "system-efficient LLM serving",
              "airplane detection", "ship detection", "change detection",
              "tool-augmented agents", "remote sensing benchmarks"]
    for t in topics:
        body = (f"{t.capitalize()}: reference article. "
                + " ".join(f"fact_{t.replace(' ', '_')}_{j}"
                           for j in range(40)))
        wiki[t] = body

    web = {}
    for j in range(40):
        url = f"https://example.org/page{j}"
        web[url] = {"title": f"Result {j}",
                    "text": f"web page {j} content " + " ".join(
                        f"w{j}_{k}" for k in range(60))}

    audio = {f"clip_{j:03d}":
             f"meeting recording {j} about satellite tasking and "
             f"acquisition windows item {j}" for j in range(20)}

    return World(images=images, regions=regions, wiki=wiki, web=web,
                 audio=audio, seed=seed)
