"""Task generator: GeoLLM-Engine-style benchmark tasks with ground-truth
plans (the "-5k"/"-10k" benchmark of the paper is a seeded draw of these).

Each task carries:
  * the natural-language query,
  * its intent (hidden from the agent at runtime — the gate must infer it),
  * the ground-truth plan: a list of *stages*; calls inside one stage are
    what an ideal multi-tool planner can aggregate into one LLM step,
  * checker metadata for the evaluator.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.env.world import (CITIES, OBJECT_CLASSES, LANDCOVER_CLASSES,
                             SENSORS, World)


@dataclass(frozen=True)
class ToolCall:
    tool: str
    args: Dict[str, Any] = field(default_factory=dict)


@dataclass
class Task:
    task_id: str
    query: str
    intent: str
    plan: List[List[ToolCall]]          # stages of aggregatable calls
    checker: Dict[str, Any]             # evaluator metadata
    metric_family: str                  # none|detection|landcover|vqa


def _img_filter_args(rng, world: World):
    sensor = SENSORS[int(rng.integers(0, len(SENSORS)))]
    city = CITIES[int(rng.integers(0, len(CITIES)))]
    rows = [r for r in world.catalog_rows()
            if r.sensor == sensor and r.region == city]
    max_cloud = 0.4
    return sensor, city, max_cloud, [r.image_id for r in rows][:24]


def gen_task(world: World, rng: np.random.Generator, idx: int) -> Task:
    kind = idx % 8
    tid = f"task_{idx:05d}"

    if kind == 0:   # load→filter→plot
        sensor, city, mc, ids = _img_filter_args(rng, world)
        query = (f"Plot {sensor} images around {city} with cloud cover "
                 f"below {int(mc*100)}% on the map")
        plan = [
            [ToolCall("sql_query_regions", {"place": city}),
             ToolCall("sql_query_images", {"sensor": sensor, "region": city,
                                           "max_cloud": mc})],
            [ToolCall("load_images", {"image_ids": ids or
                                      ["img_00000"]}),
             ToolCall("filter_clouds", {"max_cloud": mc})],
            [ToolCall("reproject", {"crs": "EPSG:4326"}),
             ToolCall("mosaic", {})],
            [ToolCall("plot_map", {"region": city}),
             ToolCall("add_layer", {"layer": "basemap-labels"})],
            [ToolCall("screenshot_map", {})],
        ]
        return Task(tid, query, "load_filter_plot", plan,
                    {"expect_map": True,
                     "expect_handles": [i for i in (ids or ["img_00000"])
                                        if world.images[i].cloud <= mc]},
                    "none")

    if kind == 1:   # detection / counting
        sensor, city, mc, ids = _img_filter_args(rng, world)
        cls = OBJECT_CLASSES[int(rng.integers(0, len(OBJECT_CLASSES)))]
        ids = ids or ["img_00001"]
        query = (f"How many {cls}s are visible in {sensor} imagery "
                 f"of {city}? Draw the detections on the map.")
        plan = [
            [ToolCall("sql_query_regions", {"place": city}),
             ToolCall("sql_query_images", {"sensor": sensor,
                                           "region": city})],
            [ToolCall("suggest_model", {"task": f"{cls} detection"}),
             ToolCall("load_images", {"image_ids": ids})],
            [ToolCall("detect_objects", {"classes": [cls]}),
             ToolCall("count_objects", {"classes": [cls]})],
            [ToolCall("draw_bboxes", {"detections": [cls]}),
             ToolCall("screenshot_map", {})],
        ]
        gt = sum(world.images[i].objects.get(cls, 0) for i in ids)
        return Task(tid, query, "detection_analysis", plan,
                    {"class": cls, "handles": ids, "gt_count": gt,
                     "expect_map": True}, "detection")

    if kind == 2:   # landcover
        sensor, city, mc, ids = _img_filter_args(rng, world)
        ids = ids or ["img_00002"]
        # the plan cloud-filters at 0.5; ground truth mirrors that subset
        ids_kept = [i for i in ids if world.images[i].cloud <= 0.5]
        ids_for_gt = ids_kept or ids
        query = (f"What is the dominant land cover class around {city} "
                 f"according to {sensor} data?")
        plan = [
            [ToolCall("sql_query_regions", {"place": city}),
             ToolCall("sql_query_images", {"sensor": sensor,
                                           "region": city})],
            [ToolCall("load_images", {"image_ids": ids}),
             ToolCall("filter_clouds", {"max_cloud": 0.5})],
            [ToolCall("classify_landcover", {})],
            [ToolCall("landcover_stats", {}),
             ToolCall("plot_histogram", {"source": "landcover"})],
        ]
        agg = {c: float(np.mean([world.images[i].landcover[c]
                                 for i in ids_for_gt]))
               for c in LANDCOVER_CLASSES}
        return Task(tid, query, "landcover_analysis", plan,
                    {"handles": ids_for_gt, "gt_fractions": agg,
                     "gt_dominant": max(agg, key=agg.get)}, "landcover")

    if kind == 3:   # information seeking
        topic = sorted(world.wiki)[int(rng.integers(0, len(world.wiki)))]
        query = f"Look up and summarize what we know about {topic}."
        plan = [
            [ToolCall("wiki_search", {"query": topic})],
            [ToolCall("wiki_get", {"title": topic})],
            [ToolCall("wiki_summarize", {"title": topic})],
        ]
        return Task(tid, query, "information_seeking", plan,
                    {"gt_text": world.wiki[topic]}, "vqa")

    if kind == 4:   # ui/web navigation
        query = ("Search the web for 'system-efficient LLM prompting' and "
                 "open the most relevant result")
        url = sorted(world.web)[0]
        plan = [
            [ToolCall("web_search", {"query":
                                     "system-efficient LLM prompting"})],
            [ToolCall("open_url", {"url": url}),
             ToolCall("ui_scroll", {"direction": "down"})],
            [ToolCall("ui_read", {"label": "main-content"}),
             ToolCall("ui_open_panel", {"panel": "notes"})],
        ]
        return Task(tid, query, "ui_web_navigation", plan,
                    {"expect_page": url}, "none")

    if kind == 5:   # visual QA
        ids = sorted(world.images)
        h = ids[int(rng.integers(0, len(ids)))]
        query = f"Describe what is shown in catalog image {h}."
        plan = [
            [ToolCall("sql_sample", {"filter": f"id='{h}'", "n": 1}),
             ToolCall("load_images", {"image_ids": [h]})],
            [ToolCall("visual_qa", {"handle": h,
                                    "question": "describe the scene"})],
            [ToolCall("caption_image", {"handle": h})],
        ]
        return Task(tid, query, "visual_qa", plan,
                    {"handle": h, "gt_text": world.images[h].caption},
                    "vqa")

    if kind == 6:   # speech transcription
        clip = sorted(world.audio)[int(rng.integers(0, len(world.audio)))]
        query = f"Transcribe audio clip {clip} and summarize it."
        plan = [
            [ToolCall("transcribe_audio", {"clip": clip})],
            [ToolCall("wiki_search", {"query": "satellite tasking"})],
        ]
        return Task(tid, query, "speech_transcription", plan,
                    {"gt_text": world.audio[clip]}, "vqa")

    # kind == 7: code / tabulation
    sensor, city, mc, ids = _img_filter_args(rng, world)
    query = (f"Tabulate the number of catalog images per sensor for "
             f"{city}.")
    plan = [
        [ToolCall("sql_distinct", {"column": "sensor"}),
         ToolCall("sql_count", {"filter": f"region='{city}'"})],
        [ToolCall("tabulate", {"records": []})],
    ]
    return Task(tid, query, "code_analysis", plan,
                {"expect_artifact": "tabulate"}, "none")


def make_benchmark(world: World, n_tasks: int, seed: int = 0) -> List[Task]:
    rng = np.random.default_rng(seed + 17)
    return [gen_task(world, rng, i) for i in range(n_tasks)]
