"""Executable tool implementations over the synthetic world.

``Workspace`` is the per-task mutable state (loaded handles, map layers,
artifacts, answers). ``execute_tool`` is the single dispatch point the
agent loop calls; unknown tools or bad args raise ``ToolError`` — which is
what triggers GeckOpt's full-catalog fallback when gating was too narrow.

Model-backed tools (detection, land-cover, VQA) apply a *seeded noise
model* standing in for real model inference: detections have per-class
recall/precision, land-cover adds jitter, VQA answers pass through a
temperature-controlled word dropout (the paper attributes its VQA metric
wobble to non-zero temperature).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.env.world import LANDCOVER_CLASSES, World


class ToolError(Exception):
    pass


@dataclass
class Workspace:
    world: World
    rng: np.random.Generator
    handles: List[str] = field(default_factory=list)
    map_layers: List[Dict] = field(default_factory=list)
    detections: Dict[str, Dict[str, int]] = field(default_factory=dict)
    landcover: Dict[str, Dict[str, float]] = field(default_factory=dict)
    artifacts: List[Dict] = field(default_factory=list)
    last_answer: Optional[str] = None
    ui_state: Dict[str, Any] = field(default_factory=dict)
    temperature: float = 0.3

    def obs(self, payload) -> str:
        s = str(payload)
        return s if len(s) < 900 else s[:900] + "…"


# per-class detector quality (seeded noise model)
_DET_RECALL = {"airplane": 0.96, "ship": 0.93, "storage tank": 0.91,
               "vehicle": 0.86, "helipad": 0.89, "bridge": 0.93,
               "crane": 0.87}
_DET_FP = {"airplane": 0.20, "ship": 0.28, "storage tank": 0.24,
           "vehicle": 0.64, "helipad": 0.16, "bridge": 0.12, "crane": 0.24}


def _resolve_ids(ws: Workspace, ids) -> List[str]:
    if isinstance(ids, str):
        ids = [ids]
    out = []
    for i in ids:
        if i in ws.world.images:
            out.append(i)
    return out


def execute_tool(ws: Workspace, name: str, args: Dict[str, Any]) -> str:
    w = ws.world
    if name == "sql_query_regions":
        place = args.get("place", "")
        hits = [c for c in w.regions if place.lower() in c.lower()
                or c.lower() in place.lower()]
        return ws.obs({"regions": hits, "bboxes": [w.regions[h]
                                                   for h in hits]})
    if name == "sql_query_images":
        rows = w.catalog_rows()
        sensor = args.get("sensor")
        region = args.get("region")
        if sensor:
            rows = [r for r in rows if r.sensor == sensor]
        if region:
            rows = [r for r in rows if region.lower() in r.region.lower()]
        if args.get("date_from"):
            rows = [r for r in rows if r.date >= args["date_from"]]
        if args.get("date_to"):
            rows = [r for r in rows if r.date <= args["date_to"]]
        if args.get("max_cloud") is not None:
            rows = [r for r in rows if r.cloud <= float(args["max_cloud"])]
        rows = rows[:24]
        ids = [r.image_id for r in rows]
        meta = [{"id": r.image_id, "date": r.date, "cloud": r.cloud,
                 "sensor": r.sensor} for r in rows[:12]]
        return ws.obs({"image_ids": ids, "count": len(ids), "rows": meta})
    if name == "sql_count":
        return ws.obs({"count": len(w.images)})
    if name == "sql_distinct":
        col = args.get("column", "sensor")
        vals = sorted({getattr(r, col, "") for r in w.catalog_rows()
                       if hasattr(r, col)})
        return ws.obs({"values": vals})
    if name == "sql_sample":
        n = int(args.get("n", 5))
        ids = sorted(w.images)[:n]
        return ws.obs({"image_ids": ids})

    if name == "load_images":
        ids = _resolve_ids(ws, args.get("image_ids", []))
        if not ids:
            raise ToolError("load_images: no valid image ids")
        ws.handles.extend(i for i in ids if i not in ws.handles)
        return ws.obs({"handles": ids})
    if name in ("filter_clouds", "filter_date"):
        hs = args.get("handles") or ws.handles
        if name == "filter_clouds":
            mx = float(args.get("max_cloud", 0.3))
            keep = [h for h in hs if w.images[h].cloud <= mx]
        else:
            keep = [h for h in hs
                    if (not args.get("date_from")
                        or w.images[h].date >= args["date_from"])
                    and (not args.get("date_to")
                         or w.images[h].date <= args["date_to"])]
        ws.handles = keep
        return ws.obs({"handles": keep, "kept": len(keep)})
    if name in ("mosaic", "reproject", "compute_ndvi", "band_math",
                "export_geotiff"):
        if not ws.handles:
            raise ToolError(f"{name}: workspace empty")
        ws.artifacts.append({"op": name, "inputs": list(ws.handles)})
        return ws.obs({"artifact": f"{name}_{len(ws.artifacts)}"})

    if name == "plot_map":
        hs = args.get("handles") or ws.handles
        if not hs:
            raise ToolError("plot_map: nothing to plot")
        ws.map_layers.append({"type": "images", "handles": list(hs),
                              "region": args.get("region", "")})
        return ws.obs({"map": "rendered", "layers": len(ws.map_layers)})
    if name in ("add_layer", "draw_bboxes", "heatmap", "plot_histogram",
                "plot_timeseries"):
        ws.map_layers.append({"type": name, "args": args})
        return ws.obs({"map": "updated", "layers": len(ws.map_layers)})
    if name == "screenshot_map":
        ws.artifacts.append({"op": "screenshot", "layers":
                             len(ws.map_layers)})
        return ws.obs({"artifact": "screenshot"})

    if name in ("detect_objects", "count_objects"):
        hs = args.get("handles") or ws.handles
        if not hs:
            raise ToolError(f"{name}: workspace empty")
        classes = args.get("classes") or list(_DET_RECALL)
        if isinstance(classes, str):
            classes = [classes]
        for h in hs:
            gt = w.images[h].objects
            det = {}
            for c in classes:
                n_gt = gt.get(c, 0)
                rec = _DET_RECALL.get(c, 0.85)
                tp = int(ws.rng.binomial(n_gt, rec)) if n_gt else 0
                fp = int(ws.rng.poisson(_DET_FP.get(c, 0.3)))
                det[c] = {"tp": tp, "fp": fp, "pred": tp + fp,
                          "gt": n_gt}
            ws.detections[h] = det
        total = {c: sum(ws.detections[h][c]["pred"] for h in hs
                        if c in ws.detections.get(h, {}))
                 for c in classes}
        return ws.obs({"detections": total, "images": len(hs)})
    if name == "change_detection":
        return ws.obs({"changes": int(ws.rng.poisson(4))})
    if name == "suggest_model":
        task = args.get("task", "")
        cls = next((c for c in _DET_RECALL if c in task), "airplane")
        return ws.obs({"model": f"dino-{cls.replace(' ', '-')}-v2"})

    if name == "classify_landcover":
        hs = args.get("handles") or ws.handles
        if not hs:
            raise ToolError("classify_landcover: workspace empty")
        for h in hs:
            gt = w.images[h].landcover
            noisy = {c: max(0.0, gt[c] + float(ws.rng.normal(0, 0.015)))
                     for c in LANDCOVER_CLASSES}
            z = sum(noisy.values())
            ws.landcover[h] = {c: v / z for c, v in noisy.items()}
        return ws.obs({"classified": len(hs)})
    if name == "landcover_stats":
        if not ws.landcover:
            raise ToolError("landcover_stats: classify first")
        agg = {c: float(np.mean([lc[c] for lc in ws.landcover.values()]))
               for c in LANDCOVER_CLASSES}
        ws.last_answer = max(agg, key=agg.get)
        return ws.obs({"fractions": {c: round(v, 4)
                                     for c, v in agg.items()}})
    if name == "compare_landcover":
        return ws.obs({"delta": "computed"})

    if name in ("visual_qa", "compare_images_qa", "caption_image",
                "describe_scene"):
        h = args.get("handle") or args.get("a") or (
            ws.handles[0] if ws.handles else None)
        if h is None or h not in w.images:
            raise ToolError(f"{name}: no image handle")
        # temperature-controlled generation noise (paper §2 attributes the
        # VQA metric wobble to non-zero temperature in function calling)
        base = w.images[h].caption
        words = base.split()
        kept = [wd for wd in words
                if ws.rng.random() > 0.34 + 0.3 * ws.temperature]
        filler = ["the", "image", "shows", "an", "area", "with",
                  "visible", "features"]
        n_fill = int(ws.rng.integers(2, 6))
        ans = " ".join(filler[:n_fill] + (kept or words[:3]))
        ws.last_answer = ans
        return ws.obs({"answer": ans})
    if name == "ground_phrase":
        return ws.obs({"box": [10, 20, 50, 60]})

    if name == "web_search":
        urls = sorted(w.web)[:5]
        return ws.obs({"results": [{"url": u, "title": w.web[u]["title"]}
                                   for u in urls]})
    if name == "open_url":
        url = args.get("url", "")
        if url not in w.web:
            url = sorted(w.web)[0]
        ws.ui_state["page"] = url
        ws.last_answer = w.web[url]["text"][:80]
        return ws.obs({"title": w.web[url]["title"],
                       "text": w.web[url]["text"][:120]})
    if name in ("download_file", "post_form"):
        ws.artifacts.append({"op": name})
        return ws.obs({"ok": True})

    if name in ("ui_click", "ui_type", "ui_scroll", "ui_read",
                "ui_open_panel"):
        ws.ui_state[name] = args
        return ws.obs({"ok": True, "state": name})

    if name == "wiki_search":
        q = args.get("query", "").lower()
        hits = [t for t in w.wiki if any(tok in t for tok in q.split())]
        hits = hits or sorted(w.wiki)[:3]
        return ws.obs({"titles": hits[:5]})
    if name in ("wiki_get", "wiki_summarize"):
        title = args.get("title", "")
        if title not in w.wiki:
            cand = [t for t in w.wiki if title.lower() in t]
            if not cand:
                raise ToolError(f"{name}: unknown article {title!r}")
            title = cand[0]
        body = w.wiki[title]
        # summarization keeps ~60% of the content (temperature-seeded)
        words = body.split()
        kept = [wd for wd in words if ws.rng.random() > 0.38]
        ws.last_answer = " ".join(kept) if kept else body[:80]
        return ws.obs({"article": title, "text": ws.last_answer[:300]})

    if name in ("transcribe_audio", "translate_audio"):
        clip = args.get("clip", "")
        if clip not in w.audio:
            clip = sorted(w.audio)[0]
        # ASR word-error noise
        words = w.audio[clip].split()
        kept = [wd for wd in words if ws.rng.random() > 0.12]
        ws.last_answer = " ".join(kept) if kept else w.audio[clip]
        return ws.obs({"transcript": ws.last_answer})

    if name == "run_python":
        ws.artifacts.append({"op": "run_python"})
        return ws.obs({"stdout": "ok"})
    if name == "tabulate":
        ws.artifacts.append({"op": "tabulate"})
        return ws.obs({"table": "rendered"})

    raise ToolError(f"unknown tool: {name}")
