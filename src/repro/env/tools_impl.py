"""Executable tool implementations over the synthetic world.

``Workspace`` is the per-task mutable state (loaded handles, map layers,
artifacts, answers). ``execute_tool`` is the single dispatch point the
agent loop calls; unknown tools or bad args raise ``ToolError`` — which is
what triggers GeckOpt's full-catalog fallback when gating was too narrow.

Model-backed tools (detection, land-cover, VQA) apply a *seeded noise
model* standing in for real model inference: detections have per-class
recall/precision, land-cover adds jitter, VQA answers pass through a
temperature-controlled word dropout (the paper attributes its VQA metric
wobble to non-zero temperature).

Fused execution (the tool-graph compiler, DESIGN.md §Tool-graph
compiler): ``execute_graph`` runs one session's compiled ``ToolGraph``
in topological waves; ``execute_graph_batch`` merges the graphs of many
co-resident sessions into shared waves — the pipeline's cross-session
execution path. ``TOOL_EFFECTS`` is the authoritative per-tool
read/write table the compiler's hazard analysis runs on; a tool
implementation may only touch workspace state its entry declares.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.catalog import FAMILIES, family_of
from repro.core.toolgraph import ToolEffects, WORKSPACE_RESOURCES
from repro.core.tools import DEFAULT_REGISTRY, ToolRegistry, validate_effects
from repro.env.world import LANDCOVER_CLASSES, World


class ToolError(Exception):
    pass


class WorkspaceHazardError(ToolError):
    """A fused batch would execute two graphs against aliased state
    (shared Workspace object or duplicate session key): cross-session
    fusion is only sound when per-session workspaces are disjoint."""


@dataclass
class Workspace:
    world: World
    rng: np.random.Generator
    handles: List[str] = field(default_factory=list)
    map_layers: List[Dict] = field(default_factory=list)
    detections: Dict[str, Dict[str, int]] = field(default_factory=dict)
    landcover: Dict[str, Dict[str, float]] = field(default_factory=dict)
    artifacts: List[Dict] = field(default_factory=list)
    last_answer: Optional[str] = None
    ui_state: Dict[str, Any] = field(default_factory=dict)
    temperature: float = 0.3

    def obs(self, payload) -> str:
        """Render one tool observation. Ordering contract: batched/fused
        executions return observations sorted by ``(session id, node
        id)`` — never by dict or completion order — so reconciliation
        into session histories is reproducible (see
        ``execute_graph_batch``)."""
        s = str(payload)
        return s if len(s) < 900 else s[:900] + "…"


# per-class detector quality (seeded noise model)
_DET_RECALL = {"airplane": 0.96, "ship": 0.93, "storage tank": 0.91,
               "vehicle": 0.86, "helipad": 0.89, "bridge": 0.93,
               "crane": 0.87}
_DET_FP = {"airplane": 0.20, "ship": 0.28, "storage tank": 0.24,
           "vehicle": 0.64, "helipad": 0.16, "bridge": 0.12, "crane": 0.24}


def _resolve_ids(ws: Workspace, ids) -> List[str]:
    if isinstance(ids, str):
        ids = [ids]
    out = []
    for i in ids:
        if i in ws.world.images:
            out.append(i)
    return out


def execute_tool(ws: Workspace, name: str, args: Dict[str, Any]) -> str:
    w = ws.world
    if name == "sql_query_regions":
        place = args.get("place", "")
        hits = [c for c in w.regions if place.lower() in c.lower()
                or c.lower() in place.lower()]
        return ws.obs({"regions": hits, "bboxes": [w.regions[h]
                                                   for h in hits]})
    if name == "sql_query_images":
        rows = w.catalog_rows()
        sensor = args.get("sensor")
        region = args.get("region")
        if sensor:
            rows = [r for r in rows if r.sensor == sensor]
        if region:
            rows = [r for r in rows if region.lower() in r.region.lower()]
        if args.get("date_from"):
            rows = [r for r in rows if r.date >= args["date_from"]]
        if args.get("date_to"):
            rows = [r for r in rows if r.date <= args["date_to"]]
        if args.get("max_cloud") is not None:
            rows = [r for r in rows if r.cloud <= float(args["max_cloud"])]
        rows = rows[:24]
        ids = [r.image_id for r in rows]
        meta = [{"id": r.image_id, "date": r.date, "cloud": r.cloud,
                 "sensor": r.sensor} for r in rows[:12]]
        return ws.obs({"image_ids": ids, "count": len(ids), "rows": meta})
    if name == "sql_count":
        return ws.obs({"count": len(w.images)})
    if name == "sql_distinct":
        col = args.get("column", "sensor")
        vals = sorted({getattr(r, col, "") for r in w.catalog_rows()
                       if hasattr(r, col)})
        return ws.obs({"values": vals})
    if name == "sql_sample":
        n = int(args.get("n", 5))
        ids = sorted(w.images)[:n]
        return ws.obs({"image_ids": ids})

    if name == "load_images":
        ids = _resolve_ids(ws, args.get("image_ids", []))
        if not ids:
            raise ToolError("load_images: no valid image ids")
        ws.handles.extend(i for i in ids if i not in ws.handles)
        return ws.obs({"handles": ids})
    if name in ("filter_clouds", "filter_date"):
        hs = args.get("handles") or ws.handles
        if name == "filter_clouds":
            mx = float(args.get("max_cloud", 0.3))
            keep = [h for h in hs if w.images[h].cloud <= mx]
        else:
            keep = [h for h in hs
                    if (not args.get("date_from")
                        or w.images[h].date >= args["date_from"])
                    and (not args.get("date_to")
                         or w.images[h].date <= args["date_to"])]
        ws.handles = keep
        return ws.obs({"handles": keep, "kept": len(keep)})
    if name in ("mosaic", "reproject", "compute_ndvi", "band_math",
                "export_geotiff"):
        if not ws.handles:
            raise ToolError(f"{name}: workspace empty")
        ws.artifacts.append({"op": name, "inputs": list(ws.handles)})
        return ws.obs({"artifact": f"{name}_{len(ws.artifacts)}"})

    if name == "plot_map":
        hs = args.get("handles") or ws.handles
        if not hs:
            raise ToolError("plot_map: nothing to plot")
        ws.map_layers.append({"type": "images", "handles": list(hs),
                              "region": args.get("region", "")})
        return ws.obs({"map": "rendered", "layers": len(ws.map_layers)})
    if name in ("add_layer", "draw_bboxes", "heatmap", "plot_histogram",
                "plot_timeseries"):
        ws.map_layers.append({"type": name, "args": args})
        return ws.obs({"map": "updated", "layers": len(ws.map_layers)})
    if name == "screenshot_map":
        ws.artifacts.append({"op": "screenshot", "layers":
                             len(ws.map_layers)})
        return ws.obs({"artifact": "screenshot"})

    if name in ("detect_objects", "count_objects"):
        hs = args.get("handles") or ws.handles
        if not hs:
            raise ToolError(f"{name}: workspace empty")
        classes = args.get("classes") or list(_DET_RECALL)
        if isinstance(classes, str):
            classes = [classes]
        for h in hs:
            gt = w.images[h].objects
            det = {}
            for c in classes:
                n_gt = gt.get(c, 0)
                rec = _DET_RECALL.get(c, 0.85)
                tp = int(ws.rng.binomial(n_gt, rec)) if n_gt else 0
                fp = int(ws.rng.poisson(_DET_FP.get(c, 0.3)))
                det[c] = {"tp": tp, "fp": fp, "pred": tp + fp,
                          "gt": n_gt}
            ws.detections[h] = det
        total = {c: sum(ws.detections[h][c]["pred"] for h in hs
                        if c in ws.detections.get(h, {}))
                 for c in classes}
        return ws.obs({"detections": total, "images": len(hs)})
    if name == "change_detection":
        return ws.obs({"changes": int(ws.rng.poisson(4))})
    if name == "suggest_model":
        task = args.get("task", "")
        cls = next((c for c in _DET_RECALL if c in task), "airplane")
        return ws.obs({"model": f"dino-{cls.replace(' ', '-')}-v2"})

    if name == "classify_landcover":
        hs = args.get("handles") or ws.handles
        if not hs:
            raise ToolError("classify_landcover: workspace empty")
        for h in hs:
            gt = w.images[h].landcover
            noisy = {c: max(0.0, gt[c] + float(ws.rng.normal(0, 0.015)))
                     for c in LANDCOVER_CLASSES}
            z = sum(noisy.values())
            ws.landcover[h] = {c: v / z for c, v in noisy.items()}
        return ws.obs({"classified": len(hs)})
    if name == "landcover_stats":
        if not ws.landcover:
            raise ToolError("landcover_stats: classify first")
        agg = {c: float(np.mean([lc[c] for lc in ws.landcover.values()]))
               for c in LANDCOVER_CLASSES}
        ws.last_answer = max(agg, key=agg.get)
        return ws.obs({"fractions": {c: round(v, 4)
                                     for c, v in agg.items()}})
    if name == "compare_landcover":
        return ws.obs({"delta": "computed"})

    if name in ("visual_qa", "compare_images_qa", "caption_image",
                "describe_scene"):
        h = args.get("handle") or args.get("a") or (
            ws.handles[0] if ws.handles else None)
        if h is None or h not in w.images:
            raise ToolError(f"{name}: no image handle")
        # temperature-controlled generation noise (paper §2 attributes the
        # VQA metric wobble to non-zero temperature in function calling)
        base = w.images[h].caption
        words = base.split()
        kept = [wd for wd in words
                if ws.rng.random() > 0.34 + 0.3 * ws.temperature]
        filler = ["the", "image", "shows", "an", "area", "with",
                  "visible", "features"]
        n_fill = int(ws.rng.integers(2, 6))
        ans = " ".join(filler[:n_fill] + (kept or words[:3]))
        ws.last_answer = ans
        return ws.obs({"answer": ans})
    if name == "ground_phrase":
        return ws.obs({"box": [10, 20, 50, 60]})

    if name == "web_search":
        urls = sorted(w.web)[:5]
        return ws.obs({"results": [{"url": u, "title": w.web[u]["title"]}
                                   for u in urls]})
    if name == "open_url":
        url = args.get("url", "")
        if url not in w.web:
            url = sorted(w.web)[0]
        ws.ui_state["page"] = url
        ws.last_answer = w.web[url]["text"][:80]
        return ws.obs({"title": w.web[url]["title"],
                       "text": w.web[url]["text"][:120]})
    if name in ("download_file", "post_form"):
        ws.artifacts.append({"op": name})
        return ws.obs({"ok": True})

    if name in ("ui_click", "ui_type", "ui_scroll", "ui_read",
                "ui_open_panel"):
        ws.ui_state[name] = args
        return ws.obs({"ok": True, "state": name})

    if name == "wiki_search":
        q = args.get("query", "").lower()
        hits = [t for t in w.wiki if any(tok in t for tok in q.split())]
        hits = hits or sorted(w.wiki)[:3]
        return ws.obs({"titles": hits[:5]})
    if name in ("wiki_get", "wiki_summarize"):
        title = args.get("title", "")
        if title not in w.wiki:
            cand = [t for t in w.wiki if title.lower() in t]
            if not cand:
                raise ToolError(f"{name}: unknown article {title!r}")
            title = cand[0]
        body = w.wiki[title]
        # summarization keeps ~60% of the content (temperature-seeded)
        words = body.split()
        kept = [wd for wd in words if ws.rng.random() > 0.38]
        ws.last_answer = " ".join(kept) if kept else body[:80]
        return ws.obs({"article": title, "text": ws.last_answer[:300]})

    if name in ("transcribe_audio", "translate_audio"):
        clip = args.get("clip", "")
        if clip not in w.audio:
            clip = sorted(w.audio)[0]
        # ASR word-error noise
        words = w.audio[clip].split()
        kept = [wd for wd in words if ws.rng.random() > 0.12]
        ws.last_answer = " ".join(kept) if kept else w.audio[clip]
        return ws.obs({"transcript": ws.last_answer})

    if name == "run_python":
        ws.artifacts.append({"op": "run_python"})
        return ws.obs({"stdout": "ok"})
    if name == "tabulate":
        ws.artifacts.append({"op": "tabulate"})
        return ws.obs({"table": "rendered"})

    # generated-catalog tools (core/catalog.py) dispatch by family: one
    # real handler per family, uniform CATALOG_FAMILY_EFFECTS footprint
    family = family_of(name)
    if family is not None:
        return _execute_family(ws, family, name, args)

    raise ToolError(f"unknown tool: {name}")


def _execute_family(ws: Workspace, family: str, name: str,
                    args: Dict[str, Any]) -> str:
    """Dispatch for generated catalog tools (core/catalog.py): every
    member of a family shares one handler and one effects footprint
    (``CATALOG_FAMILY_EFFECTS[family]``), varying deterministically by
    tool name — no wall clock, no unseeded randomness, and only the
    declared workspace resources are touched (the family-table pass of
    the effects race detector checks this statically)."""
    w = ws.world
    if family == "catalogue":
        # pure metadata lookup — mirrors SQL_apis: no workspace effects
        rows = w.catalog_rows()
        n = sum(ord(c) for c in name) % 7 + 3
        meta = [{"id": r.image_id, "sensor": r.sensor} for r in rows[:n]]
        return ws.obs({"partition": name, "count": len(meta),
                       "rows": meta})
    if family == "ingest":
        hs = args.get("handles") or ws.handles
        if not hs:
            raise ToolError(f"{name}: workspace empty")
        keep = [h for h in hs if h in w.images]
        ws.handles = list(dict.fromkeys(keep))
        return ws.obs({"op": name, "handles": len(ws.handles)})
    if family == "carto":
        ws.map_layers.append({"type": name, "args": args})
        return ws.obs({"map": "updated", "layers": len(ws.map_layers)})
    if family == "detector":
        hs = args.get("handles") or ws.handles
        if not hs:
            raise ToolError(f"{name}: workspace empty")
        for h in hs:
            found = int(ws.rng.poisson(2.0))
            ws.detections.setdefault(h, {})[name] = {"pred": found}
        return ws.obs({"detector": name, "images": len(hs)})
    if family == "terrain":
        hs = args.get("handles") or ws.handles
        if not hs:
            raise ToolError(f"{name}: workspace empty")
        for h in hs:
            # full class-fraction dicts like classify_landcover (the
            # evaluator aggregates every class across all entries),
            # just coarser noise: generated terrain endpoints are the
            # catalog's lower-fidelity tier
            gt = w.images[h].landcover
            noisy = {c: max(0.0, gt[c] + float(ws.rng.normal(0, 0.05)))
                     for c in LANDCOVER_CLASSES}
            z = sum(noisy.values()) or 1.0
            ws.landcover[h] = {c: v / z for c, v in noisy.items()}
        return ws.obs({"classified": len(hs), "model": name})
    if family == "scene":
        h = args.get("handle") or (ws.handles[0] if ws.handles else None)
        if h is None or h not in w.images:
            raise ToolError(f"{name}: no image handle")
        words = w.images[h].caption.split()
        kept = [wd for wd in words if ws.rng.random() > 0.4]
        ws.last_answer = " ".join(kept or words[:3])
        return ws.obs({"answer": ws.last_answer})
    if family == "webnav":
        ws.ui_state[name] = args
        return ws.obs({"ok": True, "surface": name})
    if family == "corpus":
        titles = sorted(w.wiki)
        title = titles[sum(ord(c) for c in name) % len(titles)]
        words = w.wiki[title].split()
        kept = [wd for wd in words if ws.rng.random() > 0.45]
        ws.last_answer = " ".join(kept) if kept else title
        return ws.obs({"article": title, "text": ws.last_answer[:200]})
    if family == "audio":
        clips = sorted(w.audio)
        clip = clips[sum(ord(c) for c in name) % len(clips)]
        words = w.audio[clip].split()
        kept = [wd for wd in words if ws.rng.random() > 0.15]
        ws.last_answer = " ".join(kept) if kept else w.audio[clip]
        return ws.obs({"transcript": ws.last_answer})
    if family == "notebook":
        ws.artifacts.append({"op": name})
        return ws.obs({"artifact": f"{name}_{len(ws.artifacts)}"})
    raise ToolError(f"unknown tool family: {family}")


# ===================================================== fused execution =====
#
# Hazard alphabet — the named workspace resources the compiler's dep
# inference runs over. ``world`` state is read-only at execution time
# (no tool mutates it) so world reads never create hazards; ``rng`` is
# modelled as a WRITE because consuming the seeded stream reorders every
# later draw.
#
#   handles     ws.handles               (loaded image handle list)
#   map         ws.map_layers            (additive layer stack)
#   detections  ws.detections            (per-handle detection results)
#   landcover   ws.landcover             (per-handle class fractions)
#   artifacts   ws.artifacts             (export/screenshot/table store)
#   answer      ws.last_answer           (the user-visible answer)
#   ui          ws.ui_state              (browser/UI session state)
#   rng         ws.rng                   (seeded noise-model stream)

#: Resource name -> the ``Workspace`` attribute it denotes. This is the
#: structured form of the table above, consumed by the static effects
#: race detector (``repro.analysis.effects_check``): any handler access
#: to one of these attributes must be covered by the tool's declared
#: ``ToolEffects`` entry.
WORKSPACE_RESOURCE_ATTRS: Dict[str, str] = {
    "handles": "handles",
    "map": "map_layers",
    "detections": "detections",
    "landcover": "landcover",
    "artifacts": "artifacts",
    "answer": "last_answer",
    "ui": "ui_state",
    "rng": "rng",
}

#: Workspace attributes that are read-only configuration at tool-
#: execution time (no tool may write them), hence outside the hazard
#: alphabet: reads of these can never order two tools.
READONLY_WORKSPACE_ATTRS = frozenset({"world", "temperature"})

assert frozenset(WORKSPACE_RESOURCE_ATTRS) == WORKSPACE_RESOURCES


def _eff(reads: str = "", writes: str = "") -> ToolEffects:
    return ToolEffects(frozenset(reads.split()), frozenset(writes.split()))


TOOL_EFFECTS: Dict[str, ToolEffects] = {
    # SQL_apis: pure catalog reads — never hazard with anything
    "sql_query_images":   _eff(),
    "sql_query_regions":  _eff(),
    "sql_count":          _eff(),
    "sql_distinct":       _eff(),
    "sql_sample":         _eff(),
    # data_apis
    "load_images":        _eff(writes="handles"),
    "filter_clouds":      _eff(reads="handles", writes="handles"),
    "filter_date":        _eff(reads="handles", writes="handles"),
    "mosaic":             _eff(reads="handles", writes="artifacts"),
    "reproject":          _eff(reads="handles", writes="artifacts"),
    "compute_ndvi":       _eff(reads="handles", writes="artifacts"),
    "band_math":          _eff(reads="handles", writes="artifacts"),
    "export_geotiff":     _eff(reads="handles", writes="artifacts"),
    # map_apis
    "plot_map":           _eff(reads="handles", writes="map"),
    "add_layer":          _eff(writes="map"),
    "draw_bboxes":        _eff(writes="map"),
    "heatmap":            _eff(writes="map"),
    "plot_histogram":     _eff(writes="map"),
    "plot_timeseries":    _eff(writes="map"),
    "screenshot_map":     _eff(reads="map", writes="artifacts"),
    # detect_apis (model-backed: seeded noise => rng writers)
    "detect_objects":     _eff(reads="handles", writes="detections rng"),
    "count_objects":      _eff(reads="handles", writes="detections rng"),
    "change_detection":   _eff(writes="rng"),
    "suggest_model":      _eff(),
    # landcover_apis
    "classify_landcover": _eff(reads="handles", writes="landcover rng"),
    "landcover_stats":    _eff(reads="landcover", writes="answer"),
    "compare_landcover":  _eff(),
    # vqa_apis / vision_apis (model-backed)
    "visual_qa":          _eff(reads="handles", writes="answer rng"),
    "caption_image":      _eff(reads="handles", writes="answer rng"),
    "compare_images_qa":  _eff(reads="handles", writes="answer rng"),
    "describe_scene":     _eff(reads="handles", writes="answer rng"),
    "ground_phrase":      _eff(),
    # web_apis
    "web_search":         _eff(),
    "open_url":           _eff(writes="ui answer"),
    "download_file":      _eff(writes="artifacts"),
    "post_form":          _eff(writes="artifacts"),
    # UI_apis
    "ui_click":           _eff(writes="ui"),
    "ui_type":            _eff(writes="ui"),
    "ui_scroll":          _eff(writes="ui"),
    "ui_read":            _eff(writes="ui"),
    "ui_open_panel":      _eff(writes="ui"),
    # wiki_apis
    "wiki_search":        _eff(),
    "wiki_get":           _eff(writes="answer rng"),
    "wiki_summarize":     _eff(writes="answer rng"),
    # speech_apis
    "transcribe_audio":   _eff(writes="answer rng"),
    "translate_audio":    _eff(writes="answer rng"),
    # code_apis
    "run_python":         _eff(writes="artifacts"),
    "tabulate":           _eff(writes="artifacts"),
}


# Fail fast at import if the effects table drifts from the catalog:
# exact 1:1 registry<->effects coverage, alphabet-only resource names
# (the runtime mirror of repro.analysis RL004/RL005).
validate_effects(DEFAULT_REGISTRY, TOOL_EFFECTS)


#: Per-family effects for generated catalog tools (core/catalog.py):
#: every member of a family shares its footprint. The effects race
#: detector runs a second pass over ``_execute_family`` keyed on this
#: table (repro.analysis.effects_check with name_param="family"), so a
#: family handler that drifts from its declaration fails the analyzer
#: exactly like a hand-written tool would.
CATALOG_FAMILY_EFFECTS: Dict[str, ToolEffects] = {
    "catalogue": _eff(),
    "ingest":    _eff(reads="handles", writes="handles"),
    "carto":     _eff(writes="map"),
    "detector":  _eff(reads="handles", writes="detections rng"),
    "terrain":   _eff(reads="handles", writes="landcover rng"),
    "scene":     _eff(reads="handles", writes="answer rng"),
    "webnav":    _eff(writes="ui"),
    "corpus":    _eff(writes="answer rng"),
    "audio":     _eff(writes="answer rng"),
    "notebook":  _eff(writes="artifacts"),
}

# the family specs (core/catalog.py) and this literal must agree — the
# literal exists so the static analyzer can parse it, the spec so the
# catalog module stays self-describing
assert set(CATALOG_FAMILY_EFFECTS) == {f.name for f in FAMILIES}
for _fam in FAMILIES:
    assert CATALOG_FAMILY_EFFECTS[_fam.name] == _eff(_fam.reads,
                                                     _fam.writes), _fam.name


def tool_effects(name: str) -> ToolEffects:
    """Effects lookup for the compiler; generated catalog tools resolve
    through their family footprint; unknown tools raise ToolError
    (mirrors ``execute_tool`` semantics at compile time)."""
    eff = TOOL_EFFECTS.get(name)
    if eff is not None:
        return eff
    family = family_of(name)
    if family is not None:
        return CATALOG_FAMILY_EFFECTS[family]
    raise ToolError(f"unknown tool: {name}")


def catalog_effects(registry: ToolRegistry) -> Dict[str, ToolEffects]:
    """The exact per-tool effects table of a generated catalog registry
    (base entries + family footprints) — what
    ``core.tools.validate_effects`` checks 1:1 against the registry."""
    return {name: tool_effects(name) for name in registry.tools}


@dataclass(frozen=True)
class NodeObservation:
    """One executed node's result, addressed for reconciliation."""
    node_id: int
    tool: str
    text: str                 # "{tool} -> {obs}" or "{tool} -> ERROR: .."
    ok: bool


def _run_node(ws: Workspace, node) -> NodeObservation:
    try:
        out = execute_tool(ws, node.tool, node.args)
        return NodeObservation(node.node_id, node.tool,
                               f"{node.tool} -> {out}", True)
    except ToolError as e:
        # an erroring node does NOT cancel its dependents: the linear
        # agent loop executes every call of a step regardless of earlier
        # errors, and fused execution must be observation-equivalent.
        # Tools guard their own preconditions (E1xx errors).
        return NodeObservation(node.node_id, node.tool,
                               f"{node.tool} -> ERROR: {e}", False)


def execute_graph(ws: Workspace, graph) -> List[NodeObservation]:
    """Execute one session's compiled graph in topological waves.

    Within a wave nodes run in ascending node-id order; observations are
    returned sorted by node id (= planner emission order) regardless of
    wave placement, so reconciliation is schedule-independent. Hazard
    deps guarantee the end state is bitwise identical to sequential
    emission-order execution (DESIGN.md §Tool-graph compiler).
    """
    out: List[NodeObservation] = []
    for wave in graph.wave_schedule():
        for nid in wave:
            out.append(_run_node(ws, graph.node(nid)))
    out.sort(key=lambda o: o.node_id)
    return out


def execute_graph_batch(entries: Sequence[Tuple[int, Workspace, Any]]
                        ) -> Dict[int, List[NodeObservation]]:
    """Fused cross-session execution: one batched run over the graphs of
    many co-resident sessions.

    ``entries`` is ``(session_key, workspace, graph)`` triples. Wave w
    of the batch executes every session's wave-w nodes in ``(session
    key, node id)`` order — the documented, stable observation order; the
    returned dict maps each session key to its observations sorted by
    node id, bitwise identical to running ``execute_graph`` per session
    alone (workspaces are disjoint, so sessions cannot hazard with each
    other).

    Hazard detection on shared state: duplicate session keys or two
    entries aliasing one ``Workspace`` object raise
    ``WorkspaceHazardError`` before anything executes.
    """
    seen_keys: set = set()
    seen_ws: Dict[int, int] = {}
    for key, ws, _ in entries:
        if key in seen_keys:
            raise WorkspaceHazardError(
                f"duplicate session key {key} in fused batch")
        seen_keys.add(key)
        if id(ws) in seen_ws:
            raise WorkspaceHazardError(
                f"sessions {seen_ws[id(ws)]} and {key} share one "
                f"Workspace — fused waves would interleave hazards")
        seen_ws[id(ws)] = key

    ordered = sorted(entries, key=lambda e: e[0])
    schedules = [(key, ws, graph, graph.wave_schedule())
                 for key, ws, graph in ordered]
    results: Dict[int, List[NodeObservation]] = {
        key: [] for key, _, _, _ in schedules}
    n_waves = max((len(s) for _, _, _, s in schedules), default=0)
    for w in range(n_waves):
        for key, ws, graph, sched in schedules:
            if w < len(sched):
                for nid in sched[w]:
                    results[key].append(_run_node(ws, graph.node(nid)))
    for key in results:
        results[key].sort(key=lambda o: o.node_id)
    return results
