"""Logical-axis -> mesh-axis sharding rules for params, optimizer state,
batches and KV/state caches.

Rules are *path-based* over the param pytree and guarded by divisibility:
a tensor dim is only sharded over a mesh-axis tuple whose total size
divides it, so odd head counts (qwen1.5-32b kv=40, whisper kv=20,
hymba kv=5) degrade gracefully to replication instead of failing to lower.

``ShardingStrategy`` exposes the knobs the §Perf hillclimb flips.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class ShardingStrategy:
    fsdp: bool = True                  # shard weight d_model dims over data
    zero1: bool = True                 # shard optimizer m/v like fsdp
    decode_cache_seq: str = "model"    # decode KV cache seq axis: model|data|both|none
    shard_vocab: bool = True           # embed/lm_head vocab over model
    batch_over_pod: bool = True        # fold pod axis into the batch axes
    prefill_seq_axis: str = "none"     # shard prefill activations' seq dim

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def dp_axes(mesh: Mesh, strategy: ShardingStrategy) -> Tuple[str, ...]:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not strategy.batch_over_pod:
        axes = tuple(a for a in axes if a != "pod")
    return axes


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, axes):
    """Return axes if dim divisible by their product, else progressively
    drop trailing axes; None if nothing fits."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    while axes:
        if dim % _axis_size(mesh, axes) == 0:
            return axes if len(axes) > 1 else axes[0]
        axes = axes[:-1]
    return None


def _spec(mesh, shape, *per_dim):
    """Build a PartitionSpec applying _fit per dim."""
    assert len(per_dim) == len(shape), (shape, per_dim)
    return P(*[_fit(mesh, d, ax) for d, ax in zip(shape, per_dim)])


# ----------------------------------------------------------------- params ----

def param_spec(path_keys, leaf, cfg: ModelConfig, mesh: Mesh,
               strategy: ShardingStrategy) -> P:
    keys = path_keys
    name = keys[-1] if keys else ""
    shape = leaf.shape
    dat = "data" if strategy.fsdp else None
    mdl = "model"

    def stacked(spec_tail):
        """Prepend None for the layer-stack axis if leaf is stacked."""
        extra = len(shape) - len(spec_tail)
        return P(*([None] * extra + list(spec_tail)))

    if name in ("embed", "lm_head"):
        vocab_ax = mdl if strategy.shard_vocab else None
        if name == "embed":
            return _spec(mesh, shape, vocab_ax, dat)
        return _spec(mesh, shape, dat, vocab_ax)
    if name == "scale" or "norm" in name or name in ("b_gates", "dt_bias",
                                                     "b_i", "b_f", "D"):
        return P(*([None] * len(shape)))

    # MoE expert weights: (R?, E, d, ff) — experts over model.
    if "moe" in keys and name in ("w_gate", "w_up", "w_down"):
        tail = [mdl, dat, None] if name != "w_down" else [mdl, None, dat]
        return stacked(_spec(mesh, shape[-3:], *tail))
    if "moe" in keys and name == "router":
        return stacked(_spec(mesh, shape[-2:], dat, mdl))

    two_d = {
        # attention
        "wq": (dat, mdl), "wk": (dat, mdl), "wv": (dat, mdl),
        "wo": (mdl, dat),
        # mlp
        "w_gate": (dat, mdl), "w_up": (dat, mdl), "w_down": (mdl, dat),
        # ssm
        "in_proj": (dat, mdl), "w_bc": (mdl, None), "w_dt": (mdl, None),
        "dt_proj": (None, mdl), "out_proj": (mdl, dat),
        # xlstm
        "up": (dat, mdl), "down": (mdl, dat), "w_gates": (dat, mdl),
        "w_if": (mdl, None),
    }
    one_d = {"bq": mdl, "bk": mdl, "bv": mdl, "conv_b": mdl}
    if name in two_d and len(shape) >= 2:
        return stacked(_spec(mesh, shape[-2:], *two_d[name]))
    if name in one_d and len(shape) >= 1:
        return stacked(_spec(mesh, shape[-1:], one_d[name]))
    if name == "conv_w":  # (R?, K, di)
        return stacked(_spec(mesh, shape[-2:], None, mdl))
    if name == "A_log":   # (R?, di, n)
        return stacked(_spec(mesh, shape[-2:], mdl, None))
    if name == "r_gates":  # (R?, H, hd, 4hd)
        return stacked(_spec(mesh, shape[-3:], None, None, mdl))
    return P(*([None] * len(shape)))


def _tree_specs(tree, fn):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        keys = tuple(
            str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
            for k in path)
        specs.append(fn(keys, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def params_sharding(param_tree, cfg: ModelConfig, mesh: Mesh,
                    strategy: ShardingStrategy):
    return _tree_specs(
        param_tree, lambda keys, leaf: NamedSharding(
            mesh, param_spec(keys, leaf, cfg, mesh, strategy)))


def opt_state_sharding(opt_tree, param_tree, cfg: ModelConfig, mesh: Mesh,
                       strategy: ShardingStrategy):
    """ZeRO-1: m/v take the fsdp spec even if params are model-only."""
    st = strategy.replace(fsdp=strategy.fsdp or strategy.zero1)
    def fn(keys, leaf):
        if keys and keys[0] == "step" or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        # strip the leading "m"/"v" path element; rules key off names anyway
        return NamedSharding(mesh, param_spec(keys, leaf, cfg, mesh, st))
    return _tree_specs(opt_tree, fn)


# ------------------------------------------------------------ batch/cache ----

def batch_sharding(batch_tree, cfg: ModelConfig, shape: ShapeConfig,
                   mesh: Mesh, strategy: ShardingStrategy):
    dp = dp_axes(mesh, strategy)
    # Sequence-parallel prefill (context parallelism): shard the prompt's
    # seq dim over `prefill_seq_axis`; GSPMD all-gathers the (small, GQA)
    # K/V heads inside attention instead of all-reducing TP activations.
    sax = (strategy.prefill_seq_axis
           if (shape.mode == "prefill"
               and strategy.prefill_seq_axis != "none") else None)

    def fn(keys, leaf):
        name = keys[-1]
        if name == "mrope_pos":        # (3, B, S)
            bax = dp if leaf.shape[1] > 1 else None
            return NamedSharding(mesh, _spec(mesh, leaf.shape, None, bax, sax))
        bax = dp if leaf.shape[0] > 1 else None
        if name in ("tokens", "labels"):
            return NamedSharding(mesh, _spec(mesh, leaf.shape, bax, sax))
        if name in ("frames", "patch_embeds"):
            return NamedSharding(mesh, _spec(mesh, leaf.shape, bax, sax, None))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return _tree_specs(batch_tree, fn)


def cache_sharding(cache_tree, cfg: ModelConfig, shape: ShapeConfig,
                   mesh: Mesh, strategy: ShardingStrategy):
    dp = dp_axes(mesh, strategy)
    seq_ax = {"model": ("model",), "data": ("data",),
              "both": ("data", "model"), "none": None}[strategy.decode_cache_seq]
    B = shape.global_batch
    batch_sharded = B % _axis_size(mesh, dp) == 0 and B > 1

    def fn(keys, leaf):
        name = keys[-1]
        shp = leaf.shape
        if name == "pos" or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        bax = dp if batch_sharded else None
        # Batch-sharded caches put seq on `model`; unsharded-batch (B=1,
        # long_500k) caches can spread seq over every axis.
        if batch_sharded:
            sax = ("model",) if seq_ax is not None else None
        else:
            sax = seq_ax
        if name in ("k", "v", "ck", "cv"):   # (R, B, Hkv, S, hd)
            return NamedSharding(
                mesh, _spec(mesh, shp, None, bax, None, sax, None))
        if name == "h" and "ssm" in keys:    # (R, B, di, n)
            return NamedSharding(mesh, _spec(mesh, shp, None, bax, "model",
                                             None))
        if name == "conv":                   # (R, B, K-1, di)
            return NamedSharding(mesh, _spec(mesh, shp, None, bax, None,
                                             "model"))
        if "mlstm" in keys:                  # (R,B,H,hd[,hd]) fp32
            rest = [None] * (leaf.ndim - 2)
            return NamedSharding(mesh, _spec(mesh, shp, None, bax, *rest))
        if "slstm" in keys:                  # (R,B,H,hd)
            return NamedSharding(mesh, _spec(mesh, shp, None, bax, None, None))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return _tree_specs(cache_tree, fn)


def logits_sharding(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    strategy: ShardingStrategy):
    dp = dp_axes(mesh, strategy)
    B = shape.global_batch
    bax = dp if (B % _axis_size(mesh, dp) == 0 and B > 1) else None
    vax = "model" if (strategy.shard_vocab
                      and cfg.vocab_size % mesh.shape["model"] == 0) else None
    return NamedSharding(mesh, P(bax, vax))
