"""In-graph sharding annotations (``with_sharding_constraint`` helpers).

GSPMD propagates shardings well through matmuls but loses them at
head-boundary reshapes when head counts are not divisible by the model
axis (hymba 25q/5kv, gemma2 8q/4kv vs model=16). The dry-run analysis
showed attention then running at *global* batch, replicated per chip —
a 16 TiB/chip temp for hymba train_4k. ``constrain_attn`` pins the
batch/head layout explicitly; every spec dim is divisibility-guarded so
the same code lowers on any mesh (including the single-device test mesh,
where it is a no-op).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

try:                                     # jax >= 0.8 home of thread_resources
    from jax._src.mesh import thread_resources as _tr
except ImportError:                      # pragma: no cover - older jax
    from jax.interpreters.pxla import thread_resources as _tr


def _mesh():
    m = _tr.env.physical_mesh
    return None if m.empty else m


def _fit(mesh, dim: int, axes):
    """Largest prefix of `axes` (present in mesh) whose product divides dim."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    while axes:
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if dim % n == 0:
            return axes if len(axes) > 1 else axes[0]
        axes = axes[:-1]
    return None


UNCONSTRAINED = "__unconstrained__"


def constrain(x, *per_dim):
    """with_sharding_constraint with divisibility-guarded per-dim axes.

    per_dim: one axis-name / tuple / None / UNCONSTRAINED per array dim
    (UNCONSTRAINED leaves that dim to GSPMD instead of pinning it
    replicated). No-op outside a mesh context (unit tests, single-device
    benches).
    """
    mesh = _mesh()
    if mesh is None:
        return x
    spec = P(*[P.UNCONSTRAINED if ax is UNCONSTRAINED
               else _fit(mesh, d, ax) for d, ax in zip(x.shape, per_dim)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def attn_batch_head_axes(mesh, batch: int, n_q_heads: int, n_kv_heads: int):
    """Pick (batch_axes, q_head_axes, kv_head_axes) for attention internals.

    Preference order (refined after the first production sweep — the
    blanket "spread batch over everything" rule regressed wide/deep
    models by up to 15x, see EXPERIMENTS.md §Prod-profile):
      1. q heads divisible by `model` -> Megatron TP: q heads over model,
         kv heads over model too when they divide, else replicated (GQA
         kv is small); batch over data. Zero/cheap resharding.
      2. q heads NOT shardable but batch divisible by data*model ->
         batch over both axes (attention fully data-parallel; pays one
         activation reshard in/out — only wins when heads are stuck,
         e.g. hymba's 25q/5kv).
      3. otherwise: pin ONLY the batch dim (keeps GSPMD from replicating
         attention at global batch — the original hymba bug) and leave
         every other dim UNCONSTRAINED so seq-sharded-KV plans survive.
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if "model" not in mesh.axis_names:
        return dp, None, None
    m = mesh.shape["model"]
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if n_q_heads % m == 0:
        kv_ax = ("model",) if n_kv_heads % m == 0 else None
        return dp, ("model",), kv_ax
    if batch % (n_dp * m) == 0:
        return dp + ("model",), dp + ("model",), dp + ("model",)
    return dp, UNCONSTRAINED, UNCONSTRAINED


def constrain_attn(q, k, v):
    """Pin (B, H, S, hd) sharding for attention inputs.

    Returns (q, k, v, pinned). pinned=False means GSPMD keeps full
    freedom (callers use this to gate optimizations that assume KV is
    chip-local, e.g. banded window slicing).
    """
    mesh = _mesh()
    if mesh is None:
        return q, k, v, True       # single device: trivially local
    bax, qhax, kvhax = attn_batch_head_axes(mesh, q.shape[0], q.shape[1],
                                            k.shape[1])
    if qhax is UNCONSTRAINED:      # batch-only pin: GSPMD keeps seq freedom
        U = UNCONSTRAINED
        q = constrain(q, bax, U, U, U)
        k = constrain(k, bax, U, U, U)
        v = constrain(v, bax, U, U, U)
        return q, k, v, False      # KV may still be seq-sharded
    if bax and "model" in bax:     # batch-spread mode: heads stay local
        q = constrain(q, bax, None, None, None)
        k = constrain(k, bax, None, None, None)
        v = constrain(v, bax, None, None, None)
        return q, k, v, True
    q = constrain(q, bax, qhax, None, None)
    k = constrain(k, bax, kvhax, None, None)
    v = constrain(v, bax, kvhax, None, None)
    return q, k, v, True


def constrain_seq(x, seq_axis: str):
    """Pin (B, S, d) activations to batch-over-data, seq-over-`seq_axis`.

    Sequence-parallel prefill: sharding only the *input tokens* is a hint
    GSPMD discards (tokens are tiny — it re-shards immediately); pinning
    the residual stream per layer is what actually holds the layout.
    """
    mesh = _mesh()
    if mesh is None or not seq_axis or seq_axis == "none":
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return constrain(x, dp if x.shape[0] > 1 else None, seq_axis, None)


def constrain_attn_out(out, n_kv_heads: int):
    """Pin (B, Hq, S, hd) sharding of the attention output (pre out-proj)."""
    mesh = _mesh()
    if mesh is None:
        return out
    bax, qhax, _ = attn_batch_head_axes(mesh, out.shape[0], out.shape[1],
                                        n_kv_heads)
    if qhax is UNCONSTRAINED:
        U = UNCONSTRAINED
        return constrain(out, bax, U, U, U)
    if bax and "model" in bax:
        return constrain(out, bax, None, None, None)
    return constrain(out, bax, qhax, None, None)
