"""Serving launcher: run the inference engine with batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch planner-proxy-100m \
      --smoke --requests 16 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_smoke_config
from repro.models.model import init_params
from repro.serving.engine import InferenceEngine
from repro.serving.sampling import SamplerConfig
from repro.training.checkpoint import load_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="planner-proxy-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--backend", default=None,
                    choices=("reference", "pallas"),
                    help="kernel backend (default: PerfFlags.kernel_backend)")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.checkpoint:
        params = load_checkpoint(args.checkpoint, params)

    engine = InferenceEngine(cfg, params, max_batch=args.max_batch,
                             cache_len=args.cache_len,
                             backend=args.backend)
    prompts = [
        f"Plot xview1 images around Tampa Bay with cloud cover below "
        f"{10 + i}%" for i in range(args.requests)]
    t0 = time.time()
    for p in prompts:
        engine.add_request(p, max_new_tokens=args.max_new,
                           sampler=SamplerConfig(
                               temperature=args.temperature, top_k=40))
    done = engine.run_until_done()
    dt = time.time() - t0
    st = engine.throughput_stats()
    print(f"served {len(done)} requests in {dt:.2f}s | "
          f"decode steps {st['decode_steps']} | "
          f"{st['tokens_generated'] / max(dt, 1e-9):.1f} tok/s")
    lat = [r.finish_t - r.enqueue_t for r in done]
    ttft = [r.first_token_t - r.enqueue_t for r in done]
    print(f"p50 latency {sorted(lat)[len(lat)//2]*1000:.0f}ms | "
          f"p50 TTFT {sorted(ttft)[len(ttft)//2]*1000:.0f}ms")


if __name__ == "__main__":
    main()
