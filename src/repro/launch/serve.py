"""Serving launcher: run the inference engine with batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch planner-proxy-100m \
      --smoke --requests 16 --max-new 24

With ``--replicas N`` the launcher serves a synthetic mixed-intent
workload (serving/workload.py) on an N-replica ``EngineCluster``
instead, and reports cluster-level tick metrics:

  PYTHONPATH=src python -m repro.launch.serve --smoke --replicas 4 \
      --router intent_affinity --requests 32 --profile bursty --skew 0.7

``--spec-decode`` turns on speculative decoding (serving/specdec.py):
the engine drafts ``--draft-k`` greedy tokens per slot and verifies
them in one target forward, emitting a multiple of the tokens per
target forward with tokens bitwise identical to non-speculative
decoding (unconditionally at T=0; at any temperature for seeded
requests — DESIGN.md §Speculative decoding). The launcher has no trained draft
checkpoint to load, so the draft shares the target's weights — the
perfect-agreement stand-in the benches use; point a real deployment's
``SpecConfig`` at a distilled ``planner_proxy_100m``-class draft.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_smoke_config
from repro.models.model import init_params
from repro.obs import Tracer
from repro.obs.export import write_trace
from repro.serving.cluster import ROUTER_POLICIES, EngineCluster
from repro.serving.engine import InferenceEngine
from repro.serving.sched import ADMISSION_POLICIES
from repro.serving.sampling import SamplerConfig
from repro.serving.specdec import SpecConfig
from repro.serving.workload import (PROFILES, WorkloadConfig,
                                    make_workload,
                                    register_workload_prefixes,
                                    skewed_mix, uniform_mix)
from repro.training.checkpoint import load_checkpoint


def _fmt(v, unit: str = "") -> str:
    """Render a possibly-None metric ("n/a": empty percentile series)."""
    return "n/a" if v is None else f"{v:.0f}{unit}"


def serve_cluster(cfg, params, args, spec_decode=None):
    # cluster engines run on the tick clock only, so the trace is
    # wall-free and byte-identical across same-seed runs
    tracer = Tracer() if args.trace_out else None
    cluster = EngineCluster(cfg, params, args.replicas,
                            router=args.router,
                            max_batch=args.max_batch,
                            cache_len=args.cache_len,
                            backend=args.backend,
                            kv_mode=args.kv_mode,
                            kv_blocks=args.kv_blocks,
                            block_size=args.block_size,
                            spec_decode=spec_decode,
                            prefill_budget=args.prefill_budget,
                            interleave=not args.no_interleave,
                            admission=args.admission,
                            sla_spill=args.sla_spill,
                            tracer=tracer)
    mix = (skewed_mix(hot_frac=args.skew) if args.skew > 0
           else uniform_mix())
    reqs = make_workload(WorkloadConfig(
        n_sessions=args.requests, intent_mix=mix, profile=args.profile,
        max_turns=args.turns, max_new_tokens=args.max_new,
        temperature=args.temperature, seed=0))
    register_workload_prefixes(cluster, reqs)
    t0 = time.time()
    stats = cluster.run_workload(reqs)
    dt = time.time() - t0
    s = stats.summary()
    print(f"cluster[{args.replicas}x{args.max_batch} slots, "
          f"router={args.router}] served {s['finished']}/{s['requests']} "
          f"requests in {s['ticks']} ticks ({dt:.2f}s wall)")
    print(f"ttft p50/p95/p99 {_fmt(s['ttft_p50'])}/{_fmt(s['ttft_p95'])}"
          f"/{_fmt(s['ttft_p99'])} ticks | "
          f"admit-wait p95 {_fmt(s['admit_wait_p95'])} | "
          f"e2e p50/p95 {_fmt(s['e2e_p50'])}/{_fmt(s['e2e_p95'])} | "
          f"SLA {100 * s['sla_attainment']:.1f}%"
          + (f" | {s['sla_expired']} expired in queue"
             if s["sla_expired"] else ""))
    print(f"prefix-hit ratio {s['prefix_hit_ratio']:.2f} | "
          f"{s['tokens_out']} tokens out")
    if spec_decode is not None:
        print(f"spec-decode[k={spec_decode.k}]: "
              f"{s['tokens_per_step']:.2f} tokens/target-forward over "
              f"{s['spec_rounds']} rounds, accept rate "
              f"{s['spec_accept_rate']:.2f}")
    kv_line = (f"kv[{args.kv_mode}]: peak "
               f"{s['kv_bytes_peak'] / 2**20:.1f} MiB of "
               f"{s['kv_bytes_allocated'] / 2**20:.1f} MiB")
    if args.kv_mode == "paged":
        kv_line += (f" | shared-block frac {s['kv_shared_frac']:.2f} | "
                    f"{s['preemptions']} preemptions, "
                    f"{s['resumes']} resumes, "
                    f"{s['prefix_evictions']} prefix evictions")
    print(kv_line)
    for r in s["per_replica"]:
        print(f"  replica {r['replica']}: {r['admissions']} admissions, "
              f"hit {r['hit_ratio']:.2f}, util {r['utilization']:.2f}")
    if tracer is not None:
        write_trace(tracer, args.trace_out)
        print(f"trace: {len(tracer.records)} records -> "
              f"{args.trace_out}")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="planner-proxy-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--backend", default=None,
                    choices=("reference", "pallas"),
                    help="kernel backend (default: PerfFlags.kernel_backend)")
    ap.add_argument("--kv-mode", default="dense",
                    choices=("dense", "paged"),
                    help="KV-cache manager: dense per-slot slabs or the "
                         "paged block pool with CoW prefix sharing")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="paged: physical KV blocks (default: the dense "
                         "budget, max_batch*cache_len/block_size)")
    ap.add_argument("--block-size", type=int, default=None,
                    help="paged: tokens per KV block (default: 16)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve an EngineCluster of N replicas (> 1)")
    ap.add_argument("--router", default="intent_affinity",
                    choices=ROUTER_POLICIES)
    ap.add_argument("--profile", default="uniform", choices=PROFILES,
                    help="workload arrival profile (cluster mode)")
    ap.add_argument("--skew", type=float, default=0.0,
                    help="hot-intent traffic fraction in [0, 1] "
                         "(0 = uniform mix, 1 = all hot)")
    ap.add_argument("--turns", type=int, default=1,
                    help="max turns per session (cluster mode)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="chunked prefill: max prompt tokens processed "
                         "per engine step (attn_chunk-aligned slabs; "
                         "budgets below one chunk fall back to one "
                         "whole chunk per step), interleaved with "
                         "decode so long prompts never stall running "
                         "streams. Default: monolithic admission-step "
                         "prefill")
    ap.add_argument("--no-interleave", action="store_true",
                    help="with --prefill-budget: run each prefill to "
                         "completion before decoding (the stall-prone "
                         "baseline the benches compare against)")
    ap.add_argument("--admission", default="fifo",
                    choices=ADMISSION_POLICIES,
                    help="admission-queue order: arrival (fifo) or "
                         "earliest SLA deadline first (slack; also "
                         "picks most-slack preemption victims)")
    ap.add_argument("--sla-spill", action="store_true",
                    help="intent_affinity router: spill a request to "
                         "the least-loaded replica when its SLA slack "
                         "is smaller than its home replica's load "
                         "(cluster mode)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decoding: draft --draft-k greedy "
                         "tokens per slot with a draft model sharing "
                         "the target's weights, verify them in one "
                         "target forward (tokens bitwise-identical to "
                         "non-speculative decoding at --temperature 0, "
                         "and at any temperature for seeded requests — "
                         "the cluster workload path; unseeded T>0 "
                         "engine-stream sampling draws a different key "
                         "schedule, like any co-tenancy change)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens per speculative round (>= 1)")
    ap.add_argument("--catalog-size", type=int, default=None,
                    help="single-engine mode: serve against a generated "
                         "tool catalog of N tools (core/catalog.py) "
                         "instead of the base registry; needs "
                         "--retriever-k (the launcher has no intent "
                         "gate, so a scaled catalog is only servable "
                         "through retrieval)")
    ap.add_argument("--retriever-k", type=int, default=None,
                    help="single-engine mode: retrieve a top-k toolset "
                         "per request (core/retriever.py), register "
                         "each toolset as a shared engine prefix, and "
                         "prepend its catalog text to the prompt — "
                         "requests retrieving the same toolset share "
                         "one cached prefill")
    ap.add_argument("--trace-out", default="",
                    help="write the request-lifecycle trace here after "
                         "the run: .jsonl = compact record-per-line, "
                         "anything else = Chrome trace-event JSON "
                         "(open in Perfetto / chrome://tracing). "
                         "Cluster traces are tick-only and "
                         "byte-identical across same-seed runs; the "
                         "single-engine path injects time.time, so "
                         "records also carry wall timestamps")
    return ap


def validate_args(ap: argparse.ArgumentParser, args):
    """CLI-level invalid-combination errors, raised before any model is
    built (mirrors the engine constructors' refusals)."""
    if not 0.0 <= args.skew <= 1.0:
        ap.error(f"--skew must be in [0, 1], got {args.skew}")
    if args.kv_mode == "dense" and (args.kv_blocks is not None
                                    or args.block_size is not None):
        ap.error("--kv-blocks/--block-size apply only to "
                 "--kv-mode paged")
    if args.spec_decode and args.draft_k < 1:
        ap.error(f"--spec-decode needs --draft-k >= 1, "
                 f"got {args.draft_k}")
    if args.replicas < 1:
        ap.error(f"--replicas must be >= 1, got {args.replicas}")
    if args.prefill_budget is not None and args.prefill_budget < 1:
        ap.error(f"--prefill-budget must be >= 1, "
                 f"got {args.prefill_budget}")
    if args.no_interleave and args.prefill_budget is None:
        ap.error("--no-interleave only applies with --prefill-budget "
                 "(monolithic prefill has nothing to interleave)")
    if args.sla_spill and args.replicas < 2:
        ap.error("--sla-spill needs --replicas >= 2 (router-level "
                 "spill has nowhere to go on one replica)")
    if args.catalog_size is not None and args.catalog_size < 1:
        ap.error(f"--catalog-size must be >= 1, got {args.catalog_size}")
    if args.retriever_k is not None and args.retriever_k < 1:
        ap.error(f"--retriever-k must be >= 1, got {args.retriever_k}")
    if args.catalog_size is not None and args.retriever_k is None:
        ap.error("--catalog-size needs --retriever-k: the launcher has "
                 "no intent gate, so a scaled catalog is only servable "
                 "through retrieved toolsets")
    if args.retriever_k is not None and args.replicas > 1:
        ap.error("--retriever-k applies to the single-engine prompt "
                 "path; cluster mode serves the synthetic intent "
                 "workload (examples/serve_pipeline.py runs retrieval "
                 "against a cluster)")
    return args


def main(argv=None):
    ap = build_parser()
    args = validate_args(ap, ap.parse_args(argv))

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.checkpoint:
        params = load_checkpoint(args.checkpoint, params)
    # no trained draft checkpoint ships with the repo: self-draft
    # (perfect agreement) stands in for a distilled small model
    spec = (SpecConfig(draft_cfg=cfg, draft_params=params,
                       k=args.draft_k)
            if args.spec_decode else None)

    if args.replicas > 1:
        serve_cluster(cfg, params, args, spec_decode=spec)
        return

    prompts = [
        f"Plot xview1 images around Tampa Bay with cloud cover below "
        f"{10 + i}%" for i in range(args.requests)]
    exposures = None
    if args.retriever_k is not None:
        from repro.core.catalog import (build_catalog,
                                        catalog_intent_libraries)
        from repro.core.retriever import ToolRetriever
        from repro.core.tools import DEFAULT_REGISTRY
        from repro.serving.tokenizer import TOKENIZER
        registry = (build_catalog(args.catalog_size, seed=0)
                    if args.catalog_size is not None
                    else DEFAULT_REGISTRY)
        retriever = ToolRetriever(registry,
                                  catalog_intent_libraries(registry),
                                  k=args.retriever_k)
        exposures = retriever.retrieve_batch(prompts,
                                             [None] * len(prompts))
        prefix_texts = {e.key_str: e.catalog_text(registry)
                        for e in exposures}
        # the cache must hold the widest toolset prefix + the turn;
        # grow it rather than refuse (register_prefix asserts the fit)
        need = max((len(TOKENIZER.encode(t)) + 1
                    for t in prefix_texts.values()), default=0)
        need += args.max_new + 128
        if args.cache_len < need:
            print(f"cache-len {args.cache_len} -> {need} "
                  f"(toolset prefixes need the room)")
            args.cache_len = need

    tracer = Tracer() if args.trace_out else None
    engine = InferenceEngine(cfg, params, max_batch=args.max_batch,
                             cache_len=args.cache_len,
                             backend=args.backend,
                             kv_mode=args.kv_mode,
                             kv_blocks=args.kv_blocks,
                             block_size=args.block_size,
                             spec_decode=spec,
                             prefill_budget=args.prefill_budget,
                             interleave=not args.no_interleave,
                             admission=args.admission,
                             tracer=tracer,
                             # the launcher is the wall-clock boundary:
                             # live latency numbers want real time
                             # (the engine binds it to the tracer too)
                             clock=time.time)
    t0 = time.time()
    if exposures is not None:
        for key, text in prefix_texts.items():
            engine.register_prefix(key, text)
        for p, exp in zip(prompts, exposures):
            engine.add_request(
                f"{prefix_texts[exp.key_str]}\nTask: {p}",
                max_new_tokens=args.max_new,
                sampler=SamplerConfig(
                    temperature=args.temperature, top_k=40),
                prefix_key=exp.key_str)
    else:
        for p in prompts:
            engine.add_request(p, max_new_tokens=args.max_new,
                               sampler=SamplerConfig(
                                   temperature=args.temperature,
                                   top_k=40))
    done = engine.run_until_done()
    dt = time.time() - t0
    st = engine.throughput_stats()
    print(f"served {len(done)} requests in {dt:.2f}s | "
          f"decode steps {st['decode_steps']} | "
          f"{st['tokens_generated'] / max(dt, 1e-9):.1f} tok/s")
    if exposures is not None:
        print(f"retrieval[k={args.retriever_k}, "
              f"catalog={len(registry.tools)} tools]: "
              f"{len(prefix_texts)} toolset prefixes for "
              f"{len(prompts)} requests | {st['prefix_hits']} prefix "
              f"hits, {st['prefix_tokens_saved']} prefill tokens saved")
    if spec is not None:
        print(f"spec-decode[k={spec.k}]: {st['tokens_per_step']:.2f} "
              f"tokens/target-forward, accept rate "
              f"{st['spec_accept_rate']:.2f} over "
              f"{st['spec_rounds']} rounds")
    print(f"kv[{st['kv_mode']}]: peak {st['kv_bytes_peak'] / 2**20:.1f} "
          f"MiB of {st['kv_bytes_allocated'] / 2**20:.1f} MiB allocated"
          + (f" | {st['preemptions']} preemptions"
             if st["kv_mode"] == "paged" else ""))
    lat = [r.finish_t - r.enqueue_t for r in done]
    ttft = [r.first_token_t - r.enqueue_t for r in done]
    print(f"p50 latency {sorted(lat)[len(lat)//2]*1000:.0f}ms | "
          f"p50 TTFT {sorted(ttft)[len(ttft)//2]*1000:.0f}ms")
    if tracer is not None:
        write_trace(tracer, args.trace_out)
        print(f"trace: {len(tracer.records)} records -> "
              f"{args.trace_out}")


if __name__ == "__main__":
    main()
