"""Static analysis of compiled HLO text: collective-traffic accounting.

``collective_stats(hlo_text)`` walks the computation graph (while-loop
bodies multiplied by their trip counts, call/fusion edges by 1) and sums
estimated per-chip bytes moved for every collective op:

  all-gather          out_bytes * (g-1)/g
  reduce-scatter      out_bytes * (g-1)
  all-reduce          2 * bytes * (g-1)/g
  all-to-all          bytes * (g-1)/g
  collective-permute  bytes

(g = replica-group size; ring-algorithm estimates, documented in
EXPERIMENTS.md §Roofline.)
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)[^{]*\{", re.M)


def _shape_bytes(stext: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(stext):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        if (stripped.endswith("{") and ("(" in stripped)
                and not stripped.startswith(("ROOT", "%param"))
                and re.match(r"^(ENTRY\s+)?%?[\w\.\-]+", stripped)
                and "=" not in stripped.split("(")[0]):
            name = stripped.split("(")[0].replace("ENTRY", "").strip()
            name = name.lstrip("%").strip()
            cur = name
            comps[cur] = []
            if "ENTRY" in stripped:
                comps["__entry__"] = comps[cur]
        elif stripped == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Scan-generated while conditions compare a counter to constant(R)."""
    best = 1
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def _callees(line: str) -> List[Tuple[str, str]]:
    out = []
    for key in ("condition", "body", "to_apply", "true_computation",
                "false_computation"):
        m = re.search(key + r"=%?([\w\.\-]+)", line)
        if m:
            out.append((key, m.group(1)))
    m = re.search(r"called_computations=\{([^}]*)\}", line)
    if m:
        for c in m.group(1).split(","):
            out.append(("call", c.strip().lstrip("%")))
    return out


def computation_multipliers(text: str) -> Tuple[Dict[str, List[str]],
                                                Dict[str, float]]:
    comps = _split_computations(text)
    mult: Dict[str, float] = defaultdict(float)
    entry = "__entry__"
    if entry not in comps:
        return comps, {k: 1.0 for k in comps}
    mult[entry] = 1.0
    # Topological-ish propagation: iterate until stable (graphs are shallow).
    for _ in range(32):
        changed = False
        for name, lines in comps.items():
            m_here = mult.get(name, 0.0)
            if m_here == 0.0:
                continue
            for ln in lines:
                for kind, callee in _callees(ln):
                    if callee not in comps or callee == name:
                        continue
                    factor = 1.0
                    if kind == "body":
                        # XLA annotates scan-derived while loops directly
                        kt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}',
                                       ln)
                        if kt:
                            trips = int(kt.group(1))
                        else:
                            cm = re.search(r"condition=%?([\w\.\-]+)", ln)
                            trips = _trip_count(comps.get(cm.group(1), [])) \
                                if cm else 1
                        factor = float(trips)
                    new = m_here * factor
                    if new > mult.get(callee, 0.0):
                        mult[callee] = new
                        changed = True
        if not changed:
            break
    return comps, dict(mult)


_SKIP_OPS = ("parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id",
             "get-dimension-size", "iota", "copy-start", "copy-done")


def _instr_op(line: str) -> str:
    # '%name = dtype[shape]{layout} opname(...), attrs'
    m = re.search(r"=\s+(?:\([^)]*\)|[\w\[\],{}\/]+)\s+([\w\-]+)\(", line)
    return m.group(1) if m else ""


def _out_shape_bytes(line: str) -> int:
    rhs = line.split("=", 1)
    if len(rhs) < 2:
        return 0
    head = rhs[1].strip()
    # take text up to the op name's '(' — covers tuple outputs too
    m = re.match(r"(\([^)]*\)|[\w\.\[\],{}]+)", head)
    return _shape_bytes(m.group(1)) if m else 0


def _operands(line: str) -> List[str]:
    m = re.search(r"\w+\(([^)]*)\)", line.split("=", 1)[-1])
    if not m:
        return []
    text = m.group(1)
    if "%" in text:
        # operand names are %-prefixed; robust to inline operand shapes
        # ('dot(f32[128,256]{1,0} %lhs, ...)' — the shape commas break a
        # naive comma split) in newer XLA text
        return re.findall(r"%([\w\.\-]+)", text)
    return [t.strip() for t in text.split(",") if re.match(r"^\w", t.strip())]


def _shape_table(lines: List[str]) -> Dict[str, str]:
    table = {}
    for ln in lines:
        m = re.match(r"(?:ROOT\s+)?%?([\w\.\-]+)\s+=\s+"
                     r"(\([^)]*\)|[\w\[\],{}\.]+)", ln)
        if m:
            table[m.group(1)] = m.group(2)
    return table


def _dims(stext: str) -> List[int]:
    m = _SHAPE_RE.search(stext)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _dot_flops(line: str, table: Dict[str, str]) -> float:
    out_dims = _dims(line.split("=", 1)[1])
    ops = _operands(line)
    if not ops:
        return 0.0
    lhs_shape = table.get(ops[0], "")
    lhs_dims = _dims(lhs_shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    k = 1
    if m and lhs_dims:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                k *= lhs_dims[int(d)]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * k


def hlo_profile(text: str, n_devices: int) -> Dict[str, float]:
    """Trip-count-scaled FLOPs and HBM-traffic model from optimized HLO.

    * flops: dot ops exactly (2*M*N*K * loop trips); every other top-level
      op contributes #output-elements (cheap elementwise estimate).
    * bytes: per top-level instruction, operand bytes + output bytes —
      i.e. fusions cost one read of inputs + one write of outputs, which is
      XLA's own fusion memory semantics. Scaled by loop trip counts.
    """
    comps, mult = computation_multipliers(text)
    # Computations reached via fusion/combiner edges are *inside* another
    # op's cost — skip them; only control-flow bodies are walked.
    fusion_called = set()
    for lines in comps.values():
        for ln in lines:
            for kind, callee in _callees(ln):
                if kind in ("to_apply", "call"):
                    fusion_called.add(callee)
    flops = 0.0
    bytes_accessed = 0.0
    dot_flops = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0 or name in fusion_called:
            continue
        if name != "__entry__" and lines is comps.get("__entry__"):
            continue   # alias of the entry computation — already counted
        table = _shape_table(lines)
        for ln in lines:
            op = _instr_op(ln)
            if not op or op in _SKIP_OPS:
                continue
            out_b = _out_shape_bytes(ln)
            if op == "dot":
                f = _dot_flops(ln, table) * m
                flops += f
                dot_flops += f
            else:
                # elementwise-ish estimate: one flop per output element
                flops += (out_b / 2.0) * m   # assume ~2-byte elements
            in_b = sum(_shape_bytes(table.get(o, "")) for o in _operands(ln))
            bytes_accessed += (out_b + in_b) * m
    return {"flops_scaled": flops, "dot_flops_scaled": dot_flops,
            "bytes_scaled": bytes_accessed}


def collective_stats(text: str, n_devices: int) -> Dict[str, float]:
    comps, mult = computation_multipliers(text)
    per_kind = defaultdict(float)
    count = defaultdict(int)
    for name, lines in comps.items():
        if name != "__entry__" and lines is comps.get("__entry__"):
            continue   # alias of the entry computation
        m = mult.get(name, 1.0) or 1.0
        for ln in lines:
            kind = next((c for c in _COLLECTIVES
                         if re.search(rf"\b{c}(-start|-done)?\(", ln)), None)
            if kind is None or f"{kind}-done(" in ln:
                continue
            lhs = ln.split(f" {kind}")[0]
            size = _shape_bytes(lhs)
            if size == 0:
                continue
            g = _group_size(ln, n_devices)
            if g <= 1:
                continue
            if kind == "all-gather":
                moved = size * (g - 1) / g
            elif kind == "reduce-scatter":
                moved = size * (g - 1)
            elif kind == "all-reduce":
                moved = 2 * size * (g - 1) / g
            elif kind == "all-to-all":
                moved = size * (g - 1) / g
            else:
                moved = size
            per_kind[kind] += moved * m
            count[kind] += 1
    total = sum(per_kind.values())
    out = {f"bytes_{k}": v for k, v in per_kind.items()}
    out.update({f"count_{k}": float(v) for k, v in count.items()})
    out["collective_bytes"] = total
    return out
