import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (device count locks on first init).

# Multi-pod dry-run: lower + compile every (architecture × input shape)
# on the production meshes, record memory/cost/collective analysis.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
#       --shape train_4k --mesh single
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
#
# Writes results/dryrun/<arch>__<shape>__<mesh>.json; EXPERIMENTS.md
# §Dry-run and benchmarks/roofline.py read these files.
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.configs import ARCH_IDS, get_config
from repro.distributed import sharding as shd
from repro.launch.hlo_stats import collective_stats, hlo_profile
from repro.launch.mesh import make_production_mesh
from repro.models import inputs as inp
from repro.models import model as mdl
from repro.training.loop import make_train_step
from repro.training.optimizer import adamw_init

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str:
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return ("full-attention architecture: long_500k requires "
                "sub-quadratic attention (DESIGN.md §Input-shape coverage)")
    return ""


def build_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    strategy: shd.ShardingStrategy):
    """Returns (fn, args_structs, in_shardings, out_shardings)."""
    batch = inp.batch_struct(cfg, shape)
    batch_sh = shd.batch_sharding(batch, cfg, shape, mesh, strategy)
    pshapes = mdl.param_shapes(cfg)
    params_sh = shd.params_sharding(pshapes, cfg, mesh, strategy)
    logits_sh = shd.logits_sharding(cfg, shape, mesh, strategy)
    repl = NamedSharding(mesh, P())

    if shape.mode == "train":
        opt_shapes = jax.eval_shape(adamw_init, pshapes)
        opt_sh = shd.opt_state_sharding(opt_shapes, pshapes, cfg, mesh,
                                        strategy)
        step = make_train_step(cfg)
        metrics_sh = {"loss": repl, "grad_norm": repl, "step": repl}
        return (step, (pshapes, opt_shapes, batch),
                (params_sh, opt_sh, batch_sh),
                (params_sh, opt_sh, metrics_sh))

    if shape.mode == "prefill":
        cache_len = (mdl.WHISPER_DEC_CACHE if cfg.family == "audio"
                     else shape.seq_len)
        enc_len = shape.seq_len if cfg.family == "audio" else 0

        seq_axis = (strategy.prefill_seq_axis
                    if strategy.prefill_seq_axis != "none" else None)

        def step(params, batch):
            return mdl.prefill(params, cfg, batch, cache_len=cache_len,
                               seq_axis=seq_axis)

        cache_shapes = jax.eval_shape(
            lambda: mdl.init_cache(cfg, shape.global_batch, cache_len,
                                   enc_len))
        cache_sh = shd.cache_sharding(cache_shapes, cfg, shape, mesh,
                                      strategy)
        return (step, (pshapes, batch), (params_sh, batch_sh),
                (logits_sh, cache_sh))

    # decode
    cache_shapes = inp.cache_struct(cfg, shape)
    cache_sh = shd.cache_sharding(cache_shapes, cfg, shape, mesh, strategy)

    def step(params, cache, batch):
        return mdl.decode_step(params, cfg, cache, batch)

    return (step, (pshapes, cache_shapes, batch),
            (params_sh, cache_sh, batch_sh), (logits_sh, cache_sh))


def parse_strategy(spec: str) -> shd.ShardingStrategy:
    """'prefill_seq_axis=model,fsdp=False' -> ShardingStrategy."""
    strategy = shd.ShardingStrategy()
    if not spec:
        return strategy
    kw = {}
    for kv in spec.split(","):
        k, v = (t.strip() for t in kv.split("="))
        cur = getattr(strategy, k)
        kw[k] = (v == "True") if isinstance(cur, bool) else type(cur)(v)
    return strategy.replace(**kw)


def run_one(arch: str, shape_name: str, mesh_name: str,
            strategy: shd.ShardingStrategy = None, save: bool = True,
            verbose: bool = True, perf: str = "", tag: str = ""):
    from repro.common.perf import PerfFlags, set_flags
    set_flags(PerfFlags().apply_overrides(perf))
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    strategy = strategy or shd.ShardingStrategy()
    reason = skip_reason(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "perf": perf, "tag": tag,
           "strategy": strategy.__dict__ if strategy else {}}
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        _save(rec, save)
        if verbose:
            print(f"SKIP {arch} × {shape_name} × {mesh_name}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_dev = mesh.devices.size
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh = build_lowerable(cfg, shape, mesh, strategy)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec["status"] = "ok"
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        rec["cost"] = {k: float(v) for k, v in dict(cost or {}).items()
                       if isinstance(v, (int, float))}
        hlo = compiled.as_text()
        rec["collectives"] = collective_stats(hlo, n_dev)
        rec["profile"] = hlo_profile(hlo, n_dev)
        rec["n_devices"] = n_dev
        if verbose:
            mb = rec["memory"].get("argument_size_in_bytes", 0) / 2**30
            tb = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
            fl = rec["profile"]["flops_scaled"]
            by = rec["profile"]["bytes_scaled"]
            cb = rec["collectives"]["collective_bytes"]
            print(f"OK   {arch} × {shape_name} × {mesh_name}: "
                  f"args={mb:.2f}GiB temp={tb:.2f}GiB flops={fl:.3e} "
                  f"hbm={by/2**30:.1f}GiB coll={cb/2**30:.2f}GiB "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"FAIL {arch} × {shape_name} × {mesh_name}: "
                  f"{rec['error'][:300]}")
    _save(rec, save)
    return rec


def _save(rec, save):
    if not save:
        return
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{rec['tag']}" if rec.get("tag") else ""
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--perf", default="",
                    help="perf-flag overrides, e.g. "
                         "'ssm_scan_chunk=128,moe_dispatch=gather'")
    ap.add_argument("--tag", default="",
                    help="suffix for the result file (perf iterations)")
    ap.add_argument("--strategy", default="",
                    help="ShardingStrategy overrides, e.g. "
                         "'prefill_seq_axis=model,fsdp=False'")
    ap.add_argument("--profile", default="baseline",
                    choices=["baseline", "prod"],
                    help="'prod' = tuned per-pair flags from "
                         "launch/profiles.py (explicit --perf/--strategy "
                         "are appended on top)")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = (list(INPUT_SHAPES) if (args.all or not args.shape)
              else [args.shape])
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            perf, strat_spec = args.perf, args.strategy
            if args.profile == "prod":
                from repro.launch.profiles import resolve
                base_perf, base_strat = resolve(arch, shape)
                perf = ",".join(s for s in (base_perf, args.perf) if s)
                strat_spec = ",".join(s for s in (base_strat,
                                                  args.strategy) if s)
            strategy = parse_strategy(strat_spec)
            for mesh in meshes:
                rec = run_one(arch, shape, mesh, strategy=strategy,
                              perf=perf, tag=args.tag)
                n_fail += rec["status"] == "error"
    print(f"dry-run complete; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
