"""Distributed training launcher.

On real hardware this binds the train step to the production mesh via the
same sharding rules the dry-run validates; on this CPU container use
``--local`` (1-device mesh) for end-to-end runs of the reduced configs.

  PYTHONPATH=src python -m repro.launch.train --arch planner-proxy-100m \
      --steps 200 --batch 8 --seq 256 --local
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.common.config import INPUT_SHAPES
from repro.configs import get_config, get_smoke_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.model import init_params
from repro.training.checkpoint import save_checkpoint
from repro.training.data import PackedLMDataset, synthetic_docs
from repro.training.loop import make_train_step
from repro.training.optimizer import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="planner-proxy-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--local", action="store_true",
                    help="1-device mesh (CPU container)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    mesh = (make_local_mesh() if args.local
            else make_production_mesh(multi_pod=args.multi_pod))
    strategy = shd.ShardingStrategy()

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params)
    params_sh = shd.params_sharding(params, cfg, mesh, strategy)
    opt_sh = shd.opt_state_sharding(opt_state, params, cfg, mesh, strategy)

    step_fn = make_train_step(cfg, lr=args.lr, remat=args.remat)
    with mesh:
        jit_step = jax.jit(step_fn, in_shardings=(params_sh, opt_sh, None),
                           out_shardings=(params_sh, opt_sh, None),
                           donate_argnums=(0, 1))
        data = PackedLMDataset(synthetic_docs(cfg.vocab_size), args.batch,
                               args.seq, cfg.vocab_size)
        t0 = time.time()
        for step in range(args.steps):
            batch = next(data)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                tput = (args.batch * args.seq * (step + 1)
                        / max(time.time() - t0, 1e-9))
                print(f"step {step:5d} loss {loss:.4f} "
                      f"tok/s {tput:,.0f}")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params)
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
