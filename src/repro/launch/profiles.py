"""Tuned performance profiles per (architecture × workload).

Codifies the EXPERIMENTS.md §Perf / §Prod-profile results as deployable
configurations: ``resolve(arch, shape)`` returns the (perf_spec,
strategy_spec) pair that won the hillclimb for that pair class, so
launchers and the dry-run can opt in with ``--profile prod`` instead of
hand-assembling flags.

Layering:
  1. BASE_PERF      — profile-wide winners, safe fleet-wide (all gated
                      internally on divisibility / seq-length / mesh).
  2. ARCH_PERF      — per-arch additions (MoE archs use the shard_map
                      expert-parallel dispatch).
  3. PAIR_OVERRIDES — per-(arch, shape) exceptions where the sweep showed
                      the base profile loses to GSPMD's own plan.
"""
from __future__ import annotations

from typing import Dict, Tuple

BASE_PERF = ("attn_constraint=auto,attn_chunk_remat=on,"
             "moe_constraint=auto,attn_window_slice=on,ssm_scan_chunk=4096")

ARCH_PERF: Dict[str, str] = {
    # shard_map expert-parallel dispatch: −58% bottleneck on kimi prefill,
    # −84% on kimi train (vs baseline); S=1 decode falls back internally.
    "kimi-k2-1t-a32b": "moe_dispatch=shard_map",
    "arctic-480b": "moe_dispatch=shard_map",
}

# (arch, shape) -> (perf_additions, strategy_spec)
PAIR_OVERRIDES: Dict[Tuple[str, str], Tuple[str, str]] = {
    # sequence-parallel prefill + wide q-chunks: 9.85 s -> 2.12 s
    ("gemma2-2b", "prefill_32k"): ("attn_chunk=4096",
                                   "prefill_seq_axis=model"),
    # 64-head wide models: GSPMD's seq-sharded-KV prefill beats the
    # q-head TP pin by ~8-10% — drop the attention constraint there.
    ("qwen1.5-110b", "prefill_32k"): ("attn_constraint=off", ""),
    ("qwen2-vl-72b", "prefill_32k"): ("attn_constraint=off", ""),
}


def resolve(arch: str, shape: str) -> Tuple[str, str]:
    """Return (perf_spec, strategy_spec) for a pair under the prod profile.

    Later fragments win inside PerfFlags.apply_overrides, so pair-level
    overrides are appended last.
    """
    perf = BASE_PERF
    if arch in ARCH_PERF:
        perf += "," + ARCH_PERF[arch]
    strategy = ""
    if (arch, shape) in PAIR_OVERRIDES:
        extra, strategy = PAIR_OVERRIDES[(arch, shape)]
        if extra:
            perf += "," + extra
    return perf, strategy
