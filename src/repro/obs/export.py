"""Trace export: Chrome trace-event JSON (Perfetto-loadable) + JSONL.

The Chrome format (the ``chrome://tracing`` / Perfetto "JSON trace
event" schema) maps our tracks onto its process/thread axes:

  * record ``group``  -> ``pid`` (one Perfetto *process* per engine
    replica, plus string groups like ``"pipeline"``);
  * record ``lane``   -> ``tid`` (one *thread* per slot, plus the
    reserved ``queue`` / ``engine`` / ``kv`` lanes);
  * record ``tick``   -> ``ts`` in microseconds, spread by the
    within-tick ordinal so same-tick records keep their sequence order
    on the timeline (1 tick = 1000 "us"; ticks are logical time).

"M" metadata events name every process and thread. Serialization is
deterministic — events in seq order, ``sort_keys`` JSON, fixed
separators — so a same-seed run exports byte-identical files
(tests/test_obs.py and the traced cluster bench assert it).

``validate_chrome_trace`` is the schema checker the CI traced-bench
step runs (benchmarks/check_trace.py): phase vocabulary, required
fields, per-track B/E stack discipline, global ts monotonicity and
complete track naming.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Tuple, Union

from repro.obs.tracer import Label, TraceRecord, Tracer

# logical microseconds per engine tick on the Chrome timeline
TICK_US = 1000


def _label_key(v: Label) -> Tuple[int, str]:
    """Deterministic ordering over mixed int/str labels: numeric
    groups (replicas) first in numeric order, then strings."""
    return (0, f"{v:020d}") if isinstance(v, int) else (1, str(v))


def _label_name(kind: str, v: Label) -> str:
    return f"{kind} {v}" if isinstance(v, int) else str(v)


def _track_ids(records: Sequence[TraceRecord]
               ) -> Tuple[Dict[Label, int], Dict[Tuple[Label, Label], int]]:
    """Assign pids to groups and tids to (group, lane), sorted — ids
    are a pure function of the label set, not of arrival order."""
    groups = sorted({r.group for r in records}, key=_label_key)
    pids = {g: i for i, g in enumerate(groups)}
    tids: Dict[Tuple[Label, Label], int] = {}
    for g in groups:
        lanes = sorted({r.lane for r in records if r.group == g},
                       key=_label_key)
        for j, lane in enumerate(lanes):
            tids[(g, lane)] = j
    return pids, tids


def chrome_trace(records_or_tracer: Union[Tracer, Iterable[TraceRecord]]
                 ) -> Dict:
    """Build the Chrome trace-event document (a JSON-ready dict)."""
    records = (records_or_tracer.records
               if isinstance(records_or_tracer, Tracer)
               else tuple(records_or_tracer))
    pids, tids = _track_ids(records)
    events: List[Dict] = []
    for g, pid in sorted(pids.items(), key=lambda kv: kv[1]):
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0,
                       "args": {"name": _label_name("replica", g)}})
    for (g, lane), tid in sorted(tids.items(),
                                 key=lambda kv: (pids[kv[0][0]], kv[1])):
        events.append({"ph": "M", "name": "thread_name",
                       "pid": pids[g], "tid": tid,
                       "args": {"name": _label_name("slot", lane)}})
    ordinal: Dict[int, int] = {}          # tick -> events seen
    for r in records:                     # seq order by construction
        k = ordinal.get(r.tick, 0)
        ordinal[r.tick] = k + 1
        args = dict(r.args)
        args["seq"] = r.seq
        if r.wall is not None:
            args["wall"] = r.wall
        ev = {"ph": r.ph, "name": r.name, "pid": pids[r.group],
              "tid": tids[(r.group, r.lane)],
              "ts": r.tick * TICK_US + min(k, TICK_US - 1),
              "args": args}
        if r.ph == "i":
            ev["s"] = "t"                 # thread-scoped instant
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"tick_us": TICK_US}}


def _dumps(obj) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def dump_chrome_trace(tracer: Tracer, path) -> Path:
    """Write the Perfetto-loadable JSON; returns the path."""
    path = Path(path)
    path.write_text(_dumps(chrome_trace(tracer)) + "\n")
    return path


def jsonl_lines(records_or_tracer: Union[Tracer, Iterable[TraceRecord]]
                ) -> List[str]:
    """One compact JSON object per record, seq order, key-sorted."""
    records = (records_or_tracer.records
               if isinstance(records_or_tracer, Tracer)
               else tuple(records_or_tracer))
    lines = []
    for r in records:
        d = {"seq": r.seq, "ph": r.ph, "name": r.name, "tick": r.tick,
             "group": r.group, "lane": r.lane, "args": dict(r.args)}
        if r.wall is not None:
            d["wall"] = r.wall
        lines.append(_dumps(d))
    return lines


def dump_jsonl(tracer: Tracer, path) -> Path:
    path = Path(path)
    path.write_text("\n".join(jsonl_lines(tracer)) + "\n")
    return path


def write_trace(tracer: Tracer, path) -> Path:
    """``--trace-out`` dispatch: ``.jsonl`` writes the event log, any
    other suffix the Chrome trace JSON."""
    path = Path(path)
    if path.suffix == ".jsonl":
        return dump_jsonl(tracer, path)
    return dump_chrome_trace(tracer, path)


_PHASES = {"B", "E", "i", "M", "X"}


def validate_chrome_trace(doc: Dict) -> List[str]:
    """Schema/well-formedness errors in a Chrome trace document
    (empty list = valid). Checks the invariants our exporter promises,
    which are also what Perfetto needs to build the track tree."""
    errors: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document has no traceEvents array"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    named_procs, named_threads = set(), set()
    stacks: Dict[Tuple[int, int], List[str]] = {}
    last_ts: Dict[Tuple[int, int], float] = {}
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: bad phase {ph!r}")
            continue
        if not isinstance(ev.get("pid"), int) \
                or not isinstance(ev.get("tid"), int):
            errors.append(f"{where}: pid/tid must be ints")
            continue
        key = (ev["pid"], ev["tid"])
        if ph == "M":
            if ev.get("name") == "process_name":
                named_procs.add(ev["pid"])
            elif ev.get("name") == "thread_name":
                named_threads.add(key)
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errors.append(f"{where}: missing event name")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where}: bad ts {ts!r}")
            continue
        if ts < last_ts.get(key, 0):
            errors.append(f"{where}: ts {ts} decreases on track {key}")
        last_ts[key] = ts
        if ph == "B":
            stacks.setdefault(key, []).append(ev["name"])
        elif ph == "E":
            stack = stacks.get(key, [])
            if not stack:
                errors.append(f"{where}: E with no open B on {key}")
            elif stack[-1] != ev["name"]:
                errors.append(f"{where}: E {ev['name']!r} closes "
                              f"B {stack[-1]!r} on {key}")
                stack.pop()
            else:
                stack.pop()
    for key, stack in sorted(stacks.items()):
        if stack:
            errors.append(f"track {key}: unclosed spans {stack}")
    for pid in sorted({e["pid"] for e in events
                       if isinstance(e, dict)
                       and isinstance(e.get("pid"), int)}):
        if pid not in named_procs:
            errors.append(f"pid {pid} has no process_name metadata")
    for key in sorted(last_ts):
        if key not in named_threads:
            errors.append(f"track {key} has no thread_name metadata")
    return errors


def load_and_validate(path) -> Tuple[Dict, List[str]]:
    """Round-trip helper: parse the file and validate (the Perfetto
    round-trip test and the CI checker share this)."""
    doc = json.loads(Path(path).read_text())
    return doc, validate_chrome_trace(doc)
