"""Deterministic observability: tick-stamped tracing + one metrics
registry (DESIGN.md §Observability).

``tracer.py``  — span/event records stamped with the engine's tick
clock and a global monotone sequence number; no wall clock unless a
clock is injected, so same-seed runs produce byte-identical traces.
``metrics.py`` — typed counter/gauge/histogram registry the serving
subsystems publish into; the legacy ``stats``/``summary()`` surfaces
are views over it.
``export.py``  — Chrome trace-event (Perfetto-loadable) JSON and
compact JSONL export, wired into ``--trace-out``.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,
                               MetricsRegistry, StatsView, percentile)
from repro.obs.tracer import NULL_TRACER, NullTracer, Tracer, TraceRecord

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "StatsView", "percentile", "NULL_TRACER", "NullTracer",
           "Tracer", "TraceRecord"]
