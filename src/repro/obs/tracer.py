"""Tick-stamped, monotonically-sequenced trace recording.

A ``Tracer`` collects ``TraceRecord``s — begin/end span markers and
instant events — stamped with the *engine tick* (``step_no`` / the
cluster's lockstep tick), never the wall clock. Each record also
carries a process-global-free, tracer-local sequence number that is
strictly increasing, so within-tick ordering is total and a trace is a
pure function of the run: same seed ⇒ byte-identical records
(tests/test_obs.py asserts this through both exporters).

Wall time is opt-in: the engine's injected ``clock=`` is bound onto
the tracer (``bind_clock``) only when a caller actually injects one
(the live-serve launcher). Records then carry a ``wall`` field and the
byte-identity guarantee is intentionally waived — determinism contracts
stay with the tick stamps.

Tracks: every record names a ``(group, lane)`` pair — the engine uses
``(replica_index, slot)`` with the reserved lanes ``"queue"`` /
``"engine"`` / ``"kv"``, the pipeline ``("pipeline", stage)``. The
Chrome exporter (obs/export.py) maps groups to Perfetto processes and
lanes to threads.

``NullTracer`` is the zero-overhead default: every method is a no-op
and ``enabled`` is False so hot loops can skip building event args
entirely. Tracing on vs off never branches engine control flow, which
is why tokens are bitwise identical either way.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

Label = Union[int, str]


@dataclass(frozen=True)
class TraceRecord:
    """One trace entry. ``ph`` follows the Chrome trace-event phases:
    "B" (span begin), "E" (span end), "i" (instant). ``args`` is a
    key-sorted tuple of pairs so serialization is deterministic."""
    seq: int
    ph: str
    name: str
    tick: int
    group: Label
    lane: Label
    args: Tuple[Tuple[str, Any], ...] = ()
    wall: Optional[float] = None


class NullTracer:
    """Disabled tracer: no records, no state, no overhead. The engine
    default — guaranteed not to perturb anything (the tracer-on/off
    token-parity test rests on tracing never branching control flow)."""

    enabled = False
    records: Tuple[TraceRecord, ...] = ()

    def bind_clock(self, clock: Optional[Callable[[], float]]) -> None:
        pass

    def event(self, name: str, *, tick: int, group: Label = 0,
              lane: Label = 0, **args) -> int:
        return -1

    def begin(self, name: str, *, tick: int, group: Label = 0,
              lane: Label = 0, **args) -> int:
        return -1

    def end(self, handle: int, *, tick: int, **args) -> None:
        pass

    def open_spans(self) -> List[TraceRecord]:
        return []


NULL_TRACER = NullTracer()


class Tracer:
    """Recording tracer. All stamps are caller-supplied ticks; ``seq``
    is assigned here and is strictly increasing across every record."""

    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self._records: List[TraceRecord] = []
        self._seq = 0
        self._clock = clock
        # handle (the begin record's seq) -> its begin record, for the
        # matching "E" and for open-span introspection
        self._open: Dict[int, TraceRecord] = {}

    # ------------------------------------------------------- recording ----
    def bind_clock(self, clock: Optional[Callable[[], float]]) -> None:
        """Attach an injected wall clock. Only callers that hold a real
        clock (launch/) bind one; the deterministic zero-clock engines
        never do, keeping traces wall-free and byte-stable."""
        if clock is not None:
            self._clock = clock

    def _push(self, ph: str, name: str, tick: int, group: Label,
              lane: Label, args: Dict[str, Any]) -> TraceRecord:
        rec = TraceRecord(
            seq=self._seq, ph=ph, name=name, tick=tick, group=group,
            lane=lane, args=tuple(sorted(args.items())),
            wall=self._clock() if self._clock is not None else None)
        self._seq += 1
        self._records.append(rec)
        return rec

    def event(self, name: str, *, tick: int, group: Label = 0,
              lane: Label = 0, **args) -> int:
        """Record an instant event; returns its seq."""
        return self._push("i", name, tick, group, lane, args).seq

    def begin(self, name: str, *, tick: int, group: Label = 0,
              lane: Label = 0, **args) -> int:
        """Open a span; returns a handle to pass to ``end``."""
        rec = self._push("B", name, tick, group, lane, args)
        self._open[rec.seq] = rec
        return rec.seq

    def end(self, handle: int, *, tick: int, **args) -> None:
        """Close the span opened under ``handle``. The end record
        reuses the begin's (name, group, lane) so exporters can pair
        them without bookkeeping."""
        b = self._open.pop(handle)
        if tick < b.tick:
            raise ValueError(f"span {b.name!r} ends at tick {tick} "
                             f"before its begin tick {b.tick}")
        self._push("E", b.name, tick, b.group, b.lane, args)

    # --------------------------------------------------- introspection ----
    @property
    def records(self) -> Tuple[TraceRecord, ...]:
        return tuple(self._records)

    def open_spans(self) -> List[TraceRecord]:
        """Begin records with no matching end yet (a drained run should
        report none — the well-formedness tests assert it)."""
        return sorted(self._open.values(), key=lambda r: r.seq)

    def lane_of(self, handle: int) -> Optional[Label]:
        """Lane of a still-open span (engine helpers stamp follow-up
        instant events onto the request's own track)."""
        rec = self._open.get(handle)
        return rec.lane if rec is not None else None

    def clear(self) -> None:
        self._records.clear()
        self._open.clear()
        self._seq = 0
