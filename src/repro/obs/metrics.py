"""Typed metrics registry: counters, gauges, histograms.

One ``MetricsRegistry`` backs every serving subsystem's counters —
the engine's ``stats`` mapping, the queue/pool/spec publishers and the
pipeline's ``PipelineStats`` are all *views* over registry metrics, so
a run has exactly one place its numbers live (DESIGN.md §Observability
maps each legacy stats key to its registry metric).

Conventions:

  * metrics are identified by ``(kind, name, labels)``; ``labeled()``
    returns a facade that injects fixed labels (the cluster scopes each
    replica's metrics with ``replica=i``) and whose ``reset()`` zeroes
    only the metrics created through it;
  * empty histograms report ``None`` from ``percentile()``/``mean()``
    — never 0.0 (PR 8's empty-percentile convention; renderers print
    "n/a");
  * ``snapshot()`` is deterministic: keys sorted, values plain Python.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


def percentile(values: Sequence[float], q: float) -> Optional[float]:
    """Percentile of ``values``, or None for an empty series — 0.0
    would read as a perfect latency for a run that finished nothing
    (the single shared implementation behind every percentile the
    serving stack reports)."""
    vals = list(values)
    return float(np.percentile(np.asarray(vals), q)) if vals else None


class Counter:
    """Monotone-by-convention integer counter (views may assign it
    directly — the engine's ``stats[k] = v`` compatibility path)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def zero(self) -> None:
        self.value = 0


class Gauge:
    """Last-write-wins scalar with a ``max`` helper for peaks."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def max(self, v) -> None:
        if v > self.value:
            self.value = v

    def zero(self) -> None:
        self.value = 0


class Histogram:
    """Exact-sample histogram (serving runs observe thousands of
    points, not millions — keeping the samples makes percentiles exact
    and the registry the single source the summaries read)."""

    __slots__ = ("name", "labels", "values")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.values: List[float] = []

    def observe(self, v) -> None:
        self.values.append(v)

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def total(self) -> float:
        return sum(self.values)

    def mean(self) -> Optional[float]:
        return (self.total / len(self.values)) if self.values else None

    def percentile(self, q: float) -> Optional[float]:
        return percentile(self.values, q)

    def zero(self) -> None:
        self.values = []


class MetricsRegistry:
    """The store. ``counter``/``gauge``/``histogram`` are get-or-create
    (idempotent — a view and a publisher naming the same metric share
    one object)."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, str, Tuple[Tuple[str, str], ...]],
                            object] = {}

    def _get(self, kind: str, cls, name: str, labels: Dict[str, str]):
        key = (kind, name, tuple(sorted((k, str(v))
                                        for k, v in labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, key[2])
            self._metrics[key] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", Histogram, name, labels)

    def labeled(self, **labels) -> "LabeledRegistry":
        """Facade that stamps ``labels`` onto every metric created
        through it (cluster replicas share one store, scoped per
        replica) and whose reset() touches only its own metrics."""
        return LabeledRegistry(self, labels)

    def reset(self) -> None:
        """Zero every metric (engine/cluster reset; histograms drop
        their samples)."""
        for m in self._metrics.values():
            m.zero()

    def snapshot(self) -> Dict[str, Dict]:
        """Deterministic dump: ``kind -> "name{labels}" -> value``.
        Histograms render count/total and the standard percentiles —
        ``None`` when empty, never 0.0."""
        out: Dict[str, Dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for (kind, name, labels), m in sorted(
                self._metrics.items(), key=lambda kv: kv[0]):
            label_s = ",".join(f"{k}={v}" for k, v in labels)
            full = f"{name}{{{label_s}}}" if label_s else name
            if kind == "counter":
                out["counters"][full] = m.value
            elif kind == "gauge":
                out["gauges"][full] = m.value
            else:
                out["histograms"][full] = {
                    "count": m.count, "total": m.total,
                    "mean": m.mean(),
                    "p50": m.percentile(50), "p95": m.percentile(95),
                    "p99": m.percentile(99)}
        return out


class LabeledRegistry:
    """Label-injecting facade over a shared ``MetricsRegistry``."""

    def __init__(self, root: MetricsRegistry, labels: Dict[str, str]):
        self._root = root
        self._labels = dict(labels)
        self._mine: List[object] = []

    def _track(self, m):
        if m not in self._mine:
            self._mine.append(m)
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._track(self._root.counter(
            name, **{**self._labels, **labels}))

    def gauge(self, name: str, **labels) -> Gauge:
        return self._track(self._root.gauge(
            name, **{**self._labels, **labels}))

    def histogram(self, name: str, **labels) -> Histogram:
        return self._track(self._root.histogram(
            name, **{**self._labels, **labels}))

    def labeled(self, **labels) -> "LabeledRegistry":
        return LabeledRegistry(self._root, {**self._labels, **labels})

    def reset(self) -> None:
        """Zero only this facade's metrics — one replica's reset must
        not clear its siblings' slices of the shared store."""
        for m in self._mine:
            m.zero()

    def snapshot(self) -> Dict[str, Dict]:
        return self._root.snapshot()


class StatsView:
    """Dict-compatible view over a fixed family of registry counters.

    Replaces the engine's ad-hoc ``self.stats`` dict: same mapping
    surface (``stats[k] += 1``, ``dict(stats)``, ``{**stats}``,
    ``.keys()``/``.items()``), but the numbers live in the registry.
    Key order is the declaration order, matching the dict it
    replaced."""

    def __init__(self, registry, keys: Sequence[str], prefix: str = ""):
        self._registry = registry
        self._prefix = prefix
        self._counters: Dict[str, Counter] = {
            k: registry.counter(prefix + k) for k in keys}

    def __getitem__(self, key: str) -> int:
        return self._counters[key].value

    def __setitem__(self, key: str, value: int) -> None:
        if key not in self._counters:
            # late-declared counters still join the view (and therefore
            # its reset sweep) — nothing can accumulate outside it
            self._counters[key] = self._registry.counter(
                self._prefix + key)
        self._counters[key].value = value

    def __contains__(self, key: str) -> bool:
        return key in self._counters

    def __iter__(self) -> Iterator[str]:
        return iter(self._counters)

    def __len__(self) -> int:
        return len(self._counters)

    def keys(self):
        return self._counters.keys()

    def values(self):
        return [c.value for c in self._counters.values()]

    def items(self):
        return [(k, c.value) for k, c in self._counters.items()]

    def get(self, key: str, default=None):
        c = self._counters.get(key)
        return default if c is None else c.value

    def __eq__(self, other) -> bool:
        if isinstance(other, StatsView):
            return self.items() == other.items()
        if isinstance(other, dict):
            return dict(self.items()) == other
        return NotImplemented

    def __repr__(self) -> str:
        return f"StatsView({dict(self.items())!r})"

    def reset(self) -> None:
        for c in self._counters.values():
            c.zero()
