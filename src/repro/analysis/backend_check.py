"""RL301–RL303: kernel backend registry checker.

`kernels/backend.py` promises that every op dispatches identically
through the ``reference`` and ``pallas`` backends — model call sites
pass the declared :data:`repro.kernels.backend.OP_SURFACE` arguments
and expect either implementation to accept them. Registration already
enforces signatures at import time (``BackendContractError``); this
checker re-runs the same contract under lint so CI reports *which* op
drifted even when an import-time failure is being bisected, and adds
registry-completeness checks imports alone cannot see:

  * RL301 — a registered implementation whose Python signature cannot
    serve the declared op surface (checked via
    ``backend.check_op_signature``);
  * RL302 — a kernel module in ``repro/kernels/`` that backend.py
    never imports: the kernel exists but no backend can reach it;
  * RL303 — a required backend name missing from the registry, or a
    registered backend missing an op implementation.

The signature checks import the live registry (the analyzer runs in
the repo's own environment); RL302 is static over backend.py's import
statements so it works on any checkout.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List

from repro.analysis.findings import Finding, make_finding

#: backends every checkout must register
REQUIRED_BACKENDS = ("reference", "pallas")

#: kernels/ modules that are not kernel implementations
_NON_KERNEL_MODULES = {"__init__", "ref", "backend"}


def analyze_backend_registry(kernels_dir: Path) -> List[Finding]:
    findings: List[Finding] = []
    backend_py = kernels_dir / "backend.py"
    rel = backend_py

    # ---- RL302: every kernel module is imported by backend.py --------
    imported: set = set()
    line_of_imports = 1
    if backend_py.exists():
        tree = ast.parse(backend_py.read_text())
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                parts = node.module.split(".")
                if "kernels" in parts:
                    imported.add(parts[-1])
                    line_of_imports = node.lineno
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    parts = alias.name.split(".")
                    if "kernels" in parts:
                        imported.add(parts[-1])
    for mod in sorted(p.stem for p in kernels_dir.glob("*.py")):
        if mod in _NON_KERNEL_MODULES:
            continue
        if mod not in imported:
            findings.append(make_finding(
                "RL302", rel, line_of_imports,
                f"kernel module {mod!r} is not imported by the backend "
                f"registry",
                "wire it into a KernelBackend (or fold it into ref.py "
                "if it is an oracle)"))

    # ---- RL301/RL303: live registry introspection --------------------
    try:
        from repro.kernels import backend as KB
    except Exception as e:          # import raises on contract errors
        findings.append(make_finding(
            "RL303", rel, 1,
            f"kernel backend registry failed to import: {e}",
            "fix the registration error; see BackendContractError"))
        return findings

    registered = KB.available_backends()
    for name in REQUIRED_BACKENDS:
        if name not in registered:
            findings.append(make_finding(
                "RL303", rel, 1,
                f"required backend {name!r} is not registered "
                f"(have {registered})",
                "register_backend(KernelBackend(name=...))"))
    for name in registered:
        be = KB.get_backend(name)
        for op, defect in sorted(KB.validate_backend(be).items()):
            rule = "RL303" if "not implemented" in defect else "RL301"
            findings.append(make_finding(
                rule, rel, 1,
                f"backend {name!r} op {op!r}: {defect}",
                f"align the implementation with OP_SURFACE[{op!r}]"))
    return findings
