"""CLI for the static-analysis suite.

    python -m repro.analysis                       # full repo sweep
    python -m repro.analysis --fail-on error       # CI gate (default)
    python -m repro.analysis --json report.json    # machine-readable
    python -m repro.analysis --format github       # PR annotations
    python -m repro.analysis path/to/file.py ...   # explicit scope
    python -m repro.analysis --write-baseline      # accept current set

Exit status: 1 when any unsuppressed finding at or above ``--fail-on``
severity remains, else 0. ``--fail-on never`` always exits 0 (report-
only mode).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis import findings as F
from repro.analysis.runner import (BASELINE_NAME, repo_root, run_paths,
                                   run_repo)


def _github_line(f: F.Finding) -> str:
    level = "error" if f.severity == "error" else "warning"
    msg = f"{f.rule}: {f.message}" + (f" ({f.hint})" if f.hint else "")
    return (f"::{level} file={f.path},line={f.line},"
            f"title={f.rule}::{msg}")


def render(findings: Sequence[F.Finding], fmt: str) -> str:
    lines: List[str] = []
    if fmt == "github":
        lines = [_github_line(f) for f in findings if not f.suppressed]
    else:
        lines = [f.render() for f in findings]
    n_err = sum(1 for f in findings
                if not f.suppressed and f.severity == "error")
    n_warn = sum(1 for f in findings
                 if not f.suppressed and f.severity == "warning")
    n_sup = sum(1 for f in findings if f.suppressed)
    lines.append(f"{n_err} error(s), {n_warn} warning(s), "
                 f"{n_sup} suppressed")
    return "\n".join(lines)


def report_json(findings: Sequence[F.Finding]) -> dict:
    return {
        "findings": [f.to_json() for f in findings],
        "summary": {
            "errors": sum(1 for f in findings
                          if not f.suppressed and f.severity == "error"),
            "warnings": sum(1 for f in findings if not f.suppressed
                            and f.severity == "warning"),
            "suppressed": sum(1 for f in findings if f.suppressed),
            "rules": sorted({f.rule for f in findings}),
        },
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="determinism & hazard static analysis "
                    "(rule catalog: DESIGN.md §Static analysis)")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to analyze (default: full repo "
                         "sweep incl. registry checks)")
    ap.add_argument("--fail-on", choices=("error", "warning", "never"),
                    default="error",
                    help="lowest severity that fails the run "
                         "(default: error)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the full report as JSON")
    ap.add_argument("--format", choices=("text", "github"),
                    default="text",
                    help="stdout format (github = PR annotations)")
    ap.add_argument("--baseline", metavar="PATH",
                    help=f"accepted-findings file "
                         f"(default: <repo>/{BASELINE_NAME})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write all current unsuppressed findings to "
                         "the baseline file and exit 0")
    args = ap.parse_args(argv)

    root = repo_root()
    baseline = Path(args.baseline) if args.baseline else None
    if args.paths:
        findings = run_paths([Path(p) for p in args.paths], root=root,
                             baseline=baseline)
    else:
        findings = run_repo(root=root, baseline=baseline)

    if args.write_baseline:
        target = baseline or root / BASELINE_NAME
        F.write_baseline(target, findings)
        print(f"wrote {target} "
              f"({sum(1 for f in findings if not f.suppressed)} entries)")
        return 0

    print(render(findings, args.format))
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report_json(findings), indent=2)
                       + "\n")

    if args.fail_on == "never":
        return 0
    return 1 if F.active(findings, args.fail_on) else 0


if __name__ == "__main__":          # pragma: no cover
    sys.exit(main())
