"""Orchestration: which analyzer runs where, suppression/baseline
application, and report assembly for the CLI.

Scopes (relative to the repo root, auto-detected from this package's
location unless overridden):

  * effects race detector  — ``src/repro/env/tools_impl.py`` (diffed
    against the live tool registry);
  * determinism lint       — ``src/repro/{core,serving,env,kernels}``
    under the full RL101–RL105 battery; every other ``src/repro``
    package gets the RL106 injected-clock boundary rule only, except
    the clock providers ``obs/`` and ``launch/``
    (``determinism.wallclock_scope`` is the dispatcher;
    ``benchmarks/`` and tests stay out of scope);
  * kernel contracts       — ``src/repro/kernels/*.py`` except
    ``ref.py``/``backend.py`` (jnp oracles are not Pallas kernels);
  * backend registry       — ``src/repro/kernels/`` as a unit.

``run_repo`` is the one entry the CLI and tests share; ``run_paths``
analyzes an explicit file/dir list (fixture corpora) with the same
rule engine but no repo-wide registry coupling.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

from repro.analysis import findings as F
from repro.analysis.backend_check import analyze_backend_registry
from repro.analysis.determinism import (analyze_clock_boundary,
                                        analyze_determinism,
                                        wallclock_scope)
from repro.analysis.effects_check import analyze_effects
from repro.analysis.kernel_contracts import analyze_kernels

DETERMINISM_DIRS = ("core", "serving", "env", "kernels")
#: RL106-only scope: everything else under src/repro except the
#: allowlisted clock providers (obs/, launch/)
BOUNDARY_DIRS = ("analysis", "common", "configs", "distributed",
                 "models", "training")
BASELINE_NAME = "analysis_baseline.json"


def repo_root() -> Path:
    """…/src/repro/analysis/runner.py -> the repo checkout root."""
    return Path(__file__).resolve().parents[3]


def _rel(path: Path, root: Path) -> str:
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def _is_kernel_impl(path: Path, source: str) -> bool:
    return (path.stem not in ("__init__", "ref", "backend")
            and "pallas_call" in source)


def analyze_file(path: Path, root: Path,
                 registry_names: Optional[Sequence[str]] = None
                 ) -> List[F.Finding]:
    """Every applicable single-file analyzer over one source file."""
    source = path.read_text()
    rel = _rel(path, root)
    out: List[F.Finding] = []
    scope = wallclock_scope(rel)
    if scope == "full":
        out.extend(analyze_determinism(Path(rel), source))
    elif scope == "boundary":
        out.extend(analyze_clock_boundary(Path(rel), source))
    # "allow": the clock providers get no determinism-family lint
    has_effects_table = any(ln.startswith("TOOL_EFFECTS")
                            for ln in source.splitlines())
    if path.name == "tools_impl.py" or has_effects_table:
        out.extend(analyze_effects(Path(rel), source,
                                   registry_names=registry_names))
    # generated-catalog pass: the family-keyed dispatch must cover the
    # CATALOG_FAMILY_EFFECTS table (and vice versa) so growing the
    # catalog can't open an effects coverage gap
    if any(ln.startswith("CATALOG_FAMILY_EFFECTS")
           for ln in source.splitlines()):
        try:
            from repro.core.catalog import FAMILY_NAMES
            family_names: Optional[Sequence[str]] = FAMILY_NAMES
        except Exception:
            family_names = None
        out.extend(analyze_effects(Path(rel), source,
                                   registry_names=family_names,
                                   table_name="CATALOG_FAMILY_EFFECTS",
                                   name_param="family"))
    if _is_kernel_impl(path, source):
        out.extend(analyze_kernels(Path(rel), source))
    return out


def _suppress(findings: List[F.Finding], root: Path) -> List[F.Finding]:
    sources: Dict[str, str] = {}
    for f in findings:
        p = root / f.path
        if f.path not in sources and p.exists():
            sources[f.path] = p.read_text()
    return F.apply_suppressions(findings, sources)


def run_paths(paths: Iterable[Path], root: Optional[Path] = None,
              baseline: Optional[Path] = None) -> List[F.Finding]:
    """Analyze an explicit list of files/dirs (no registry coupling)."""
    root = root or repo_root()
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    findings: List[F.Finding] = []
    for f in files:
        findings.extend(analyze_file(f, root))
    findings = _suppress(findings, root)
    if baseline is not None:
        findings = F.apply_baseline(findings, F.load_baseline(baseline))
    findings.sort(key=lambda x: (x.path, x.line, x.rule))
    return findings


def run_repo(root: Optional[Path] = None,
             baseline: Optional[Path] = None) -> List[F.Finding]:
    """The full four-analyzer sweep the CI gate runs."""
    root = root or repo_root()
    pkg = root / "src" / "repro"
    findings: List[F.Finding] = []

    try:
        from repro.core.tools import DEFAULT_REGISTRY
        registry_names: Optional[List[str]] = DEFAULT_REGISTRY.names()
    except Exception:
        registry_names = None

    for d in DETERMINISM_DIRS + BOUNDARY_DIRS:
        for f in sorted((pkg / d).rglob("*.py")):
            findings.extend(analyze_file(f, root,
                                         registry_names=registry_names))

    kfinds = analyze_backend_registry(pkg / "kernels")
    for f in kfinds:
        findings.append(F.Finding(f.rule, _rel(Path(f.path), root),
                                  f.line, f.message, f.hint))

    findings = _suppress(findings, root)
    bl = baseline if baseline is not None else root / BASELINE_NAME
    findings = F.apply_baseline(findings, F.load_baseline(bl))
    findings.sort(key=lambda x: (x.path, x.line, x.rule))
    return findings
