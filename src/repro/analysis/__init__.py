"""Determinism & hazard static-analysis suite (``python -m
repro.analysis``).

Four analyzers over one shared finding model (DESIGN.md §Static
analysis has the rule catalog):

  * RL0xx effects race detector   — tool handlers vs TOOL_EFFECTS;
  * RL1xx determinism lint        — wall-clock/stdlib-random/environ/
    unordered-set/float-key hygiene in core, serving, env, kernels;
  * RL2xx kernel contract checker — Pallas grid/BlockSpec/scalar-
    prefetch/fp32-accumulator conventions;
  * RL3xx backend registry checker — reference/pallas op parity.
"""
from repro.analysis.findings import (Finding, RULES, active,
                                     make_finding)
from repro.analysis.runner import run_paths, run_repo

__all__ = ["Finding", "RULES", "active", "make_finding", "run_paths",
           "run_repo"]
