"""RL001–RL005: the effects race detector.

``execute_graph_batch`` trusts ``TOOL_EFFECTS`` for RAW/WAR/WAW hazard
inference — an *undeclared* workspace write is a silent data race the
bitwise-parity tests may never trigger (two "independent" nodes land in
one wave and mutate the same resource), and an *over-declared* effect
serializes nodes that could fuse (lost parallelism). This analyzer
closes the loop statically: it parses every tool handler branch in the
dispatch function, infers the handler's actual workspace reads/writes
and rng use from the AST, and diffs that against the declared
``ToolEffects`` entry.

Inference rules, over the workspace parameter (first arg of the
dispatch function, ``ws`` by convention):

  * ``ws.attr = ...``, ``ws.attr += ...``, ``ws.attr[...] = ...`` and
    mutating method calls (``append``/``extend``/``update``/...) are
    WRITES of the resource mapped to ``attr``;
  * any method call on ``ws.rng`` is an rng WRITE (consuming the seeded
    stream reorders every later draw — core/toolgraph.py models rng as
    a write resource for exactly this reason);
  * every other load of ``ws.attr`` is a READ;
  * helpers called with the workspace (``_helper(ws, ...)``) are
    summarized once and inlined at their call sites;
  * a declared WRITE subsumes reads of the same resource (write-hazard
    edges are a superset of read-hazard edges), so ``reads ⊆ declared
    reads ∪ declared writes`` and ``writes ⊆ declared writes`` is the
    soundness condition; anything declared but never inferred is
    over-declaration.

Handler branches are the ``if name == "x":`` / ``if name in (...):``
arms of the dispatch function; a branch shared by several tools
attributes its whole body to each of them (a sound over-approximation —
the declared entries for those tools are identical today).

The attr→resource map and read-only attr set come from module literals
``WORKSPACE_RESOURCE_ATTRS`` / ``READONLY_WORKSPACE_ATTRS`` when the
analyzed file defines them (env/tools_impl.py does), else from the
defaults mirrored here — so the analyzer runs unchanged on fixture
corpora.

The sweep is parameterized over (effects table, dispatch key): the base
pass checks ``TOOL_EFFECTS`` against the ``name``-keyed dispatch, and a
second pass (analysis/runner.py) checks ``CATALOG_FAMILY_EFFECTS``
against the ``family``-keyed dispatch covering every generated catalog
family (core/catalog.py) — so scaling the catalog cannot open effects
coverage gaps.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding, make_finding

_MUTATORS = {"append", "extend", "add", "update", "insert", "pop",
             "clear", "setdefault", "remove", "discard", "popitem",
             "appendleft", "sort", "reverse"}

_DEFAULT_ATTRS = {
    "handles": "handles", "map": "map_layers", "detections": "detections",
    "landcover": "landcover", "artifacts": "artifacts",
    "answer": "last_answer", "ui": "ui_state", "rng": "rng",
}
_DEFAULT_READONLY = {"world", "temperature"}

#: names of workspace methods that touch no hazard resource
_WS_PURE_METHODS = {"obs"}

#: second-parameter names that mark a module function as a dispatch
#: function rather than a summarizable helper — one per effects table
#: ("name": TOOL_EFFECTS base pass; "family": CATALOG_FAMILY_EFFECTS
#: generated-catalog pass)
_DISPATCH_PARAMS = ("name", "family")


@dataclass
class InferredEffects:
    reads: Set[str] = field(default_factory=set)
    writes: Set[str] = field(default_factory=set)
    #: attr -> first line where an unknown workspace attr was touched
    unknown: Dict[str, int] = field(default_factory=dict)
    #: resource -> first line of read / write (for finding locations)
    read_line: Dict[str, int] = field(default_factory=dict)
    write_line: Dict[str, int] = field(default_factory=dict)

    def merge(self, other: "InferredEffects", line: int) -> None:
        """Fold ``other`` in; ``line`` is the fallback location (the
        call/branch site) when ``other`` lacks a precise one."""
        for r in other.reads:
            self.reads.add(r)
            self.read_line.setdefault(r, other.read_line.get(r, line))
        for r in other.writes:
            self.writes.add(r)
            self.write_line.setdefault(r, other.write_line.get(r, line))
        for a, aline in other.unknown.items():
            self.unknown.setdefault(a, aline)


class _WsVisitor(ast.NodeVisitor):
    """Collect workspace effects inside one statement list."""

    def __init__(self, ws_name: str, attr_map: Dict[str, str],
                 readonly: Set[str],
                 helpers: Dict[str, "InferredEffects"]):
        self.ws = ws_name
        self.res_of = {attr: res for res, attr in attr_map.items()}
        self.readonly = set(readonly)
        self.helpers = helpers
        self.eff = InferredEffects()

    # -- helpers ----------------------------------------------------------
    def _is_ws(self, node: ast.AST) -> bool:
        return isinstance(node, ast.Name) and node.id == self.ws

    def _ws_attr(self, node: ast.AST) -> Optional[Tuple[str, int]]:
        """(attr, line) when ``node`` is ``ws.<attr>``."""
        if isinstance(node, ast.Attribute) and self._is_ws(node.value):
            return node.attr, node.lineno
        return None

    def _note(self, attr: str, line: int, write: bool) -> None:
        if attr in self.readonly or attr in _WS_PURE_METHODS:
            return
        res = self.res_of.get(attr)
        if res is None:
            self.eff.unknown.setdefault(attr, line)
            return
        if write:
            self.eff.writes.add(res)
            self.eff.write_line.setdefault(res, line)
        else:
            self.eff.reads.add(res)
            self.eff.read_line.setdefault(res, line)

    # -- writes -----------------------------------------------------------
    def _target(self, tgt: ast.AST) -> None:
        wa = self._ws_attr(tgt)
        if wa:
            self._note(wa[0], wa[1], write=True)
            return
        if isinstance(tgt, ast.Subscript):
            wa = self._ws_attr(tgt.value)
            if wa:
                self._note(wa[0], wa[1], write=True)
                return
            self.visit(tgt.value)
            self.visit(tgt.slice)
            return
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                self._target(e)
            return
        self.visit(tgt)

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            self._target(tgt)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._target(node.target)
        # augmented assignment also reads the target resource, but a
        # write subsumes the read for hazard purposes
        self.visit(node.value)

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        # ws.attr.method(...) — mutators write, others read the attr
        if isinstance(fn, ast.Attribute):
            wa = self._ws_attr(fn.value)
            if wa is not None:
                attr, line = wa
                # any rng method consumes the seeded stream => write
                is_write = (fn.attr in _MUTATORS
                            or self.res_of.get(attr) == "rng")
                self._note(attr, line, write=is_write)
                for a in node.args:
                    self.visit(a)
                for kw in node.keywords:
                    self.visit(kw.value)
                return
            # ws.method(...): pure observation helpers are transparent
            if self._is_ws(fn.value) and fn.attr in _WS_PURE_METHODS:
                for a in node.args:
                    self.visit(a)
                for kw in node.keywords:
                    self.visit(kw.value)
                return
        # helper(ws, ...) — inline the helper's summary
        if isinstance(fn, ast.Name) and fn.id in self.helpers and any(
                self._is_ws(a) for a in node.args):
            self.eff.merge(self.helpers[fn.id], node.lineno)
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        wa = self._ws_attr(node)
        if wa:
            self._note(wa[0], wa[1], write=False)
            return
        self.generic_visit(node)


def _infer(body: Sequence[ast.stmt], ws_name: str,
           attr_map: Dict[str, str], readonly: Set[str],
           helpers: Dict[str, InferredEffects]) -> InferredEffects:
    v = _WsVisitor(ws_name, attr_map, readonly, helpers)
    for stmt in body:
        v.visit(stmt)
    return v.eff


# --------------------------------------------------- module-level parse ----

def _literal_str_dict(node: ast.AST) -> Optional[Dict[str, str]]:
    if isinstance(node, ast.Dict):
        try:
            d = {ast.literal_eval(k): ast.literal_eval(v)
                 for k, v in zip(node.keys, node.values)}
        except (ValueError, TypeError):
            return None
        if all(isinstance(k, str) and isinstance(v, str)
               for k, v in d.items()):
            return d
    return None


def _tool_names_of_test(test: ast.AST, name_arg: str) -> List[str]:
    """Tool names matched by ``name == "x"`` / ``name in ("x", "y")``."""
    if not isinstance(test, ast.Compare) or len(test.ops) != 1:
        return []
    left, op, right = test.left, test.ops[0], test.comparators[0]
    if not (isinstance(left, ast.Name) and left.id == name_arg):
        return []
    if isinstance(op, ast.Eq) and isinstance(right, ast.Constant) \
            and isinstance(right.value, str):
        return [right.value]
    if isinstance(op, ast.In) and isinstance(right, (ast.Tuple, ast.List,
                                                     ast.Set)):
        names = []
        for e in right.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                names.append(e.value)
        return names
    return []


@dataclass
class HandlerInfo:
    tools: Tuple[str, ...]
    line: int
    effects: InferredEffects


def _declared_effects(tree: ast.Module, table_name: str = "TOOL_EFFECTS"
                      ) -> Dict[str, Tuple[Set[str], Set[str], int]]:
    """Parse the ``<table_name> = {...}`` literal: tool -> (reads,
    writes, line). Supports the ``_eff(reads=..., writes=...)`` helper
    and direct ``ToolEffects(frozenset(...), frozenset(...))`` calls."""
    out: Dict[str, Tuple[Set[str], Set[str], int]] = {}
    for node in tree.body:
        if not (isinstance(node, (ast.Assign, ast.AnnAssign))):
            continue
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        if not any(isinstance(t, ast.Name) and t.id == table_name
                   for t in targets):
            continue
        value = node.value
        if not isinstance(value, ast.Dict):
            continue
        for k, v in zip(value.keys, value.values):
            if not (isinstance(k, ast.Constant)
                    and isinstance(k.value, str)):
                continue
            reads: Set[str] = set()
            writes: Set[str] = set()
            if isinstance(v, ast.Call):
                args = list(v.args)
                kwargs = {kw.arg: kw.value for kw in v.keywords}
                def _strset(n: Optional[ast.AST]) -> Set[str]:
                    if n is None:
                        return set()
                    try:
                        val = ast.literal_eval(n)
                    except (ValueError, TypeError):
                        return set()
                    if isinstance(val, str):
                        return set(val.split())
                    return set(val)
                fn = v.func
                fname = fn.id if isinstance(fn, ast.Name) else getattr(
                    fn, "attr", "")
                if fname == "_eff":
                    reads = _strset(args[0] if args else
                                    kwargs.get("reads"))
                    writes = _strset(args[1] if len(args) > 1 else
                                     kwargs.get("writes"))
                else:   # ToolEffects(frozenset({...}), frozenset({...}))
                    def _inner(n: Optional[ast.AST]) -> Set[str]:
                        if isinstance(n, ast.Call) and n.args:
                            return _strset(n.args[0])
                        return _strset(n)
                    reads = _inner(args[0] if args else
                                   kwargs.get("reads"))
                    writes = _inner(args[1] if len(args) > 1 else
                                    kwargs.get("writes"))
            out[k.value] = (reads, writes, v.lineno)
    return out


def _dispatch_functions(tree: ast.Module, name_param: str = "name"
                        ) -> List[ast.FunctionDef]:
    """Dispatch functions: module-level defs whose params look like
    ``(ws-like, <name_param>, ...)`` — we key on a first param named
    ``ws`` (or annotated Workspace) and a second param matching the
    pass's dispatch key (``name`` or ``family``)."""
    out = []
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        params = [a.arg for a in node.args.args]
        if len(params) >= 2 and params[1] == name_param and (
                params[0] == "ws" or _annotated_workspace(node.args.args[0])):
            out.append(node)
    return out


def _annotated_workspace(arg: ast.arg) -> bool:
    ann = arg.annotation
    name = ""
    if isinstance(ann, ast.Name):
        name = ann.id
    elif isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        name = ann.value
    return name == "Workspace"


def _helper_summaries(tree: ast.Module, attr_map: Dict[str, str],
                      readonly: Set[str]) -> Dict[str, InferredEffects]:
    """One-level summaries for module functions taking a ws param."""
    out: Dict[str, InferredEffects] = {}
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        params = [a.arg for a in node.args.args]
        # a dispatch function (second param is a dispatch key) must not
        # be summarized as a helper: inlining its union-of-branches at
        # a call site would attribute every family's effects to the
        # calling tool
        if (params and params[0] == "ws"
                and (len(params) < 2
                     or params[1] not in _DISPATCH_PARAMS)):
            out[node.name] = _infer(node.body, "ws", attr_map, readonly, {})
    return out


def analyze_effects(path: Path, source: str,
                    registry_names: Optional[Sequence[str]] = None,
                    table_name: str = "TOOL_EFFECTS",
                    name_param: str = "name") -> List[Finding]:
    """Run RL001–RL005 over one tools-impl-shaped file.

    ``registry_names``: when given (the real repo run passes the
    catalog), RL004 also checks registry ⇔ effects-table coverage.
    ``table_name``/``name_param`` select the pass: the default checks
    ``TOOL_EFFECTS`` against the ``name``-keyed dispatch; the
    generated-catalog pass checks ``CATALOG_FAMILY_EFFECTS`` against
    the ``family``-keyed dispatch.
    """
    findings: List[Finding] = []
    tree = ast.parse(source)

    attr_map = dict(_DEFAULT_ATTRS)
    readonly = set(_DEFAULT_READONLY)
    for node in tree.body:
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            names = {t.id for t in targets if isinstance(t, ast.Name)}
            if "WORKSPACE_RESOURCE_ATTRS" in names and node.value is not None:
                parsed = _literal_str_dict(node.value)
                if parsed:
                    attr_map = parsed
            if "READONLY_WORKSPACE_ATTRS" in names and node.value is not None:
                try:
                    val = ast.literal_eval(
                        node.value.args[0]
                        if isinstance(node.value, ast.Call)
                        and node.value.args else node.value)
                    readonly = set(val)
                except (ValueError, TypeError):
                    pass

    declared = _declared_effects(tree, table_name)
    helpers = _helper_summaries(tree, attr_map, readonly)

    handlers: List[HandlerInfo] = []
    for fn in _dispatch_functions(tree, name_param):
        ws_name = fn.args.args[0].arg
        name_arg = fn.args.args[1].arg
        for stmt in ast.walk(fn):
            if not isinstance(stmt, ast.If):
                continue
            tools = _tool_names_of_test(stmt.test, name_arg)
            if not tools:
                continue
            eff = _infer(stmt.body, ws_name, attr_map, readonly, helpers)
            handlers.append(HandlerInfo(tuple(tools), stmt.lineno, eff))

    handled_tools: Set[str] = set()
    for h in handlers:
        handled_tools.update(h.tools)

    # nested `if name == ...` arms inside a multi-tool branch re-appear
    # as their own HandlerInfo; union per tool
    per_tool: Dict[str, Tuple[InferredEffects, int]] = {}
    for h in handlers:
        for t in h.tools:
            if t in per_tool:
                per_tool[t][0].merge(h.effects, h.line)
            else:
                eff = InferredEffects()
                eff.merge(h.effects, h.line)
                per_tool[t] = (eff, h.line)

    for tool in sorted(per_tool):
        eff, line = per_tool[tool]
        for attr, aline in sorted(eff.unknown.items()):
            findings.append(make_finding(
                "RL005", path, aline,
                f"handler {tool!r} touches workspace attribute "
                f"{attr!r} outside the hazard alphabet",
                "add the resource to WORKSPACE_RESOURCE_ATTRS + "
                "core.toolgraph.WORKSPACE_RESOURCES (or mark it "
                "read-only) so hazard inference can order it"))
        if tool not in declared:
            findings.append(make_finding(
                "RL004", path, line,
                f"tool {tool!r} has a handler but no {table_name} entry",
                "add an entry; unknown tools fail graph compilation"))
            continue
        dr, dw, dline = declared[tool]
        for res in sorted(eff.writes - dw):
            findings.append(make_finding(
                "RL001", path, eff.write_line.get(res, line),
                f"tool {tool!r} writes {res!r} but declares writes="
                f"{sorted(dw)}",
                f"declare the write in {table_name}: undeclared writes "
                "race inside execute_graph_batch waves"))
        for res in sorted(eff.reads - (dr | dw)):
            findings.append(make_finding(
                "RL002", path, eff.read_line.get(res, line),
                f"tool {tool!r} reads {res!r} but declares reads="
                f"{sorted(dr)} writes={sorted(dw)}",
                "declare the read: an unordered RAW hazard makes "
                "observations schedule-dependent"))
        for res in sorted(dw - eff.writes):
            findings.append(make_finding(
                "RL003", path, dline,
                f"tool {tool!r} declares write of {res!r} it never "
                f"performs",
                "drop the over-declaration: it serializes nodes that "
                "could run in one wave"))
        for res in sorted(dr - eff.reads - eff.writes):
            findings.append(make_finding(
                "RL003", path, dline,
                f"tool {tool!r} declares read of {res!r} it never "
                f"performs",
                "drop the over-declaration: it serializes against "
                "writers needlessly"))

    for tool in sorted(set(declared) - handled_tools):
        findings.append(make_finding(
            "RL004", path, declared[tool][2],
            f"{table_name} entry {tool!r} has no handler branch",
            "remove the dead entry or add the handler"))

    if registry_names is not None and declared:
        reg = set(registry_names)
        for tool in sorted(reg - set(declared)):
            findings.append(make_finding(
                "RL004", path, 1,
                f"registry tool {tool!r} missing from {table_name}",
                "every catalog tool needs an effects entry for hazard "
                "inference"))
        for tool in sorted(set(declared) - reg):
            findings.append(make_finding(
                "RL004", path, declared[tool][2],
                f"{table_name} entry {tool!r} not in the tool registry",
                "remove the dead entry or register the tool"))

    return findings
