"""Shared finding model for the determinism & hazard static-analysis
suite (``python -m repro.analysis``).

A :class:`Finding` is one rule violation at one source location; every
analyzer in this package emits the same shape so the CLI can merge,
suppress, baseline and render them uniformly. Severities:

  * ``error``   — breaks the repo's determinism/parity contract
                  (undeclared workspace write, wall-clock in serving,
                  kernel contract violation, ...);
  * ``warning`` — correct but wasteful or fragile (over-declared
                  effects = lost parallelism).

Suppression, in precedence order:

  1. inline  — ``# repro-lint: disable=RL001`` (comma-separated ids,
     or ``all``) on the finding's line;
  2. file    — ``# repro-lint: disable-file=RL104`` anywhere in the
     file suppresses that rule for the whole file;
  3. baseline — a committed JSON file of accepted findings, matched on
     ``(rule, path, message)`` so line drift does not resurrect them.

Suppressed findings are kept (flagged) rather than dropped: reports
show them, exit codes ignore them.
"""
from __future__ import annotations

import io
import json
import tokenize
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Set, Tuple

SEVERITIES = ("error", "warning")

#: rule id -> (severity, one-line summary); the authoritative catalog
#: (DESIGN.md §Static analysis documents the rationale per rule).
RULES: Dict[str, Tuple[str, str]] = {
    # effects race detector (env/tools_impl.py handlers vs TOOL_EFFECTS)
    "RL001": ("error", "undeclared workspace write (hazard race)"),
    "RL002": ("error", "undeclared workspace read (unordered RAW)"),
    "RL003": ("warning", "over-declared effect (lost parallelism)"),
    "RL004": ("error", "registry/effects-table coverage gap"),
    "RL005": ("error", "workspace attribute outside the hazard alphabet"),
    # determinism lint (core/ serving/ env/ kernels/)
    "RL101": ("error", "wall-clock read in deterministic code"),
    "RL102": ("error", "stdlib random (unseeded global stream)"),
    "RL103": ("error", "environment read in deterministic code"),
    "RL104": ("error", "unordered set iteration feeding ordered output"),
    "RL105": ("error", "float-keyed dict (hash/round-trip fragile)"),
    "RL106": ("error", "wall-clock read outside the injected-clock "
                       "boundary"),
    # pallas kernel contract checker (kernels/*.py)
    "RL201": ("error", "non-fp32 VMEM scratch accumulator"),
    "RL202": ("error", "BlockSpec index_map arity != grid + prefetch"),
    "RL203": ("error", "pallas_call operand/parameter count mismatch"),
    "RL204": ("error", "dimension_semantics arity != grid arity"),
    "RL205": ("error", "softmax/exp without fp32 cast in kernel"),
    # backend registry checker (kernels/backend.py)
    "RL301": ("error", "backend op signature violates OP_SURFACE"),
    "RL302": ("error", "kernel module not wired into the registry"),
    "RL303": ("error", "required backend/op registration missing"),
}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str                    # repo-relative, "/"-separated
    line: int
    message: str
    hint: str = ""
    suppressed: str = ""         # "", "inline", "file" or "baseline"

    @property
    def severity(self) -> str:
        return RULES[self.rule][0]

    def to_json(self) -> Dict:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line,
                "message": self.message, "hint": self.hint,
                "suppressed": self.suppressed}

    def render(self) -> str:
        tag = f" [suppressed:{self.suppressed}]" if self.suppressed else ""
        hint = f"\n    hint: {self.hint}" if self.hint else ""
        return (f"{self.path}:{self.line}: {self.rule} "
                f"{self.severity}: {self.message}{tag}{hint}")


def make_finding(rule: str, path, line: int, message: str,
                 hint: str = "") -> Finding:
    assert rule in RULES, rule
    return Finding(rule, str(path).replace("\\", "/"), line, message, hint)


# ------------------------------------------------------- suppressions ----

_MARK = "repro-lint:"


def _parse_directive(comment: str) -> Tuple[str, Set[str]]:
    """Parse one ``# repro-lint: disable[-file]=RL001,RL002`` comment;
    returns ("", set()) when the comment is not a directive."""
    text = comment.lstrip("#").strip()
    if not text.startswith(_MARK):
        return "", set()
    body = text[len(_MARK):].strip()
    for kind in ("disable-file", "disable"):
        if body.startswith(kind):
            rest = body[len(kind):].lstrip("= ")
            ids = {r.strip() for r in rest.split(",") if r.strip()}
            return kind, ids
    return "", set()


@dataclass
class Suppressions:
    """Per-file suppression state parsed from comments."""
    by_line: Dict[int, Set[str]] = field(default_factory=dict)
    whole_file: Set[str] = field(default_factory=set)

    @classmethod
    def for_source(cls, source: str) -> "Suppressions":
        sup = cls()
        try:
            toks = tokenize.generate_tokens(io.StringIO(source).readline)
            for tok in toks:
                if tok.type != tokenize.COMMENT:
                    continue
                kind, ids = _parse_directive(tok.string)
                if kind == "disable":
                    sup.by_line.setdefault(tok.start[0], set()).update(ids)
                elif kind == "disable-file":
                    sup.whole_file.update(ids)
        except tokenize.TokenizeError:
            pass
        return sup

    def match(self, f: Finding) -> str:
        inline = self.by_line.get(f.line, set())
        if f.rule in inline or "all" in inline:
            return "inline"
        if f.rule in self.whole_file or "all" in self.whole_file:
            return "file"
        return ""


def apply_suppressions(findings: Sequence[Finding],
                       source_by_path: Dict[str, str]) -> List[Finding]:
    """Mark findings suppressed by in-source directives."""
    cache: Dict[str, Suppressions] = {}
    out: List[Finding] = []
    for f in findings:
        if f.path not in cache and f.path in source_by_path:
            cache[f.path] = Suppressions.for_source(source_by_path[f.path])
        kind = cache[f.path].match(f) if f.path in cache else ""
        out.append(replace(f, suppressed=kind) if kind else f)
    return out


# ----------------------------------------------------------- baseline ----

def load_baseline(path: Path) -> Set[Tuple[str, str, str]]:
    """Committed accepted findings as (rule, path, message) triples."""
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return {(e["rule"], e["path"], e["message"])
            for e in data.get("accepted", [])}


def write_baseline(path: Path, findings: Iterable[Finding]) -> None:
    entries = [{"rule": f.rule, "path": f.path, "message": f.message}
               for f in findings if not f.suppressed]
    entries.sort(key=lambda e: (e["path"], e["rule"], e["message"]))
    path.write_text(json.dumps({"accepted": entries}, indent=2) + "\n")


def apply_baseline(findings: Sequence[Finding],
                   baseline: Set[Tuple[str, str, str]]) -> List[Finding]:
    out: List[Finding] = []
    for f in findings:
        if not f.suppressed and (f.rule, f.path, f.message) in baseline:
            f = replace(f, suppressed="baseline")
        out.append(f)
    return out


def active(findings: Iterable[Finding], severity: str = "error"
           ) -> List[Finding]:
    """Unsuppressed findings at or above ``severity``."""
    keep = {"error": ("error",),
            "warning": ("error", "warning")}[severity]
    return [f for f in findings
            if not f.suppressed and f.severity in keep]
