"""RL101–RL106: determinism lint.

The serving stack's headline claims — bitwise-identical replays, tick
clocks, seeded rng everywhere — are conventions, not types. This pass
makes them machine-checked in the deterministic directories (``core/``,
``serving/``, ``env/``, ``kernels/``; ``benchmarks/`` and tests
legitimately read wall-clock and are out of scope by default):

  * RL101 — wall-clock reads: ``time.time/monotonic/perf_counter/
    time_ns``, ``datetime.now/utcnow/today``. A tick-based system that
    reads the wall clock is only *usually* reproducible.
  * RL102 — stdlib ``random``: the module-global Mersenne stream is
    process-wide mutable state; all randomness must flow through seeded
    ``np.random.Generator`` / ``jax.random`` keys.
  * RL103 — ``os.environ`` / ``os.getenv`` reads: behaviour keyed on
    ambient environment diverges across machines and CI.
  * RL104 — iterating a ``set``/``frozenset`` expression directly into
    an ordered sink (for-loop, comprehension, ``list``/``tuple``/
    ``join``/``enumerate``) without ``sorted(...)``: set order is
    hash-seed-dependent across processes.
  * RL105 — float-keyed dict literals/comprehensions: float key
    identity is representation-fragile (``0.1 + 0.2`` lookups, JSON
    round-trips stringify keys).
  * RL106 — the *boundary* rule for every other ``src/repro`` package
    (``common``, ``configs``, ``models``, ``distributed``,
    ``training``, ``analysis``): wall-clock reads are only legal
    behind an injected ``clock=`` callable (the engine/tracer
    convention — ``InferenceEngine(clock=...)``,
    ``Tracer.bind_clock``). Direct ``time.*``/``datetime.now``-family
    reads are flagged; the full RL102–RL105 battery is not, those
    packages may legitimately read env vars etc. Only
    ``src/repro/obs/`` and ``src/repro/launch/`` (the clock
    *providers*) may touch the wall clock directly — see
    :data:`CLOCK_ALLOWLIST` / :func:`wallclock_scope`.

Purely syntactic (AST) — no imports of the analyzed code.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional

from repro.analysis.findings import Finding, make_finding

_WALLCLOCK_TIME = {"time", "monotonic", "perf_counter", "time_ns",
                   "monotonic_ns", "perf_counter_ns"}
_WALLCLOCK_DT = {"now", "utcnow", "today"}
_ORDERED_SINKS = {"list", "tuple", "enumerate"}

#: packages under the full RL101–RL105 battery
FULL_LINT_DIRS = ("src/repro/core/", "src/repro/serving/",
                  "src/repro/env/", "src/repro/kernels/")
#: the only packages allowed to read the wall clock directly: obs/
#: binds injected clocks to traces, launch/ is the process entry that
#: *supplies* ``time.time`` to everything else
CLOCK_ALLOWLIST = ("src/repro/obs/", "src/repro/launch/")


def wallclock_scope(rel: str) -> str:
    """Which determinism lint applies to a repo-relative path:

    * ``"full"``     — RL101–RL105 (deterministic core dirs, and any
      path outside ``src/repro`` such as the fixture corpora);
    * ``"allow"``    — no determinism lint (the clock providers);
    * ``"boundary"`` — RL106 only (remaining ``src/repro`` packages).
    """
    rel = rel.replace("\\", "/")
    if any(rel.startswith(p) for p in CLOCK_ALLOWLIST):
        return "allow"
    if any(rel.startswith(p) for p in FULL_LINT_DIRS) \
            or not rel.startswith("src/repro/"):
        return "full"
    return "boundary"


def _dotted(node: ast.AST) -> str:
    """'a.b.c' for nested attributes rooted at a Name, else ''."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    return False


class _Lint(ast.NodeVisitor):
    """``clock_only=True`` is the RL106 boundary mode: the same
    wall-clock detection, emitted under ``clock_rule``, with every
    other rule (RL102–RL105) switched off."""

    def __init__(self, path: Path, *, clock_rule: str = "RL101",
                 clock_only: bool = False):
        self.path = path
        self.clock_rule = clock_rule
        self.clock_only = clock_only
        self.findings: List[Finding] = []

    def _add(self, rule: str, line: int, message: str, hint: str) -> None:
        self.findings.append(make_finding(rule, self.path, line,
                                          message, hint))

    def _add_clock(self, line: int, dotted: str, full_hint: str) -> None:
        if self.clock_rule == "RL106":
            self._add("RL106", line,
                      f"wall-clock read {dotted}() outside the "
                      f"injected-clock boundary",
                      "accept an injected clock= callable (the "
                      "engine/tracer convention); only src/repro/obs/ "
                      "and src/repro/launch/ read the wall clock "
                      "directly")
        else:
            self._add("RL101", line, f"wall-clock read {dotted}()",
                      full_hint)

    # ------------------------------------------------ RL101/RL106-103 ----
    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        head, _, tail = dotted.rpartition(".")
        if head in ("time",) and tail in _WALLCLOCK_TIME:
            self._add_clock(node.lineno, dotted,
                            "inject a clock / use the tick counter; "
                            "wall-clock belongs in launch/ and "
                            "benchmarks/")
        elif tail in _WALLCLOCK_DT and head.split(".")[-1] == "datetime":
            self._add_clock(node.lineno, dotted,
                            "pass timestamps in explicitly")
        elif self.clock_only:
            pass                 # boundary scope: clock reads only
        elif dotted in ("os.getenv",) or (
                head == "os.environ" and tail == "get"):
            self._add("RL103", node.lineno,
                      f"environment read {dotted}(...)",
                      "thread configuration through explicit config "
                      "objects / PerfFlags")
        elif head == "random" or dotted.startswith("random."):
            self._add("RL102", node.lineno,
                      f"stdlib random call {dotted}()",
                      "use a seeded np.random.Generator or jax.random "
                      "key threaded from the caller")
        # ordered sinks over raw set expressions
        if not self.clock_only and isinstance(node.func, ast.Name) \
                and node.func.id in _ORDERED_SINKS and node.args \
                and _is_set_expr(node.args[0]):
            self._add("RL104", node.lineno,
                      f"{node.func.id}() over an unordered set "
                      f"expression",
                      "wrap the set in sorted(...)")
        if not self.clock_only and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "join" and node.args \
                and _is_set_expr(node.args[0]):
            self._add("RL104", node.lineno,
                      "str.join over an unordered set expression",
                      "wrap the set in sorted(...)")
        self.generic_visit(node)

    def visit_Subscript(self, node: ast.Subscript) -> None:
        if not self.clock_only and _dotted(node.value) == "os.environ" \
                and isinstance(node.ctx, ast.Load):
            self._add("RL103", node.lineno, "os.environ[...] read",
                      "thread configuration through explicit config")
        self.generic_visit(node)

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            if not self.clock_only and alias.name == "random":
                self._add("RL102", node.lineno, "import random",
                          "stdlib random is a process-global stream; "
                          "use seeded generators")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if not self.clock_only and node.module == "random":
            self._add("RL102", node.lineno, "from random import ...",
                      "use seeded generators")
        self.generic_visit(node)

    # ---------------------------------------------------------- RL104 ----
    def _check_iter(self, it: ast.AST, line: int) -> None:
        if not self.clock_only and _is_set_expr(it):
            self._add("RL104", line,
                      "iteration over an unordered set expression",
                      "iterate sorted(...) so downstream order is "
                      "deterministic")

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node.iter, node.lineno)
        self.generic_visit(node)

    def _comp(self, node) -> None:
        for gen in node.generators:
            self._check_iter(gen.iter, node.lineno)
        self.generic_visit(node)

    visit_ListComp = _comp
    visit_GeneratorExp = _comp

    def visit_SetComp(self, node: ast.SetComp) -> None:
        # building another set keeps order irrelevant; don't descend
        # into RL104 for its generators, but other rules still apply
        self.generic_visit(node)

    def visit_DictComp(self, node: ast.DictComp) -> None:
        for gen in node.generators:
            self._check_iter(gen.iter, node.lineno)
        if not self.clock_only and _is_float_const(node.key):
            self._add("RL105", node.lineno,
                      "dict comprehension with float keys",
                      "key on ints/strings (quantize or stringify)")
        self.generic_visit(node)

    # ---------------------------------------------------------- RL105 ----
    def visit_Dict(self, node: ast.Dict) -> None:
        for k in node.keys:
            if not self.clock_only and k is not None \
                    and _is_float_const(k):
                self._add("RL105", k.lineno,
                          "dict literal with float key",
                          "key on ints/strings (quantize or stringify)")
                break
        self.generic_visit(node)


def _is_float_const(node: Optional[ast.AST]) -> bool:
    return isinstance(node, ast.Constant) and isinstance(node.value, float)


def analyze_determinism(path: Path, source: str) -> List[Finding]:
    """Full RL101–RL105 battery (deterministic-core scope)."""
    lint = _Lint(path)
    lint.visit(ast.parse(source))
    return lint.findings


def analyze_clock_boundary(path: Path, source: str) -> List[Finding]:
    """RL106 only: wall-clock reads in boundary-scope packages (the
    rest of the determinism battery does not apply there)."""
    lint = _Lint(path, clock_rule="RL106", clock_only=True)
    lint.visit(ast.parse(source))
    return lint.findings
