"""RL101–RL105: determinism lint.

The serving stack's headline claims — bitwise-identical replays, tick
clocks, seeded rng everywhere — are conventions, not types. This pass
makes them machine-checked in the deterministic directories (``core/``,
``serving/``, ``env/``, ``kernels/``; ``benchmarks/`` and ``launch/``
legitimately read wall-clock and are out of scope by default):

  * RL101 — wall-clock reads: ``time.time/monotonic/perf_counter/
    time_ns``, ``datetime.now/utcnow/today``. A tick-based system that
    reads the wall clock is only *usually* reproducible.
  * RL102 — stdlib ``random``: the module-global Mersenne stream is
    process-wide mutable state; all randomness must flow through seeded
    ``np.random.Generator`` / ``jax.random`` keys.
  * RL103 — ``os.environ`` / ``os.getenv`` reads: behaviour keyed on
    ambient environment diverges across machines and CI.
  * RL104 — iterating a ``set``/``frozenset`` expression directly into
    an ordered sink (for-loop, comprehension, ``list``/``tuple``/
    ``join``/``enumerate``) without ``sorted(...)``: set order is
    hash-seed-dependent across processes.
  * RL105 — float-keyed dict literals/comprehensions: float key
    identity is representation-fragile (``0.1 + 0.2`` lookups, JSON
    round-trips stringify keys).

Purely syntactic (AST) — no imports of the analyzed code.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional

from repro.analysis.findings import Finding, make_finding

_WALLCLOCK_TIME = {"time", "monotonic", "perf_counter", "time_ns",
                   "monotonic_ns", "perf_counter_ns"}
_WALLCLOCK_DT = {"now", "utcnow", "today"}
_ORDERED_SINKS = {"list", "tuple", "enumerate"}


def _dotted(node: ast.AST) -> str:
    """'a.b.c' for nested attributes rooted at a Name, else ''."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    return False


class _Lint(ast.NodeVisitor):
    def __init__(self, path: Path):
        self.path = path
        self.findings: List[Finding] = []

    def _add(self, rule: str, line: int, message: str, hint: str) -> None:
        self.findings.append(make_finding(rule, self.path, line,
                                          message, hint))

    # ------------------------------------------------------ RL101-103 ----
    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        head, _, tail = dotted.rpartition(".")
        if head in ("time",) and tail in _WALLCLOCK_TIME:
            self._add("RL101", node.lineno,
                      f"wall-clock read {dotted}()",
                      "inject a clock / use the tick counter; "
                      "wall-clock belongs in launch/ and benchmarks/")
        elif tail in _WALLCLOCK_DT and head.split(".")[-1] == "datetime":
            self._add("RL101", node.lineno,
                      f"wall-clock read {dotted}()",
                      "pass timestamps in explicitly")
        elif dotted in ("os.getenv",) or (
                head == "os.environ" and tail == "get"):
            self._add("RL103", node.lineno,
                      f"environment read {dotted}(...)",
                      "thread configuration through explicit config "
                      "objects / PerfFlags")
        elif head == "random" or dotted.startswith("random."):
            self._add("RL102", node.lineno,
                      f"stdlib random call {dotted}()",
                      "use a seeded np.random.Generator or jax.random "
                      "key threaded from the caller")
        # ordered sinks over raw set expressions
        if isinstance(node.func, ast.Name) \
                and node.func.id in _ORDERED_SINKS and node.args \
                and _is_set_expr(node.args[0]):
            self._add("RL104", node.lineno,
                      f"{node.func.id}() over an unordered set "
                      f"expression",
                      "wrap the set in sorted(...)")
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "join" and node.args \
                and _is_set_expr(node.args[0]):
            self._add("RL104", node.lineno,
                      "str.join over an unordered set expression",
                      "wrap the set in sorted(...)")
        self.generic_visit(node)

    def visit_Subscript(self, node: ast.Subscript) -> None:
        if _dotted(node.value) == "os.environ" \
                and isinstance(node.ctx, ast.Load):
            self._add("RL103", node.lineno, "os.environ[...] read",
                      "thread configuration through explicit config")
        self.generic_visit(node)

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            if alias.name == "random":
                self._add("RL102", node.lineno, "import random",
                          "stdlib random is a process-global stream; "
                          "use seeded generators")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "random":
            self._add("RL102", node.lineno, "from random import ...",
                      "use seeded generators")
        self.generic_visit(node)

    # ---------------------------------------------------------- RL104 ----
    def _check_iter(self, it: ast.AST, line: int) -> None:
        if _is_set_expr(it):
            self._add("RL104", line,
                      "iteration over an unordered set expression",
                      "iterate sorted(...) so downstream order is "
                      "deterministic")

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node.iter, node.lineno)
        self.generic_visit(node)

    def _comp(self, node) -> None:
        for gen in node.generators:
            self._check_iter(gen.iter, node.lineno)
        self.generic_visit(node)

    visit_ListComp = _comp
    visit_GeneratorExp = _comp

    def visit_SetComp(self, node: ast.SetComp) -> None:
        # building another set keeps order irrelevant; don't descend
        # into RL104 for its generators, but other rules still apply
        self.generic_visit(node)

    def visit_DictComp(self, node: ast.DictComp) -> None:
        for gen in node.generators:
            self._check_iter(gen.iter, node.lineno)
        if _is_float_const(node.key):
            self._add("RL105", node.lineno,
                      "dict comprehension with float keys",
                      "key on ints/strings (quantize or stringify)")
        self.generic_visit(node)

    # ---------------------------------------------------------- RL105 ----
    def visit_Dict(self, node: ast.Dict) -> None:
        for k in node.keys:
            if k is not None and _is_float_const(k):
                self._add("RL105", k.lineno,
                          "dict literal with float key",
                          "key on ints/strings (quantize or stringify)")
                break
        self.generic_visit(node)


def _is_float_const(node: Optional[ast.AST]) -> bool:
    return isinstance(node, ast.Constant) and isinstance(node.value, float)


def analyze_determinism(path: Path, source: str) -> List[Finding]:
    lint = _Lint(path)
    lint.visit(ast.parse(source))
    return lint.findings
