"""RL201–RL205: pallas kernel contract checker.

The seven Pallas kernels follow conventions DESIGN.md §Kernel backends
documents but nothing enforced: fp32 online-softmax accumulators in
VMEM scratch, BlockSpec ``index_map`` lambdas taking exactly
``len(grid) + num_scalar_prefetch`` parameters (scalar-prefetch refs
are appended to every index_map's signature), operands passed to the
compiled call in scalar-prefetch-first order, and
``dimension_semantics`` tuples matching the grid arity. Violating any
of these yields shape errors at best and silently wrong indexing at
worst (an index_map with too few params drops a grid axis; a scalar
operand out of order aliases the wrong ref).

This checker parses every ``pl.pallas_call`` site:

  * RL201 — ``pltpu.VMEM((...), dtype)`` scratch with dtype other than
    ``jnp.float32`` (the online-softmax m/l/acc accumulators must not
    round between blocks);
  * RL202 — a BlockSpec ``index_map`` whose non-defaulted parameter
    count differs from grid arity + num_scalar_prefetch (extra
    defaulted params like ``G=G`` closures are fine);
  * RL203 — operand/parameter count mismatches: the immediate call of
    the ``pallas_call`` result must pass ``num_scalar_prefetch +
    len(in_specs)`` operands, and the kernel function must take
    ``prefetch + inputs + outputs + scratch`` positional refs;
  * RL204 — ``dimension_semantics`` length != grid arity;
  * RL205 — a kernel body computing ``exp``/softmax with no
    ``.astype(jnp.float32)`` cast in scope (scores must be promoted
    before exponentiation).

Static only; conservative: sites whose grid/specs are not literal
enough to analyze are skipped, never guessed.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding, make_finding


def _dotted(node: ast.AST) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _kwarg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


@dataclass
class SiteSpec:
    call: ast.Call                       # the pl.pallas_call(...) node
    line: int
    grid_arity: Optional[int]
    n_prefetch: int
    in_specs: List[ast.AST]
    out_specs: List[ast.AST]
    n_out: Optional[int]
    scratch: List[ast.AST]
    dim_semantics: Optional[int]
    kernel_arg: Optional[ast.AST]        # first positional arg


def _tuple_len(node: Optional[ast.AST]) -> Optional[int]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    return None


def _list_elts(node: Optional[ast.AST]) -> List[ast.AST]:
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    if node is None:
        return []
    return [node]


def _local_value(node: Optional[ast.AST],
                 enclosing: Optional[ast.FunctionDef]) -> Optional[ast.AST]:
    """Follow ``x = <expr>`` one level when ``node`` is a local Name —
    the kernels bind grid_spec/kernel to locals before pallas_call."""
    if not (isinstance(node, ast.Name) and enclosing is not None):
        return node
    for stmt in ast.walk(enclosing):
        if isinstance(stmt, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == node.id
                for t in stmt.targets):
            return stmt.value
    return node


def _parse_site(call: ast.Call,
                enclosing: Optional[ast.FunctionDef]) -> SiteSpec:
    grid_arity: Optional[int] = None
    n_prefetch = 0
    in_specs: List[ast.AST] = []
    out_specs: List[ast.AST] = []
    scratch: List[ast.AST] = []

    def arg(c: ast.Call, name: str) -> Optional[ast.AST]:
        return _local_value(_kwarg(c, name), enclosing)

    grid_spec = arg(call, "grid_spec")
    if isinstance(grid_spec, ast.Call) and \
            _dotted(grid_spec.func).endswith("PrefetchScalarGridSpec"):
        gs = grid_spec
        npf = arg(gs, "num_scalar_prefetch")
        if isinstance(npf, ast.Constant) and isinstance(npf.value, int):
            n_prefetch = npf.value
        grid_arity = _tuple_len(arg(gs, "grid"))
        in_specs = _list_elts(arg(gs, "in_specs"))
        out_specs = _list_elts(arg(gs, "out_specs"))
        scratch = _list_elts(arg(gs, "scratch_shapes"))
    else:
        grid_arity = _tuple_len(arg(call, "grid"))
        in_specs = _list_elts(arg(call, "in_specs"))
        out_specs = _list_elts(arg(call, "out_specs"))
        scratch = _list_elts(arg(call, "scratch_shapes"))

    out_shape = arg(call, "out_shape")
    n_out = _tuple_len(out_shape)
    if n_out is None and out_shape is not None:
        n_out = 1
    if n_out is None and out_specs:
        n_out = len(out_specs)

    dim_sem: Optional[int] = None
    cp = arg(call, "compiler_params")
    if isinstance(cp, ast.Call):
        dim_sem = _tuple_len(arg(cp, "dimension_semantics"))

    kernel_arg = call.args[0] if call.args else None
    return SiteSpec(call, call.lineno, grid_arity, n_prefetch, in_specs,
                    out_specs, n_out, scratch, dim_sem, kernel_arg)


def _resolve_kernel_fn(site: SiteSpec, module: ast.Module,
                       enclosing: Optional[ast.FunctionDef]
                       ) -> Tuple[Optional[ast.FunctionDef], int]:
    """The kernel FunctionDef the site dispatches to, plus the number
    of positional args pre-bound by ``functools.partial``."""
    target = site.kernel_arg
    bound = 0
    if isinstance(target, ast.Name) and enclosing is not None:
        wanted = target.id
        for stmt in ast.walk(enclosing):
            if isinstance(stmt, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == wanted
                    for t in stmt.targets):
                target = stmt.value
                break
    if isinstance(target, ast.Call) and \
            _dotted(target.func).endswith("partial") and target.args:
        bound = len(target.args) - 1
        target = target.args[0]
    if isinstance(target, ast.Name):
        for node in module.body:
            if isinstance(node, ast.FunctionDef) \
                    and node.name == target.id:
                return node, bound
    return None, bound


def _lambda_arity(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Lambda):
        a = node.args
        return len(a.args) - len(a.defaults)
    return None


def _index_map(spec: ast.AST) -> Optional[ast.AST]:
    """The index_map argument of a BlockSpec(...) call, if literal."""
    if not (isinstance(spec, ast.Call)
            and _dotted(spec.func).endswith("BlockSpec")):
        return None
    im = _kwarg(spec, "index_map")
    if im is not None:
        return im
    if len(spec.args) >= 2:
        return spec.args[1]
    return None


def analyze_kernels(path: Path, source: str) -> List[Finding]:
    findings: List[Finding] = []
    module = ast.parse(source)

    # map every pallas_call site to its enclosing function + the call
    # applying its result (for operand counting)
    enclosing_of: Dict[ast.Call, Optional[ast.FunctionDef]] = {}
    applied_args: Dict[ast.Call, int] = {}

    def walk(node: ast.AST, fn: Optional[ast.FunctionDef]) -> None:
        for child in ast.iter_child_nodes(node):
            nfn = child if isinstance(child, ast.FunctionDef) else fn
            if isinstance(child, ast.Call):
                if _dotted(child.func).endswith("pallas_call"):
                    enclosing_of[child] = nfn
                if isinstance(child.func, ast.Call) and \
                        _dotted(child.func.func).endswith("pallas_call"):
                    applied_args[child.func] = len(child.args)
            walk(child, nfn)

    walk(module, None)

    for call, fn in enclosing_of.items():
        site = _parse_site(call, fn)
        expected_im = (None if site.grid_arity is None
                       else site.grid_arity + site.n_prefetch)

        # RL202: index_map arities
        for spec in site.in_specs + site.out_specs:
            im = _index_map(spec)
            arity = _lambda_arity(im) if im is not None else None
            if expected_im is not None and arity is not None \
                    and arity != expected_im:
                findings.append(make_finding(
                    "RL202", path, im.lineno,
                    f"index_map takes {arity} params; grid arity "
                    f"{site.grid_arity} + {site.n_prefetch} scalar-"
                    f"prefetch refs requires {expected_im}",
                    "scalar-prefetch refs are appended to every "
                    "index_map signature"))

        # RL201: scratch dtypes
        for s in site.scratch:
            if isinstance(s, ast.Call) and \
                    _dotted(s.func).endswith("VMEM") and len(s.args) >= 2:
                dt = _dotted(s.args[1])
                if dt and not dt.endswith("float32"):
                    findings.append(make_finding(
                        "RL201", path, s.lineno,
                        f"VMEM scratch declared {dt}; online-softmax "
                        f"accumulators must be fp32",
                        "use jnp.float32 scratch and cast on the "
                        "final store"))

        # RL204: dimension_semantics arity
        if site.dim_semantics is not None and site.grid_arity is not None \
                and site.dim_semantics != site.grid_arity:
            findings.append(make_finding(
                "RL204", path, site.line,
                f"dimension_semantics has {site.dim_semantics} entries "
                f"for a {site.grid_arity}-axis grid",
                "one semantics entry per grid axis"))

        # RL203: operand count at the application site
        n_ops = applied_args.get(call)
        if n_ops is not None and site.in_specs:
            expected_ops = site.n_prefetch + len(site.in_specs)
            if n_ops != expected_ops:
                findings.append(make_finding(
                    "RL203", path, site.line,
                    f"compiled call receives {n_ops} operands; "
                    f"{site.n_prefetch} scalar-prefetch + "
                    f"{len(site.in_specs)} in_specs requires "
                    f"{expected_ops}",
                    "pass scalar-prefetch operands first, then one "
                    "array per in_spec"))

        # RL203 + RL205: kernel function checks
        kfn, bound = _resolve_kernel_fn(site, module, fn)
        if kfn is not None and site.in_specs and site.n_out is not None:
            n_pos = len(kfn.args.args) - bound
            expected_refs = (site.n_prefetch + len(site.in_specs)
                             + site.n_out + len(site.scratch))
            if n_pos != expected_refs:
                findings.append(make_finding(
                    "RL203", path, kfn.lineno,
                    f"kernel {kfn.name!r} takes {n_pos} refs; "
                    f"{site.n_prefetch} prefetch + "
                    f"{len(site.in_specs)} inputs + {site.n_out} "
                    f"outputs + {len(site.scratch)} scratch requires "
                    f"{expected_refs}",
                    "ref order: scalar-prefetch, inputs, outputs, "
                    "scratch"))
        if kfn is not None:
            findings.extend(_check_fp32_softmax(path, kfn))

    return findings


def _check_fp32_softmax(path: Path, kfn: ast.FunctionDef
                        ) -> List[Finding]:
    uses_exp_line = None
    has_cast = False
    for node in ast.walk(kfn):
        if isinstance(node, ast.Call):
            d = _dotted(node.func)
            if d.endswith(".exp") or d.endswith(".softmax"):
                if uses_exp_line is None:
                    uses_exp_line = node.lineno
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "astype" and node.args \
                    and _dotted(node.args[0]).endswith("float32"):
                has_cast = True
    if uses_exp_line is not None and not has_cast:
        return [make_finding(
            "RL205", path, uses_exp_line,
            f"kernel {kfn.name!r} exponentiates without any "
            f".astype(jnp.float32) promotion",
            "cast scores to fp32 before exp; accumulate in fp32")]
    return []
