"""Inference engine: prefill + continuous-batching decode.

A fixed pool of ``max_batch`` slots shares one batched cache pytree with
per-sequence positions (the (B,) ``pos`` vector — decode writes use
one-hot masked updates so every slot can sit at a different fill level).
Requests are prefilled on arrival (B=1) and their caches inserted into a
free slot; one ``decode_step`` advances every active slot together.

Two serving-pipeline extensions (see DESIGN.md §Pipeline concurrency):

  * **prompt-prefix caching** — ``register_prefix`` prefills a shared
    prompt prefix (e.g. the gated system prompt of one GeckOpt intent)
    once; requests tagged with that ``prefix_key`` reuse the cached
    prefill and only extend it with their suffix tokens, instead of
    recomputing the full prefix per slot;
  * **sessions** — ``open_session`` returns an ``EngineSession`` that
    multiplexes the turns of one Copilot conversation over the shared
    continuous-batching slots (each turn is one request tagged with the
    session's intent prefix).

This is the single-host engine the examples serve the planner with; the
distributed story (pjit over the production mesh) reuses exactly the same
step functions via launch/serve.py.

``backend`` selects the kernel backend (kernels/backend.py) for every
jitted step — ``"pallas"`` routes prefill/extend attention through
flash_prefill, the continuous-batching decode through flash_decode (per
slot (B,) fill levels via scalar prefetch), MoE routing through the
fused top-k kernel and SSM/mLSTM state scans through their Pallas
kernels; ``"reference"`` (the default) keeps the pure-jnp paths.
DESIGN.md §Kernel backends has the selection rules and parity contract.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig, WINDOW_KINDS
from repro.models.model import (decode_step, init_cache, prefill,
                                prefill_extend)
from repro.serving.sampling import SamplerConfig, sample
from repro.serving.tokenizer import SPECIALS, TOKENIZER


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 32
    sampler: SamplerConfig = field(default_factory=SamplerConfig)
    prefix_key: Optional[str] = None
    session_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None   # "eos"|"max_new_tokens"|"cache_len"
    enqueue_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0


@dataclass
class CachedPrefix:
    ids: List[int]
    cache: dict          # B=1 prefilled cache pytree (scalar pos)
    logits: jnp.ndarray  # (1,V) logits after the prefix's last token


def _insert_slot(batched, single, slot: int):
    """Insert a B=1 cache pytree into slot `slot` of the batched cache.
    All cache leaves carry batch on axis 1 (stacked layer axis 0) except
    the (B,) pos vector."""
    def ins(b, s):
        if b.ndim >= 2 and s.shape[0] == b.shape[0] and s.ndim == b.ndim \
                and s.shape[1] == 1:
            return jax.lax.dynamic_update_slice_in_dim(b, s.astype(b.dtype),
                                                       slot, axis=1)
        return b
    out = jax.tree.map(ins, batched, single)
    return out


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 cache_len: int = 512, seed: int = 0,
                 backend: Optional[str] = None):
        from repro.kernels.backend import get_backend
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        # resolve once so every jitted step traces one fixed backend
        self.backend = get_backend(backend).name
        self.seed = seed
        self.rng = jax.random.PRNGKey(seed)
        self.cache = init_cache(cfg, max_batch, cache_len)
        self.cache["pos"] = jnp.zeros((max_batch,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        # deque: admission pops the head once per free slot; a list's
        # pop(0) is O(n) and goes quadratic under cluster-scale queues
        self.queue: Deque[Request] = deque()
        self.prefixes: Dict[str, CachedPrefix] = {}
        self._next_id = 0
        self._next_session = 0
        self.stats = {"decode_steps": 0, "prefills": 0,
                      "tokens_generated": 0, "prefix_hits": 0,
                      "prefix_tokens_saved": 0, "admissions": 0,
                      "prefix_registrations": 0}

        be = self.backend
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, cache_len=cache_len,
                                 backend=be))
        self._decode = jax.jit(
            lambda p, c, b: decode_step(p, cfg, c, b, backend=be))
        self._extend = jax.jit(
            lambda p, c, b, n: prefill_extend(p, cfg, c, b, n_valid=n,
                                              backend=be))
        kinds = {k for unit, _ in cfg.segments for k in unit}
        # multi-token cache extension: no ring buffers / cross-attention;
        # bucket-padded extends additionally require a stateless
        # (pure-attention) stack — recurrent state would step through pads
        self._can_extend = (not (kinds & set(WINDOW_KINDS))
                            and "encdec" not in kinds
                            and not cfg.n_enc_layers)
        self._pad_extend = (self._can_extend
                            and kinds <= {"full", "dense", "moe"})
        self._last_tokens = jnp.zeros((max_batch, 1), jnp.int32)

    # ------------------------------------------------------------- API ----
    def add_request(self, prompt_text_or_ids, max_new_tokens: int = 32,
                    sampler: SamplerConfig = SamplerConfig(),
                    prefix_key: Optional[str] = None,
                    session_id: Optional[int] = None) -> int:
        ids = (TOKENIZER.encode_with_specials(prompt_text_or_ids)
               if isinstance(prompt_text_or_ids, str)
               else list(prompt_text_or_ids))
        req = Request(self._next_id, ids, max_new_tokens, sampler,
                      prefix_key=prefix_key, session_id=session_id,
                      enqueue_t=time.time())
        self._next_id += 1
        self.queue.append(req)
        return req.request_id

    # ----------------------------------------------- load introspection ----
    # (the cluster router reads these to place requests; serving/cluster.py)
    def busy_slots(self) -> int:
        return sum(s is not None for s in self.slots)

    def free_slot_count(self) -> int:
        return self.max_batch - self.busy_slots()

    def queue_depth(self) -> int:
        return len(self.queue)

    def load(self) -> int:
        """In-flight work: occupied slots plus queued requests."""
        return self.busy_slots() + len(self.queue)

    def is_idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)

    def reset(self, seed: Optional[int] = None):
        """Return the engine to its just-constructed state (drain and
        recycle a cluster replica between workloads). Cache storage is
        reused — stale rows are masked by the zeroed ``pos`` vector and
        overwritten at the next admission; jitted step functions are
        kept, so a reset engine serves warm."""
        if seed is not None:
            self.seed = seed
        self.rng = jax.random.PRNGKey(self.seed)
        self.cache["pos"] = jnp.zeros((self.max_batch,), jnp.int32)
        self.slots = [None] * self.max_batch
        self.queue.clear()
        self.prefixes.clear()
        self._next_id = 0
        self._next_session = 0
        self.stats = {k: 0 for k in self.stats}
        self._last_tokens = jnp.zeros((self.max_batch, 1), jnp.int32)

    # -------------------------------------------------- prefix caching ----
    def register_prefix(self, key: str, prefix_text_or_ids) -> int:
        """Prefill a shared prompt prefix ONCE and cache the result.
        Returns the prefix length in tokens. Requests whose prompt starts
        with these ids (pass ``prefix_key=key``) skip the prefix prefill.
        Text prefixes are encoded as <bos> + tokens (no <eos>) so they
        concatenate with the rest of the prompt; split at whitespace.

        Prefixes longer than the attention q-chunk are prefilled on
        their chunk-aligned head and decode-extended over the tail (the
        prefill path requires Sq % attn_chunk == 0 above one chunk)."""
        from repro.common.perf import get_flags
        ids = ([SPECIALS["<bos>"]] + TOKENIZER.encode(prefix_text_or_ids)
               if isinstance(prefix_text_or_ids, str)
               else list(prefix_text_or_ids))
        assert len(ids) < self.cache_len, (len(ids), self.cache_len)
        align = get_flags().attn_chunk
        head = (ids if len(ids) <= align
                else ids[:(len(ids) // align) * align])
        prompt = jnp.asarray(head, jnp.int32)[None, :]
        logits, cache = self._prefill(self.params, {"tokens": prompt})
        self.stats["prefills"] += 1
        self.stats["prefix_registrations"] += 1
        cache = dict(cache)
        cache["pos"] = jnp.asarray(len(head), jnp.int32)
        logits, cache = self._decode_through(logits, cache,
                                             ids[len(head):])
        self.prefixes[key] = CachedPrefix(ids, cache, logits)
        return len(ids)

    def _decode_through(self, logits, cache, tokens: List[int]
                        ) -> Tuple[jnp.ndarray, dict]:
        """Advance a B=1 cache through new tokens. Uses multi-token
        ``prefill_extend`` calls when the stack supports them (chunked
        prefill: whole attn_chunk slabs, then one bucket-padded call for
        the remainder so jit retraces O(log n) shapes); falls back to
        token-by-token decode otherwise. Returns (last-token logits
        (1,V), extended cache)."""
        from repro.common.perf import get_flags
        toks = list(tokens)
        if not toks:
            return logits, cache
        if not self._can_extend:
            for t in toks:
                logits, cache = self._decode(
                    self.params, cache, {"tokens": jnp.asarray(
                        [[t]], jnp.int32)})
            return logits, cache
        align = get_flags().attn_chunk
        i = 0
        while len(toks) - i >= align:
            chunk = jnp.asarray(toks[i:i + align], jnp.int32)[None]
            logits, cache = self._extend(self.params, cache,
                                         {"tokens": chunk}, align)
            i += align
        rest = toks[i:]
        if rest:
            n = len(rest)
            # pad rows are written at [pos+n, pos+width); cap width at
            # the cache end — dynamic_update_slice would otherwise CLAMP
            # the start index and silently overwrite valid prefix rows
            room = self.cache_len - int(cache["pos"])
            if self._pad_extend and n < room:
                width = min(1 << (n - 1).bit_length(), room)
                rest = rest + [0] * (width - n)
            chunk = jnp.asarray(rest, jnp.int32)[None]
            logits, cache = self._extend(self.params, cache,
                                         {"tokens": chunk}, n)
        return logits, cache

    def _extend_prefix(self, pref: CachedPrefix, suffix: List[int]
                       ) -> Tuple[jnp.ndarray, dict]:
        """Advance a cached prefix cache through the suffix tokens."""
        cache = {"segments": pref.cache["segments"],
                 "pos": pref.cache["pos"]}
        return self._decode_through(pref.logits, cache, suffix)

    # ------------------------------------------------------- sessions ----
    def open_session(self, prefix_key: Optional[str] = None,
                     session_id: Optional[int] = None) -> "EngineSession":
        """``session_id`` defaults to an engine-local counter; a cluster
        passes its own cluster-unique ids so sessions on different
        replicas never collide (request ids are engine-local)."""
        if session_id is None:
            session_id = self._next_session
            self._next_session += 1
        return EngineSession(self, session_id, prefix_key)

    # ---------------------------------------------------- scheduling ----
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _request_key(self, req: Request, engine_key):
        """Sampling key for the request's next token. Engine-stream by
        default (``engine_key`` was split off ``self.rng`` either way,
        so seeded requests never perturb their neighbours' streams);
        per-request fold_in stream when the sampler carries a seed."""
        if req.sampler.seed is None:
            return engine_key
        return jax.random.fold_in(jax.random.PRNGKey(req.sampler.seed),
                                  len(req.output))

    def _admit(self) -> List[Request]:
        """Prefill queued requests into free slots; returns the ones
        whose admission token was already terminal (they never occupy a
        slot — the slot stays open for the next queued request)."""
        finished: List[Request] = []
        free = deque(self._free_slots())
        while free and self.queue:
            slot = free[0]
            req = self.queue.popleft()
            self.stats["admissions"] += 1
            pref = (self.prefixes.get(req.prefix_key)
                    if req.prefix_key else None)
            if pref is not None and len(req.prompt) > len(pref.ids) and \
                    len(req.prompt) < self.cache_len and \
                    req.prompt[:len(pref.ids)] == pref.ids:
                logits, cache1 = self._extend_prefix(
                    pref, req.prompt[len(pref.ids):])
                self.stats["prefix_hits"] += 1
                self.stats["prefix_tokens_saved"] += len(pref.ids)
            else:
                prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
                logits, cache1 = self._prefill(self.params,
                                               {"tokens": prompt})
                self.stats["prefills"] += 1
                cache1 = dict(cache1)
            self.rng, k = jax.random.split(self.rng)
            tok = int(sample(logits, self._request_key(req, k),
                             req.sampler)[0])
            req.output.append(tok)
            req.first_token_t = time.time()
            if tok == SPECIALS["<eos>"] or \
                    len(req.output) >= req.max_new_tokens:
                # terminal at admission: an <eos> first token, or a
                # max_new_tokens=1 budget — never decode past it
                req.done = True
                req.finish_reason = ("eos" if tok == SPECIALS["<eos>"]
                                     else "max_new_tokens")
                req.finish_t = time.time()
                finished.append(req)
                continue
            free.popleft()
            self.cache = _insert_slot(self.cache, cache1, slot)
            self.cache["pos"] = self.cache["pos"].at[slot].set(
                len(req.prompt))
            self.slots[slot] = req
            self._last_tokens = self._last_tokens.at[slot, 0].set(tok)
        return finished

    def step(self) -> List[Request]:
        """One engine iteration: admit from queue, decode one token for
        every active slot. Returns newly finished requests (including
        any that terminated on their admission token)."""
        finished = self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return finished
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": self._last_tokens})
        self.stats["decode_steps"] += 1
        # per-slot sampling: each slot draws its own engine-stream key,
        # unless the request carries a per-request seed (_request_key)
        for i in active:
            req = self.slots[i]
            self.rng, ki = jax.random.split(self.rng)
            tok = int(sample(logits[i:i + 1], self._request_key(req, ki),
                             req.sampler)[0])
            req.output.append(tok)
            self.stats["tokens_generated"] += 1
            self._last_tokens = self._last_tokens.at[i, 0].set(tok)
            hit_cap = len(req.output) >= req.max_new_tokens
            hit_len = int(self.cache["pos"][i]) + 1 >= self.cache_len - 1
            if tok == SPECIALS["<eos>"] or hit_cap or hit_len:
                req.done = True
                req.finish_reason = ("eos" if tok == SPECIALS["<eos>"]
                                     else "max_new_tokens" if hit_cap
                                     else "cache_len")
                req.finish_t = time.time()
                finished.append(req)
                self.slots[i] = None
                self.cache["pos"] = self.cache["pos"].at[i].set(0)
        return finished

    def run_until_done(self, max_iters: int = 10_000) -> List[Request]:
        done: List[Request] = []
        it = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and it < max_iters:
            done.extend(self.step())
            it += 1
        return done

    def throughput_stats(self) -> Dict[str, float]:
        return dict(self.stats)


@dataclass
class EngineSession:
    """One Copilot conversation multiplexed over the engine's slots.

    Each planner/gate turn becomes one engine request tagged with the
    session's ``prefix_key`` (its gated intent), so every turn of every
    session sharing an intent reuses the same cached system-prompt
    prefill. Turns from many sessions interleave freely in the slot pool
    — the engine does not reserve a slot per session.
    """
    engine: InferenceEngine
    session_id: int
    prefix_key: Optional[str] = None
    pending: List[int] = field(default_factory=list)
    turns: List[Request] = field(default_factory=list)

    def submit_turn(self, text: str, max_new_tokens: int = 16,
                    sampler: SamplerConfig = SamplerConfig()) -> int:
        rid = self.engine.add_request(text, max_new_tokens, sampler,
                                      prefix_key=self.prefix_key,
                                      session_id=self.session_id)
        self.pending.append(rid)
        return rid

    def collect(self, finished: List[Request]) -> List[Request]:
        """Claim this session's turns from an engine ``step`` result.
        Matches on (session_id, request_id): a cluster merges finished
        lists from many replicas, and request ids are only engine-local."""
        mine = [r for r in finished if r.session_id == self.session_id
                and r.request_id in self.pending]
        for r in mine:
            self.pending.remove(r.request_id)
            self.turns.append(r)
        return mine

    @property
    def idle(self) -> bool:
        return not self.pending
