"""Inference engine: prefill + continuous-batching decode.

A fixed pool of ``max_batch`` slots shares one batched cache pytree with
per-sequence positions (the (B,) ``pos`` vector — decode writes use
one-hot masked updates so every slot can sit at a different fill level).
Requests are prefilled on arrival (B=1) and their caches inserted into a
free slot; one ``decode_step`` advances every active slot together.

This is the single-host engine the examples serve the planner with; the
distributed story (pjit over the production mesh) reuses exactly the same
step functions via launch/serve.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.models.model import decode_step, init_cache, prefill
from repro.serving.sampling import SamplerConfig, sample
from repro.serving.tokenizer import SPECIALS, TOKENIZER


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 32
    sampler: SamplerConfig = field(default_factory=SamplerConfig)
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    done: bool = False
    enqueue_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0


def _insert_slot(batched, single, slot: int):
    """Insert a B=1 cache pytree into slot `slot` of the batched cache.
    All cache leaves carry batch on axis 1 (stacked layer axis 0) except
    the (B,) pos vector."""
    def ins(b, s):
        if b.ndim >= 2 and s.shape[0] == b.shape[0] and s.ndim == b.ndim \
                and s.shape[1] == 1:
            return jax.lax.dynamic_update_slice_in_dim(b, s.astype(b.dtype),
                                                       slot, axis=1)
        return b
    out = jax.tree.map(ins, batched, single)
    return out


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 cache_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.rng = jax.random.PRNGKey(seed)
        self.cache = init_cache(cfg, max_batch, cache_len)
        self.cache["pos"] = jnp.zeros((max_batch,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self._next_id = 0
        self.stats = {"decode_steps": 0, "prefills": 0,
                      "tokens_generated": 0}

        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, cache_len=cache_len))
        self._decode = jax.jit(lambda p, c, b: decode_step(p, cfg, c, b))
        self._last_tokens = jnp.zeros((max_batch, 1), jnp.int32)

    # ------------------------------------------------------------- API ----
    def add_request(self, prompt_text_or_ids, max_new_tokens: int = 32,
                    sampler: SamplerConfig = SamplerConfig()) -> int:
        ids = (TOKENIZER.encode_with_specials(prompt_text_or_ids)
               if isinstance(prompt_text_or_ids, str)
               else list(prompt_text_or_ids))
        req = Request(self._next_id, ids, max_new_tokens, sampler,
                      enqueue_t=time.time())
        self._next_id += 1
        self.queue.append(req)
        return req.request_id

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache1 = self._prefill(self.params,
                                           {"tokens": prompt})
            self.stats["prefills"] += 1
            self.rng, k = jax.random.split(self.rng)
            tok = sample(logits, k, req.sampler)
            req.output.append(int(tok[0]))
            req.first_token_t = time.time()
            cache1 = dict(cache1)
            cache1["pos"] = jnp.asarray([len(req.prompt)], jnp.int32)
            self.cache = _insert_slot(self.cache, cache1, slot)
            self.cache["pos"] = self.cache["pos"].at[slot].set(
                len(req.prompt))
            self.slots[slot] = req
            self._last_tokens = self._last_tokens.at[slot, 0].set(tok[0])

    def step(self) -> List[Request]:
        """One engine iteration: admit from queue, decode one token for
        every active slot. Returns newly finished requests."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        finished: List[Request] = []
        if not active:
            return finished
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": self._last_tokens})
        self.stats["decode_steps"] += 1
        self.rng, k = jax.random.split(self.rng)
        # per-slot samplers may differ; sample with the pool max config
        for i in active:
            req = self.slots[i]
            self.rng, ki = jax.random.split(self.rng)
            tok = int(sample(logits[i:i + 1], ki, req.sampler)[0])
            req.output.append(tok)
            self.stats["tokens_generated"] += 1
            self._last_tokens = self._last_tokens.at[i, 0].set(tok)
            hit_cap = len(req.output) >= req.max_new_tokens
            hit_len = int(self.cache["pos"][i]) + 1 >= self.cache_len - 1
            if tok == SPECIALS["<eos>"] or hit_cap or hit_len:
                req.done = True
                req.finish_t = time.time()
                finished.append(req)
                self.slots[i] = None
                self.cache["pos"] = self.cache["pos"].at[i].set(0)
        return finished

    def run_until_done(self, max_iters: int = 10_000) -> List[Request]:
        done: List[Request] = []
        it = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and it < max_iters:
            done.extend(self.step())
            it += 1
        return done

    def throughput_stats(self) -> Dict[str, float]:
        return dict(self.stats)
