"""Inference engine: prefill + continuous-batching decode.

A fixed pool of ``max_batch`` slots shares one batched cache pytree with
per-sequence positions (the (B,) ``pos`` vector — decode writes use
one-hot masked updates so every slot can sit at a different fill level).
Requests are prefilled on arrival (B=1) and their caches inserted into a
free slot; one ``decode_step`` advances every active slot together.

Two serving-pipeline extensions (see DESIGN.md §Pipeline concurrency):

  * **prompt-prefix caching** — ``register_prefix`` prefills a shared
    prompt prefix (e.g. the gated system prompt of one GeckOpt intent)
    once; requests tagged with that ``prefix_key`` reuse the cached
    prefill and only extend it with their suffix tokens, instead of
    recomputing the full prefix per slot;
  * **sessions** — ``open_session`` returns an ``EngineSession`` that
    multiplexes the turns of one Copilot conversation over the shared
    continuous-batching slots (each turn is one request tagged with the
    session's intent prefix).

This is the single-host engine the examples serve the planner with; the
distributed story (pjit over the production mesh) reuses exactly the same
step functions via launch/serve.py.

``kv_mode`` selects the KV-cache memory manager:

  * ``"dense"`` (default) — one (max_batch, cache_len) slab; admission
    physically copies the request's prefill (and any cached prefix)
    into its slot;
  * ``"paged"``  — a fixed budget of ``kv_blocks`` blocks of
    ``block_size`` rows (serving/kvpool.py) with per-slot block tables:
    a registered prefix's blocks are CoW-shared by every admission
    (refcount++, zero copies), admission is gated on free blocks, cold
    prefix pins are LRU-evicted under pressure and the lowest-priority
    running request is preempted-and-requeued (bit-exact swap) instead
    of dropped. Dense and paged decode are bitwise identical
    (DESIGN.md §Paged KV cache).

``spec_decode`` (a ``serving/specdec.py`` SpecConfig) turns on
draft–verify speculative decoding: every step drafts K greedy tokens
per slot with a cheap draft model and verifies them in ONE target
``verify_extend`` forward, emitting 1..K+1 tokens per slot — bitwise
identical to non-speculative decoding (T=0 always; any temperature for
seeded requests), in both kv modes (DESIGN.md §Speculative decoding).

``backend`` selects the kernel backend (kernels/backend.py) for every
jitted step — ``"pallas"`` routes prefill/extend attention through
flash_prefill, the continuous-batching decode through flash_decode (per
slot (B,) fill levels via scalar prefetch), MoE routing through the
fused top-k kernel and SSM/mLSTM state scans through their Pallas
kernels; ``"reference"`` (the default) keeps the pure-jnp paths.
DESIGN.md §Kernel backends has the selection rules and parity contract.

``prefill_budget`` makes admission-time prefill PREEMPTIBLE: instead of
running a request's whole prefill inside its admission step, the engine
splits it into ``attn_chunk``-aligned slabs (the ``prefill_extend``
machinery — bitwise identical to monolithic prefill at any seam) and
spends at most ``max(1, prefill_budget // attn_chunk)`` slabs per step
across all in-flight prefills, interleaved with decode — so one long
prompt no longer stalls every co-resident stream. ``interleave=False``
keeps the budgeted cost model but runs prefill to completion (decode
stalls while any prefill is pending) — the run-to-completion baseline
the benches compare against. ``admission`` selects the queue order:
``"fifo"`` (arrival) or ``"slack"`` (earliest SLA deadline first, and
most-slack-first preemption victims). DESIGN.md §Stall-free scheduling.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig, WINDOW_KINDS
from repro.kernels.ref import paged_gather_kv
from repro.models.model import (decode_step, init_cache, init_paged_cache,
                                prefill, prefill_extend, verify_extend)
from repro.obs import MetricsRegistry, NULL_TRACER, StatsView
from repro.serving.kvpool import BlockTable, KVBlockPool
from repro.serving.sampling import SamplerConfig, sample
from repro.serving.sched import AdmissionQueue, deadline_step, victim_key
from repro.serving.specdec import SpecConfig, SpecDecoder, check_spec_stack
from repro.serving.tokenizer import SPECIALS, TOKENIZER

KV_MODES = ("dense", "paged")

# The engine's counter surface (the legacy ``engine.stats`` keys), now a
# StatsView over the obs metrics registry. Semantics:
#   decode_steps/prefills/tokens_generated — forward counts (decode_steps
#     counts TARGET forwards, also under spec decode);
#   prefix_* — prompt-prefix cache traffic (hits, tokens saved,
#     registrations, LRU pin evictions);
#   admissions/preemptions/resumes — slot lifecycle;
#   prefill_chunks/stall_ticks/sla_expired — stall-free scheduling:
#     chunked-prefill slabs run, decode ticks skipped behind pending
#     prefills (interleave=False only), queued requests dropped past
#     their SLA deadline;
#   spec_rounds/spec_drafted/spec_accepted — speculative decoding (zero
#     when disabled): rounds = verify forwards, drafted/accepted = draft
#     token counts (accept rate = their ratio).
# The reset-audit test (tests/test_obs.py) pins this tuple against
# engine.reset() so new counters can't silently leak across runs.
ENGINE_STAT_KEYS = (
    "decode_steps", "prefills", "tokens_generated", "prefix_hits",
    "prefix_tokens_saved", "admissions", "prefix_registrations",
    "preemptions", "resumes", "prefix_evictions", "prefill_chunks",
    "stall_ticks", "sla_expired", "spec_rounds", "spec_drafted",
    "spec_accepted")


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 32
    sampler: SamplerConfig = field(default_factory=SamplerConfig)
    prefix_key: Optional[str] = None
    session_id: Optional[int] = None
    # SLA deadline budget in engine steps (ticks) from enqueue; None =
    # no deadline. Drives slack admission order and queued-expiry drops.
    sla_ticks: Optional[int] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    done: bool = False
    # "eos" | "max_new_tokens" | "cache_len" | "kv_oom" (paged: the
    # request can never fit the physical block budget) | "sla_expired"
    # (deadline passed while still queued — dropped, never admitted)
    finish_reason: Optional[str] = None
    enqueue_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0
    # tick-based latency stamps (engine step numbers; the cluster's tick
    # clock advances in lockstep, so these ARE cluster ticks). The wall
    # times above come from the injected clock and stay 0.0 under the
    # deterministic zero clock; the step stamps always advance.
    enqueue_step: int = 0
    admit_step: Optional[int] = None
    first_token_step: Optional[int] = None
    finish_step: Optional[int] = None
    # paged preemption: host-side copy of the KV rows generated so far
    # ({"segments": ..., "pos": n}); set while the request sits requeued
    swap: Optional[dict] = None


@dataclass
class CachedPrefix:
    ids: List[int]
    cache: dict          # B=1 prefilled cache pytree (scalar pos)
    logits: jnp.ndarray  # (1,V) logits after the prefix's last token


@dataclass
class PendingPrefill:
    """An admission whose prefill is in flight under ``prefill_budget``:
    the request owns its slot (and, paged, its block table) from
    admission, but its B=1 cache advances one budgeted chunk at a time
    across engine steps instead of monolithically inside one step. The
    first token is sampled — and the cache installed into the batched
    slot — only when the last chunk lands."""
    req: Request
    slot: int
    toks: List[int]                  # full prompt ids
    i: int                           # ids already in the cache
    logits: Optional[jnp.ndarray]    # (1,V) after toks[:i]; None pre-head
    cache: Optional[dict]            # B=1 cache pytree; None pre-head
    table: Optional[BlockTable]      # paged: blocks held from admission
    j0: int                          # paged: shared-prefix scatter skip


def _insert_slot(batched, single, slot: int):
    """Insert a B=1 cache pytree into slot `slot` of the batched cache.
    All cache leaves carry batch on axis 1 (stacked layer axis 0) except
    the (B,) pos vector."""
    def ins(b, s):
        if b.ndim >= 2 and s.shape[0] == b.shape[0] and s.ndim == b.ndim \
                and s.shape[1] == 1:
            return jax.lax.dynamic_update_slice_in_dim(b, s.astype(b.dtype),
                                                       slot, axis=1)
        return b
    out = jax.tree.map(ins, batched, single)
    return out


@jax.jit
def _paged_scatter(segments, single_segments, ids):
    """Scatter a B=1 cache's rows into pool blocks.

    ``segments``: paged pools, leaves (R, n_blocks, Hkv, bs, hd);
    ``single_segments``: a prefill/extend result, leaves
    (R, 1, Hkv, mb*bs, hd); ``ids``: (mb,) int32 destination block per
    logical block — entries >= n_blocks (the sentinel) are dropped, so
    one trace serves any row range (shared prefix blocks are skipped by
    sentinel-masking their logical indices)."""
    def ins(pages, s):
        R, nb, Hkv, bs, hd = pages.shape
        mb = s.shape[3] // bs
        upd = s[:, 0].reshape(R, Hkv, mb, bs, hd).transpose(0, 2, 1, 3, 4)
        return pages.at[:, ids].set(upd.astype(pages.dtype), mode="drop")
    return jax.tree.map(ins, segments, single_segments)


@jax.jit
def _paged_gather(segments, ids):
    """Gather one sequence's logical rows out of the pools as a B=1
    cache pytree (the swap-out payload of preemption; bit-exact, so a
    resumed request decodes as if never interrupted). ``ids``: (mb,)
    block ids, clip-padded — rows past the table are garbage and are
    never scattered back. Always full logical width: one trace for any
    fill level, paid only on the rare preemption path. The gather
    itself is kernels.ref.paged_gather_kv, vmapped over the stacked
    layer axis — one clip/sentinel rule for every paged read."""
    def g(pages):
        return jax.vmap(lambda p: paged_gather_kv(p, ids[None]))(pages)
    return jax.tree.map(g, segments)


def advance_cache_through(params, logits, cache, tokens, *, decode_fn,
                          extend_fn, can_extend: bool, pad_extend: bool,
                          cache_len: int):
    """Advance a B=1 cache through new tokens. Uses multi-token
    ``prefill_extend`` calls when the stack supports them (chunked
    prefill: whole attn_chunk slabs, then one bucket-padded call for
    the remainder so jit retraces O(log n) shapes); falls back to
    token-by-token decode otherwise. Returns (last-token logits (1,V),
    extended cache). Shared by the engine's prefix cache and the
    speculative-decode draft admissions (serving/specdec.py)."""
    from repro.common.perf import get_flags
    toks = list(tokens)
    if not toks:
        return logits, cache
    if not can_extend:
        for t in toks:
            logits, cache = decode_fn(
                params, cache, {"tokens": jnp.asarray([[t]], jnp.int32)})
        return logits, cache
    align = get_flags().attn_chunk
    i = 0
    while len(toks) - i >= align:
        chunk = jnp.asarray(toks[i:i + align], jnp.int32)[None]
        logits, cache = extend_fn(params, cache, {"tokens": chunk}, align)
        i += align
    rest = toks[i:]
    if rest:
        n = len(rest)
        # pad rows are written at [pos+n, pos+width); cap width at
        # the cache end — dynamic_update_slice would otherwise CLAMP
        # the start index and silently overwrite valid prefix rows
        room = cache_len - int(cache["pos"])
        if pad_extend and n < room:
            width = min(1 << (n - 1).bit_length(), room)
            rest = rest + [0] * (width - n)
        chunk = jnp.asarray(rest, jnp.int32)[None]
        logits, cache = extend_fn(params, cache, {"tokens": chunk}, n)
    return logits, cache


def _kv_cache_bytes(segments) -> int:
    """Total bytes of the KV leaves (k/v and cross-attention ck/cv) in a
    cache pytree's segments."""
    total = 0
    for seg in segments:
        for c in seg:
            for key in ("k", "v", "ck", "cv"):
                if isinstance(c, dict) and key in c:
                    leaf = c[key]
                    total += int(leaf.size) * leaf.dtype.itemsize
    return total


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 cache_len: int = 512, seed: int = 0,
                 backend: Optional[str] = None, kv_mode: str = "dense",
                 kv_blocks: Optional[int] = None,
                 block_size: Optional[int] = None,
                 spec_decode: Optional[SpecConfig] = None,
                 prefill_budget: Optional[int] = None,
                 interleave: bool = True,
                 admission: str = "fifo",
                 clock: Optional[Callable[[], float]] = None,
                 tracer=None, metrics: Optional[MetricsRegistry] = None):
        from repro.kernels.backend import get_backend
        self.cfg = cfg
        self.params = params
        # Request latency timestamps (enqueue/first-token/finish) come
        # from an *injected* clock; the engine itself never reads the
        # wall clock, so runs are reproducible by construction. The
        # live-serve launcher passes time.time; ticks/tests keep the
        # zero clock (timestamps all 0.0, TTFT math is tick-based).
        self._clock: Callable[[], float] = clock or (lambda: 0.0)
        # Observability (repro.obs) is injected like the clock. The
        # default NullTracer records nothing and tracing never branches
        # control flow, so tokens are bitwise identical tracer on/off;
        # a cluster passes one shared registry scoped per replica.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # wall time in traces is opt-in: only an *injected* clock is
        # ever bound — the deterministic zero clock is not a wall clock
        self.tracer.bind_clock(clock)
        # exporter track group; the cluster overwrites this with the
        # replica index so traces are keyed (replica, slot)
        self.trace_group: int = 0
        # request_id -> open "request" lifecycle span handle
        self._req_spans: Dict[int, int] = {}
        self.max_batch = max_batch
        self.cache_len = cache_len
        # resolve once so every jitted step traces one fixed backend
        self.backend = get_backend(backend).name
        self.seed = seed
        self.rng = jax.random.PRNGKey(seed)
        if kv_mode not in KV_MODES:
            raise ValueError(f"kv_mode must be one of {KV_MODES}, "
                             f"got {kv_mode!r}")
        self.kv_mode = kv_mode
        kinds = {k for unit, _ in cfg.segments for k in unit}
        if kv_mode == "paged":
            self.block_size = 16 if block_size is None else block_size
            bs = self.block_size
            if not kinds <= {"full", "dense", "moe"}:
                raise ValueError(
                    f"kv_mode='paged' needs a pure-attention stack "
                    f"(full/dense/moe), got kinds {sorted(kinds)}")
            if cache_len % bs:
                raise ValueError(f"cache_len {cache_len} must be a "
                                 f"multiple of block_size {bs}")
            # default physical budget: exactly the dense reservation
            self.kv_blocks = (kv_blocks if kv_blocks is not None
                              else max_batch * cache_len // bs)
            self.pool = KVBlockPool(self.kv_blocks, bs,
                                    metrics=self.metrics)
            self.cache = init_paged_cache(cfg, max_batch, cache_len,
                                          self.kv_blocks, bs)
            self.tables: List[Optional[BlockTable]] = [None] * max_batch
            self._prefix_tables: Dict[str, BlockTable] = {}
            self._prefix_lru: Dict[str, int] = {}
            self._lru_tick = 0
        else:
            if kv_blocks is not None or block_size is not None:
                # mirror EngineCluster's refusal of sizing kwargs that
                # would be silently dropped
                raise ValueError(
                    "kv_blocks/block_size apply only to "
                    "kv_mode='paged'")
            self.block_size = 0
            self.kv_blocks = 0
            self.pool = None
            self.cache = init_cache(cfg, max_batch, cache_len)
            self.cache["pos"] = jnp.zeros((max_batch,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        # admission order is a policy now (serving/sched.py): "fifo"
        # keeps the seed deque behavior, "slack" admits by SLA deadline
        self.admission = admission
        self.queue: AdmissionQueue = AdmissionQueue(admission,
                                                    metrics=self.metrics)
        self.interleave = interleave
        self.prefill_budget = prefill_budget
        # engine step counter — the tick clock every latency stamp
        # (enqueue/admit/first-token/finish steps) is expressed in
        self.step_no = 0
        # slot -> in-flight chunked prefill; _pending_rr is the
        # deficit-round-robin service order the per-step chunk
        # allowance rotates over (one chunk per turn), so a short
        # prompt drains past a long one instead of queuing behind its
        # whole prefill, and nothing starves
        self._pending: Dict[int, PendingPrefill] = {}
        self._pending_rr: deque = deque()
        self.prefixes: Dict[str, CachedPrefix] = {}
        self._next_id = 0
        self._next_session = 0
        # dict-compatible view over registry counters: same keys and
        # mapping surface as the ad-hoc dict it replaced (ENGINE_STAT_KEYS
        # documents each key), one storage for all of them
        self.stats = StatsView(self.metrics, ENGINE_STAT_KEYS)
        self._kv_bytes_total = _kv_cache_bytes(self.cache["segments"])
        self._kv_peak_blocks = 0       # paged: peak pool blocks in use
        self._kv_peak_shared = 0       # paged: peak CoW-shared blocks
        self._kv_peak_slots = 0        # dense: peak busy slots

        be = self.backend
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, cache_len=cache_len,
                                 backend=be))
        self._decode = jax.jit(
            lambda p, c, b: decode_step(p, cfg, c, b, backend=be))
        self._extend = jax.jit(
            lambda p, c, b, n: prefill_extend(p, cfg, c, b, n_valid=n,
                                              backend=be))
        # multi-token cache extension: no ring buffers / cross-attention;
        # bucket-padded extends additionally require a stateless
        # (pure-attention) stack — recurrent state would step through pads
        self._can_extend = (not (kinds & set(WINDOW_KINDS))
                            and "encdec" not in kinds
                            and not cfg.n_enc_layers)
        self._pad_extend = (self._can_extend
                            and kinds <= {"full", "dense", "moe"})
        if prefill_budget is not None:
            if prefill_budget < 1:
                raise ValueError(f"prefill_budget must be >= 1 token "
                                 f"per step, got {prefill_budget}")
            if not self._can_extend:
                raise ValueError(
                    "prefill_budget (chunked prefill) needs a stack "
                    "that supports multi-token prefill_extend — no "
                    "windowed/recurrent kinds and no encoder; got "
                    f"kinds {sorted(kinds)}")
        self._last_tokens = jnp.zeros((max_batch, 1), jnp.int32)

        # speculative decoding: draft K cheap tokens per slot, verify
        # them in ONE target forward (serving/specdec.py; the emitted
        # stream is bitwise identical to non-speculative decoding)
        self.spec: Optional[SpecDecoder] = None
        self._verify = None
        if spec_decode is not None:
            check_spec_stack(cfg, "target model")
            self.spec = SpecDecoder(spec_decode, max_batch=max_batch,
                                    cache_len=cache_len,
                                    backend=self.backend,
                                    metrics=self.metrics)
            self._verify = jax.jit(
                lambda p, c, b: verify_extend(p, cfg, c, b, backend=be))

    # ------------------------------------------------------------- API ----
    def add_request(self, prompt_text_or_ids, max_new_tokens: int = 32,
                    sampler: SamplerConfig = SamplerConfig(),
                    prefix_key: Optional[str] = None,
                    session_id: Optional[int] = None,
                    sla_ticks: Optional[int] = None) -> int:
        ids = (TOKENIZER.encode_with_specials(prompt_text_or_ids)
               if isinstance(prompt_text_or_ids, str)
               else list(prompt_text_or_ids))
        req = Request(self._next_id, ids, max_new_tokens, sampler,
                      prefix_key=prefix_key, session_id=session_id,
                      sla_ticks=sla_ticks, enqueue_t=self._clock(),
                      enqueue_step=self.step_no)
        self._next_id += 1
        if self.tracer.enabled:
            self.tracer.event("enqueue", tick=self.step_no,
                              group=self.trace_group, lane="queue",
                              request=req.request_id,
                              prompt_tokens=len(ids))
        self.queue.push(req)
        return req.request_id

    # ----------------------------------------------- load introspection ----
    # (the cluster router reads these to place requests; serving/cluster.py)
    def busy_slots(self) -> int:
        return sum(s is not None for s in self.slots)

    def free_slot_count(self) -> int:
        return self.max_batch - self.busy_slots()

    def queue_depth(self) -> int:
        return len(self.queue)

    def load(self) -> int:
        """In-flight work: occupied slots plus queued requests."""
        return self.busy_slots() + len(self.queue)

    def is_idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)

    @property
    def spec_k(self) -> int:
        """Draft tokens per speculative round (0 = spec decode off)."""
        return self.spec.k if self.spec is not None else 0

    def reset(self, seed: Optional[int] = None):
        """Return the engine to its just-constructed state (drain and
        recycle a cluster replica between workloads). Cache storage is
        reused — stale rows are masked by the zeroed ``pos`` vector and
        overwritten at the next admission; jitted step functions are
        kept, so a reset engine serves warm."""
        if seed is not None:
            self.seed = seed
        self.rng = jax.random.PRNGKey(self.seed)
        # one sweep zeroes every registry-backed metric this engine
        # publishes (stats view, queue, pool, spec); a shared-registry
        # facade zeroes only this engine's slice. The tracer is NOT
        # cleared: a trace is a session log — spans carry their ticks,
        # and a mid-flight reset abandons the open request spans.
        self.metrics.reset()
        self._req_spans.clear()
        self.cache["pos"] = jnp.zeros((self.max_batch,), jnp.int32)
        if self.kv_mode == "paged":
            self.pool = KVBlockPool(self.kv_blocks, self.block_size,
                                    metrics=self.metrics)
            self.tables = [None] * self.max_batch
            self._prefix_tables = {}
            self._prefix_lru = {}
            self._lru_tick = 0
            self.cache["block_tab"] = jnp.full(
                (self.max_batch, self.cache_len // self.block_size),
                self.kv_blocks, jnp.int32)
        self.slots = [None] * self.max_batch
        self.queue.clear()
        self._pending.clear()
        self._pending_rr.clear()
        self.step_no = 0
        self.prefixes.clear()
        self._next_id = 0
        self._next_session = 0
        self._kv_peak_blocks = 0
        self._kv_peak_shared = 0
        self._kv_peak_slots = 0
        self._last_tokens = jnp.zeros((self.max_batch, 1), jnp.int32)
        if self.spec is not None:
            self.spec.reset()

    # -------------------------------------------------- prefix caching ----
    def register_prefix(self, key: str, prefix_text_or_ids) -> int:
        """Prefill a shared prompt prefix ONCE and cache the result.
        Returns the prefix length in tokens. Requests whose prompt starts
        with these ids (pass ``prefix_key=key``) skip the prefix prefill.
        Text prefixes are encoded as <bos> + tokens (no <eos>) so they
        concatenate with the rest of the prompt; split at whitespace.

        Prefixes longer than the attention q-chunk are prefilled on
        their chunk-aligned head and decode-extended over the tail (the
        prefill path requires Sq % attn_chunk == 0 above one chunk)."""
        from repro.common.perf import get_flags
        ids = ([SPECIALS["<bos>"]] + TOKENIZER.encode(prefix_text_or_ids)
               if isinstance(prefix_text_or_ids, str)
               else list(prefix_text_or_ids))
        assert len(ids) < self.cache_len, (len(ids), self.cache_len)
        align = get_flags().attn_chunk
        head = (ids if len(ids) <= align
                else ids[:(len(ids) // align) * align])
        prompt = jnp.asarray(head, jnp.int32)[None, :]
        logits, cache = self._prefill(self.params, {"tokens": prompt})
        self.stats["prefills"] += 1
        self.stats["prefix_registrations"] += 1
        cache = dict(cache)
        cache["pos"] = jnp.asarray(len(head), jnp.int32)
        logits, cache = self._decode_through(logits, cache,
                                             ids[len(head):])
        self.prefixes[key] = CachedPrefix(ids, cache, logits)
        if self.kv_mode == "paged":
            self._pin_prefix(key, self.prefixes[key])
        return len(ids)

    def _decode_through(self, logits, cache, tokens: List[int]
                        ) -> Tuple[jnp.ndarray, dict]:
        """Advance a B=1 cache through new tokens (see
        ``advance_cache_through``)."""
        return advance_cache_through(
            self.params, logits, cache, tokens, decode_fn=self._decode,
            extend_fn=self._extend, can_extend=self._can_extend,
            pad_extend=self._pad_extend, cache_len=self.cache_len)

    def _extend_prefix(self, pref: CachedPrefix, suffix: List[int]
                       ) -> Tuple[jnp.ndarray, dict]:
        """Advance a cached prefix cache through the suffix tokens."""
        cache = {"segments": pref.cache["segments"],
                 "pos": pref.cache["pos"]}
        return self._decode_through(pref.logits, cache, suffix)

    # ------------------------------------------------ paged KV memory ----
    # Host-side policy over serving/kvpool.py: the pool owns block ids
    # and refcounts; the engine owns what is cold (LRU prefix pins) and
    # who is lowest priority (preempt the latest-admitted request).
    def _tab_ids(self, blocks: List[int], pad: int) -> np.ndarray:
        """A full (max_blocks,) table row: real block ids then ``pad``
        (the sentinel ``kv_blocks`` for drop-masked device rows, 0 for
        clip-safe gathers)."""
        ids = np.full((self.cache_len // self.block_size,), pad, np.int32)
        ids[:len(blocks)] = blocks
        return ids

    def _pin_prefix(self, key: str, pref: CachedPrefix):
        """Write the prefix's KV rows into pool blocks ONCE and pin them
        (an LRU-evictable hold). Every admission that hits the prefix
        forks this table — refcount++, zero copies — instead of copying
        the rows into its slot. If the pool cannot hold the prefix even
        after evicting colder pins, it stays unpinned: hits still reuse
        the staged prefill, they just scatter their own copy."""
        old = self._prefix_tables.pop(key, None)
        if old is not None:
            self._prefix_lru.pop(key, None)
            self.pool.free(old)
        need = self.pool.blocks_needed(len(pref.ids))
        if need > self.pool.n_blocks or not self._reserve(need):
            return
        table = self.pool.alloc(len(pref.ids))
        ids = self._tab_ids(table.blocks, self.kv_blocks)
        self.cache["segments"] = _paged_scatter(
            self.cache["segments"], pref.cache["segments"],
            jnp.asarray(ids))
        self._prefix_tables[key] = table
        self._touch_prefix(key)
        self._note_kv_peak()

    def _touch_prefix(self, key: str):
        self._prefix_lru[key] = self._lru_tick
        self._lru_tick += 1

    def _reserve(self, need: int, keep: Optional[str] = None) -> bool:
        """True once >= ``need`` blocks are free, evicting cold prefix
        pins (LRU; never ``keep`` — the pin an admission is about to
        fork) as required. Evicts only when eviction can actually
        satisfy the request: pins are never re-established (only
        register_prefix pins), so destroying them for an unsatisfiable
        reservation would end zero-copy sharing for nothing. Never
        touches running requests — that escalation (preemption) is
        _ensure_room's call."""
        if self.pool.free_blocks() >= need:
            return True
        # blocks an eviction sweep would actually free: a pin's
        # exclusively-held blocks (shared ones stay with their forks)
        gain = sum(1 for k, t in self._prefix_tables.items()
                   if k != keep
                   for b in t.blocks if self.pool.ref[b] == 1)
        if self.pool.free_blocks() + gain < need:
            return False
        while self.pool.free_blocks() < need \
                and self._evict_cold_prefix(keep):
            pass
        return self.pool.free_blocks() >= need

    def _evict_cold_prefix(self, keep: Optional[str] = None) -> bool:
        """Evict the LRU prefix pin among those whose eviction frees at
        least one block NOW (all-shared pins are in active use — their
        blocks return via their forks anyway, so destroying the pin
        would cost future sharing and gain nothing)."""
        candidates = [k for k, t in self._prefix_tables.items()
                      if k != keep
                      and any(self.pool.ref[b] == 1 for b in t.blocks)]
        if not candidates:
            return False
        key = min(candidates, key=self._prefix_lru.get)
        self.pool.free(self._prefix_tables.pop(key))
        del self._prefix_lru[key]
        self.stats["prefix_evictions"] += 1
        if self.tracer.enabled:
            self.tracer.event("kv_evict", tick=self.step_no,
                              group=self.trace_group, lane="kv",
                              prefix=key)
        return True

    def _install(self, slot: int, req: Request, table: BlockTable,
                 single_segments, scatter_from: int):
        """Bind (request, block table) to a slot: scatter the B=1 cache
        rows of logical blocks [scatter_from, len(table)) into the
        table's blocks (blocks below ``scatter_from`` are shared prefix
        blocks — already written at pin time, never copied), then point
        the device block-table row and pos at them."""
        ids = self._tab_ids(table.blocks, self.kv_blocks)
        scat = ids.copy()
        scat[:scatter_from] = self.kv_blocks
        self.cache["segments"] = _paged_scatter(
            self.cache["segments"], single_segments, jnp.asarray(scat))
        self.cache["block_tab"] = self.cache["block_tab"].at[slot].set(
            jnp.asarray(ids))
        self.cache["pos"] = self.cache["pos"].at[slot].set(table.n_tokens)
        self.slots[slot] = req
        self.tables[slot] = table
        self._note_kv_peak()

    def _release_slot(self, slot: int):
        """Free a paged slot's blocks and sentinel its table row."""
        self.pool.free(self.tables[slot])
        self.tables[slot] = None
        self.cache["block_tab"] = self.cache["block_tab"].at[slot].set(
            self.kv_blocks)

    def _preempt(self, slot: int):
        """Swap the slot's KV rows to host memory, free its blocks and
        requeue it at the queue head. The swap payload is bit-exact, so
        the resumed request decodes the same tokens it would have —
        sampler-seeded requests are provably unperturbed (their keys
        fold in len(output)); engine-stream requests see a different key
        schedule, exactly as any co-tenancy change does."""
        req = self.slots[slot]
        table = self.tables[slot]
        gather_ids = jnp.asarray(self._tab_ids(table.blocks, 0))
        segs = jax.tree.map(np.asarray,
                            _paged_gather(self.cache["segments"],
                                          gather_ids))
        # retain only the rows the request actually holds (.copy() so
        # the slice drops the full-width base buffer); the resume path
        # pads back to the logical width, keeping one scatter trace
        rows = len(table.blocks) * self.block_size
        segs = jax.tree.map(lambda a: a[:, :, :, :rows].copy(), segs)
        req.swap = {"segments": segs, "pos": table.n_tokens}
        self.slots[slot] = None
        self.cache["pos"] = self.cache["pos"].at[slot].set(0)
        self._release_slot(slot)
        # FIFO requeues at the head (the victim resumes before new
        # arrivals); slack mode re-competes by deadline
        self.queue.push(req, front=True)
        self.stats["preemptions"] += 1
        h = self._req_spans.pop(req.request_id, None)
        if h is not None:
            self.tracer.end(h, tick=self.step_no, preempted=True,
                            tokens=len(req.output))
        if self.tracer.enabled:
            self.tracer.event("preempt", tick=self.step_no,
                              group=self.trace_group, lane="queue",
                              request=req.request_id, slot=slot)

    def _finish_now(self, req: Request, reason: str):
        req.done = True
        req.finish_reason = reason
        req.finish_t = self._clock()
        req.finish_step = self.step_no
        if not req.first_token_t:
            # finished without ever sampling (paged cache_len/kv_oom
            # refusals, sla_expired drops): leave no 0.0 sentinel for
            # TTFT math downstream
            req.first_token_t = req.finish_t
        if req.first_token_step is None:
            req.first_token_step = req.finish_step
        h = self._req_spans.pop(req.request_id, None)
        if h is not None:
            self.tracer.end(h, tick=self.step_no, reason=reason,
                            tokens=len(req.output))
        elif self.tracer.enabled:
            # never admitted (sla_expired / paged up-front refusals):
            # no lifecycle span to close — mark the drop on the queue
            # lane instead
            self.tracer.event(
                "sla_expired" if reason == "sla_expired" else "finish",
                tick=self.step_no, group=self.trace_group, lane="queue",
                request=req.request_id, reason=reason)

    def _ensure_room(self, width: int = 1) -> List[Request]:
        """Pre-decode: every active slot must own blocks for the
        ``width`` rows it is about to write (1 per decode step, K+1 per
        speculative verify — rejected rows stay in blocks the slot
        already owns, so rollback never re-enters this path). Under
        memory pressure, escalate: evict cold prefix pins (inside
        _reserve), then preempt-and-requeue the lowest-priority running
        request — never drop it. "Lowest priority" is the admission
        policy's call (sched.victim_key): FIFO preempts the
        latest-admitted request (the seed rule), slack mode the one
        with the most deadline slack. Pending chunked prefills are
        neither growers (their blocks were sized at admission) nor
        victims (their KV lives host-side until install). A lone
        request that has outgrown the whole pool finishes with
        ``kv_oom`` (nothing left to preempt)."""
        finished: List[Request] = []
        for i in range(self.max_batch):
            if self.slots[i] is None or i in self._pending:
                continue
            table = self.tables[i]
            needed_rows = min(table.n_tokens + width, self.cache_len)
            blocked = False
            while (not blocked
                   and len(table.blocks) * self.block_size < needed_rows):
                if self._reserve(1):
                    j = len(table.blocks)
                    block = self.pool.append_block(table)
                    self.cache["block_tab"] = \
                        self.cache["block_tab"].at[i, j].set(block)
                    continue
                active = [j for j in range(self.max_batch)
                          if self.slots[j] is not None
                          and j not in self._pending]
                victim = max(active, key=lambda j: victim_key(
                    self.slots[j], self.admission))
                if victim == i and len(active) == 1:
                    req = self.slots[i]
                    self._finish_now(req, "kv_oom")
                    finished.append(req)
                    self.slots[i] = None
                    self.cache["pos"] = self.cache["pos"].at[i].set(0)
                    self._release_slot(i)
                    blocked = True
                    break
                self._preempt(victim)
                if victim == i:
                    blocked = True
        self._note_kv_peak()
        return finished

    def _note_kv_peak(self):
        if self.kv_mode == "paged":
            self._kv_peak_blocks = max(self._kv_peak_blocks,
                                       self.pool.used_blocks())
            self._kv_peak_shared = max(self._kv_peak_shared,
                                       self.pool.shared_blocks())
        else:
            self._kv_peak_slots = max(self._kv_peak_slots,
                                      self.busy_slots())

    # ------------------------------------------------------- sessions ----
    def open_session(self, prefix_key: Optional[str] = None,
                     session_id: Optional[int] = None) -> "EngineSession":
        """``session_id`` defaults to an engine-local counter; a cluster
        passes its own cluster-unique ids so sessions on different
        replicas never collide (request ids are engine-local)."""
        if session_id is None:
            session_id = self._next_session
            self._next_session += 1
        return EngineSession(self, session_id, prefix_key)

    # ---------------------------------------------------- scheduling ----
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _request_key(self, req: Request, engine_key):
        """Sampling key for the request's next token. Engine-stream by
        default (``engine_key`` was split off ``self.rng`` either way,
        so seeded requests never perturb their neighbours' streams);
        per-request fold_in stream when the sampler carries a seed."""
        if req.sampler.seed is None:
            return engine_key
        return jax.random.fold_in(jax.random.PRNGKey(req.sampler.seed),
                                  len(req.output))

    def _prefix_hit(self, req: Request) -> Optional[CachedPrefix]:
        """The cached prefix this request can extend, if any."""
        pref = (self.prefixes.get(req.prefix_key)
                if req.prefix_key else None)
        if pref is not None and len(req.prompt) > len(pref.ids) and \
                len(req.prompt) < self.cache_len and \
                req.prompt[:len(pref.ids)] == pref.ids:
            return pref
        return None

    _UNSET = object()

    def _prefill_request(self, req: Request, pref=_UNSET):
        """Compute a request's admission logits + B=1 cache — via the
        prefix cache when it hits, full prefill otherwise. ``pref``
        takes a precomputed ``_prefix_hit`` result (paged admission
        already needs it for the block math). Returns
        (logits, cache, hit_prefix_or_None)."""
        if pref is self._UNSET:
            pref = self._prefix_hit(req)
        if pref is not None:
            logits, cache1 = self._extend_prefix(
                pref, req.prompt[len(pref.ids):])
            self.stats["prefix_hits"] += 1
            self.stats["prefix_tokens_saved"] += len(pref.ids)
            return logits, cache1, pref
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1 = self._prefill(self.params, {"tokens": prompt})
        self.stats["prefills"] += 1
        return logits, dict(cache1), None

    def _trace_admit(self, req: Request, slot: int,
                     resumed: bool = False):
        """Open the request's lifecycle span on its slot lane (admit →
        finish/preempt). The paired instant on the queue lane marks the
        queue handoff; a resume opens a fresh span — one span per slot
        residency, so preempted requests show as separate segments."""
        if not self.tracer.enabled:
            return
        self.tracer.event("resume" if resumed else "admit",
                          tick=self.step_no, group=self.trace_group,
                          lane="queue", request=req.request_id,
                          slot=slot)
        self._req_spans[req.request_id] = self.tracer.begin(
            "request", tick=self.step_no, group=self.trace_group,
            lane=slot, request=req.request_id,
            prompt_tokens=len(req.prompt), resumed=resumed)

    def _first_token(self, req: Request, logits) -> bool:
        """Sample the admission token; True when it is terminal (an
        <eos> first token, or a max_new_tokens=1 budget — never decode
        past it; the request then never occupies a slot)."""
        self.rng, k = jax.random.split(self.rng)
        tok = int(sample(logits, self._request_key(req, k),
                         req.sampler)[0])
        req.output.append(tok)
        req.first_token_t = self._clock()
        req.first_token_step = self.step_no
        if self.tracer.enabled:
            h = self._req_spans.get(req.request_id)
            lane = (self.tracer.lane_of(h)
                    if h is not None else None)
            self.tracer.event("first_token", tick=self.step_no,
                              group=self.trace_group,
                              lane="queue" if lane is None else lane,
                              request=req.request_id)
        if tok == SPECIALS["<eos>"] or \
                len(req.output) >= req.max_new_tokens:
            self._finish_now(req, "eos" if tok == SPECIALS["<eos>"]
                             else "max_new_tokens")
            return True
        return False

    def _drop_expired(self) -> List[Request]:
        """Drop queue heads whose SLA deadline has already passed while
        waiting: admitting them would burn a slot (and, paged, KV
        blocks) on a guaranteed SLA miss. Only fresh requests are
        dropped — a preempted request (non-empty output) already holds
        generated tokens and always resumes. Deterministic: only the
        queue's own order and ``step_no`` decide."""
        dropped: List[Request] = []
        while self.queue:
            req = self.queue.peek()
            if req.output or self.step_no < deadline_step(req):
                break
            self.queue.pop()
            self._finish_now(req, "sla_expired")
            self.stats["sla_expired"] += 1
            dropped.append(req)
        return dropped

    def _admit(self) -> List[Request]:
        """Prefill queued requests into free slots (or, under
        ``prefill_budget``, start their chunked prefills); returns the
        ones whose admission token was already terminal plus any
        expired-in-queue drops."""
        if self.kv_mode == "paged":
            return self._admit_paged()
        finished: List[Request] = self._drop_expired()
        free = deque(self._free_slots())
        while free and self.queue:
            slot = free[0]
            req = self.queue.pop()
            if (self.spec is not None or self.prefill_budget is not None) and \
                    len(req.prompt) >= self.cache_len:
                # plain dense truncates the prefill and emits a token
                # or two before dying with "cache_len"; that clamped
                # overflow write cannot be reproduced by one verify
                # forward — or replayed chunk-by-chunk — so spec and
                # budget modes refuse up front (the paged semantics)
                self._finish_now(req, "cache_len")
                finished.append(req)
                continue
            self.stats["admissions"] += 1
            req.admit_step = self.step_no
            self._trace_admit(req, slot)
            if self.prefill_budget is not None:
                free.popleft()
                self._start_pending(slot, req, self._prefix_hit(req),
                                    None, 0)
                continue
            logits, cache1, _ = self._prefill_request(req)
            if self._first_token(req, logits):
                finished.append(req)
                continue
            free.popleft()
            self.cache = _insert_slot(self.cache, cache1, slot)
            self.cache["pos"] = self.cache["pos"].at[slot].set(
                len(req.prompt))
            self.slots[slot] = req
            self._last_tokens = self._last_tokens.at[slot, 0].set(
                req.output[-1])
            if self.spec is not None:
                self.spec.admit(slot, req.prompt)
        return finished

    def _admit_paged(self) -> List[Request]:
        """Paged admission: FIFO like dense, but gated on free blocks —
        a queue head that does not fit (after LRU-evicting cold prefix
        pins) WAITS for running requests to free memory instead of being
        admitted or dropped. Requests that can never fit the pool finish
        immediately with ``kv_oom``; preempted requests at the head are
        restored from their swap payload without recomputation."""
        finished: List[Request] = self._drop_expired()
        free = deque(self._free_slots())
        while free and self.queue:
            slot = free[0]
            req = self.queue.peek()
            if req.swap is not None:                       # resume
                total = req.swap["pos"]
                # +1: room for the decode write this same step — without
                # it a resumed request preempts itself right back out
                need = self.pool.blocks_needed(total + 1)
                if need > self.pool.n_blocks:
                    self.queue.pop()
                    self._finish_now(req, "kv_oom")
                    finished.append(req)
                    continue
                if not self._reserve(need):
                    break                                  # wait
                self.queue.pop()
                # hold the decode-write headroom block NOW — a reserve
                # that is only re-checked later can be consumed by the
                # next admission in this same loop
                table = self.pool.alloc(total + 1)
                table.n_tokens = total
                # pad the sliced swap rows back to the logical width so
                # _paged_scatter keeps one trace for any fill level
                pad = self.cache_len
                segs = jax.tree.map(
                    lambda a: np.pad(a, ((0, 0), (0, 0), (0, 0),
                                         (0, pad - a.shape[3]),
                                         (0, 0))),
                    req.swap["segments"])
                self._install(slot, req, table, segs, scatter_from=0)
                self._last_tokens = self._last_tokens.at[slot, 0].set(
                    req.output[-1])
                req.swap = None
                self.stats["resumes"] += 1
                self._trace_admit(req, slot, resumed=True)
                if self.spec is not None:
                    # the swap restored the target's KV, but the draft
                    # cache was dropped at preemption — rebuild it over
                    # the same context (prompt + output minus the
                    # carried last token)
                    self.spec.admit(slot,
                                    req.prompt + req.output[:-1])
                free.popleft()
                continue
            total = len(req.prompt)
            if total >= self.cache_len:
                # no room in the logical view for even one decode write;
                # dense truncates the prefill and emits a token or two
                # before dying with "cache_len" — paged refuses up front
                # instead of letting the block math run off the table
                self.queue.pop()
                self._finish_now(req, "cache_len")
                finished.append(req)
                continue
            # zero-copy sharing needs the prefix PINNED (its blocks in
            # the pool); a hit on an evicted pin still reuses the staged
            # prefill but scatters a private copy (j0 = 0)
            pref = self._prefix_hit(req)
            ptab = (self._prefix_tables.get(req.prefix_key)
                    if pref is not None else None)
            j0 = (len(pref.ids) // self.block_size
                  if ptab is not None else 0)
            if ptab is not None:
                # LRU-touch at the hit decision, not after install — a
                # terminal-first-token admission must still keep a hot
                # pin warm
                self._touch_prefix(req.prefix_key)
            # +1 as above: prompt blocks plus the imminent decode write
            need = self.pool.blocks_needed(total + 1) - j0
            if need > self.pool.n_blocks:
                self.queue.pop()
                self._finish_now(req, "kv_oom")
                finished.append(req)
                continue
            if not self._reserve(need, keep=(req.prefix_key
                                             if ptab is not None
                                             else None)):
                if self.busy_slots() > 0:
                    break      # wait: running requests will free blocks
                # nothing running will ever free blocks; last resort,
                # retry as a private (unshared) copy — this may evict
                # the very pin we would have forked, the only remaining
                # path to progress
                if ptab is not None:
                    ptab, j0 = None, 0
                    need = self.pool.blocks_needed(total + 1)
                if not self._reserve(need):
                    # the head can never fit — fail it, don't deadlock
                    self.queue.pop()
                    self._finish_now(req, "kv_oom")
                    finished.append(req)
                    continue
            self.queue.pop()
            self.stats["admissions"] += 1
            req.admit_step = self.step_no
            self._trace_admit(req, slot)
            if self.prefill_budget is not None:
                # chunked admission: take the blocks NOW (same math as
                # the monolithic path below) so co-resident decodes
                # cannot starve the in-flight prefill of its own rows,
                # then advance chunk-by-chunk across steps
                if ptab is not None:
                    table = self.pool.fork(ptab, total)
                    self.pool.cow_from(table, j0)
                    self.pool.grow(table, total + 1)
                    if self.tracer.enabled:
                        self.tracer.event("cow_fork", tick=self.step_no,
                                          group=self.trace_group,
                                          lane="kv",
                                          request=req.request_id,
                                          shared_blocks=j0)
                else:
                    table = self.pool.alloc(total + 1)
                table.n_tokens = total
                free.popleft()
                self._start_pending(slot, req, pref, table, j0)
                self._note_kv_peak()
                continue
            logits, cache1, _ = self._prefill_request(req, pref)
            if self._first_token(req, logits):
                finished.append(req)
                continue
            # the +1 headroom block is allocated (held), not just
            # reserved — see the resume path above
            if ptab is not None:
                # CoW fork: share every fully-covered prefix block
                # (refcount++), own a fresh copy of the partial tail
                # block and the suffix blocks
                table = self.pool.fork(ptab, total)
                self.pool.cow_from(table, j0)
                self.pool.grow(table, total + 1)
                if self.tracer.enabled:
                    self.tracer.event("cow_fork", tick=self.step_no,
                                      group=self.trace_group,
                                      lane="kv",
                                      request=req.request_id,
                                      shared_blocks=j0)
            else:
                table = self.pool.alloc(total + 1)
            table.n_tokens = total
            self._install(slot, req, table, cache1["segments"],
                          scatter_from=j0)
            self._last_tokens = self._last_tokens.at[slot, 0].set(
                req.output[-1])
            if self.spec is not None:
                self.spec.admit(slot, req.prompt)
            free.popleft()
        return finished

    def _start_pending(self, slot: int, req: Request,
                       pref: Optional[CachedPrefix],
                       table: Optional[BlockTable], j0: int):
        """Open a chunked prefill: the request takes its slot (and, in
        paged mode, its pre-allocated block table) immediately, but its
        B=1 cache is built across subsequent steps by
        ``_advance_pendings``. A prefix hit seeds the cache from the
        registered prefill exactly like the monolithic path."""
        i, logits, cache = 0, None, None
        if pref is not None:
            i = len(pref.ids)
            logits = pref.logits
            cache = {"segments": pref.cache["segments"],
                     "pos": pref.cache["pos"]}
            self.stats["prefix_hits"] += 1
            self.stats["prefix_tokens_saved"] += i
        else:
            self.stats["prefills"] += 1
        self.slots[slot] = req
        self._pending[slot] = PendingPrefill(
            req=req, slot=slot, toks=list(req.prompt), i=i,
            logits=logits, cache=cache, table=table, j0=j0)
        self._pending_rr.append(slot)

    def _advance_pending(self, p: PendingPrefill, chunks: int) -> int:
        """Spend up to ``chunks`` whole attn_chunk slabs advancing one
        pending prefill; returns the slabs consumed. Every call is the
        same ``prefill``/``prefill_extend`` sequence
        ``advance_cache_through`` would issue, just spread across
        steps — the chunk-seam parity of prefill_extend (DESIGN.md
        §Prefix caching) makes the result bitwise identical to a
        monolithic prefill no matter where the budget cuts."""
        from repro.common.perf import get_flags
        align = get_flags().attn_chunk
        spent = 0
        while spent < chunks and p.i < len(p.toks):
            rem = len(p.toks) - p.i
            if p.cache is None:
                # head: one B=1 prefill over the first chunk (or the
                # whole short prompt)
                n = min(rem, align)
                head = jnp.asarray(p.toks[:n], jnp.int32)[None]
                logits, cache = self._prefill(self.params,
                                              {"tokens": head})
                cache = dict(cache)
                cache["pos"] = jnp.asarray(n, jnp.int32)
                p.logits, p.cache = logits, cache
                p.i = n
            elif rem >= align:
                chunk = jnp.asarray(p.toks[p.i:p.i + align],
                                    jnp.int32)[None]
                p.logits, p.cache = self._extend(
                    self.params, p.cache, {"tokens": chunk}, align)
                p.i += align
            else:
                # bucket-padded remainder — advance_cache_through's
                # tail rule (cap the pad width at the cache end)
                rest = p.toks[p.i:]
                room = self.cache_len - int(p.cache["pos"])
                if self._pad_extend and rem < room:
                    width = min(1 << (rem - 1).bit_length(), room)
                    rest = rest + [0] * (width - rem)
                chunk = jnp.asarray(rest, jnp.int32)[None]
                p.logits, p.cache = self._extend(
                    self.params, p.cache, {"tokens": chunk}, rem)
                p.i = len(p.toks)
            spent += 1
        self.stats["prefill_chunks"] += spent
        if spent and self.tracer.enabled:
            self.tracer.event("prefill_chunk", tick=self.step_no,
                              group=self.trace_group, lane=p.slot,
                              request=p.req.request_id, chunks=spent,
                              done_tokens=p.i)
        return spent

    def _complete_pending(self, slot: int) -> Optional[Request]:
        """Last chunk landed: sample the admission token and install
        the finished B=1 cache into the batched slot (dense copy or
        paged scatter — identical to the monolithic admission tail).
        Returns the request when its first token was already terminal
        (the slot frees without ever decoding)."""
        p = self._pending.pop(slot)
        req = p.req
        if self._first_token(req, p.logits):
            self.slots[slot] = None
            if self.kv_mode == "paged":
                self.pool.free(p.table)
            return req
        if self.kv_mode == "paged":
            self._install(slot, req, p.table, p.cache["segments"],
                          scatter_from=p.j0)
        else:
            self.cache = _insert_slot(self.cache, p.cache, slot)
            self.cache["pos"] = self.cache["pos"].at[slot].set(
                len(req.prompt))
        self._last_tokens = self._last_tokens.at[slot, 0].set(
            req.output[-1])
        if self.spec is not None:
            self.spec.admit(slot, req.prompt)
        return None

    def _advance_pendings(self) -> List[Request]:
        """Spend this step's prefill budget — ``max(1, prefill_budget
        // attn_chunk)`` slabs (a budget smaller than one chunk falls
        back to whole-chunk granularity) — over pending prefills in
        deficit round-robin: one chunk per turn, an unfinished prefill
        rotates to the back. A short prompt therefore drains past a
        long one in a few turns instead of queuing behind its whole
        prefill (head-of-line order would stall every wavemate's first
        token for the longest prompt), and the rotation is
        starvation-free: with k pendings every prefill advances at
        least every k turns. A prefill that finishes completes
        immediately: its admission token is emitted this step and the
        slot joins this same step's decode, matching the monolithic
        path's timing relative to prefill completion."""
        from repro.common.perf import get_flags
        allowance = max(1, self.prefill_budget // get_flags().attn_chunk)
        finished: List[Request] = []
        while allowance > 0 and self._pending_rr:
            slot = self._pending_rr[0]
            p = self._pending[slot]
            allowance -= self._advance_pending(p, 1)
            if p.i >= len(p.toks):
                self._pending_rr.popleft()
                done = self._complete_pending(slot)
                if done is not None:
                    finished.append(done)
            else:
                self._pending_rr.rotate(-1)
        return finished

    def step(self) -> List[Request]:
        """One engine iteration (one tick of ``step_no``): admit from
        the queue, advance pending chunked prefills by the per-step
        budget, then decode one token for every active slot — or, with
        spec decode on, draft K cheap tokens per slot and verify them
        in one target forward, emitting 1..K+1 tokens per slot
        (_spec_step). With ``interleave=False`` decode (and spec) is
        skipped while any prefill is pending — the run-to-completion
        baseline. Returns newly finished requests (including any that
        terminated on their admission token and expired-in-queue
        drops). Paged mode additionally grows block tables before the
        decode/verify writes and may preempt-and-requeue under memory
        pressure (_ensure_room)."""
        finished = self._step_once()
        self.step_no += 1
        return finished

    def _step_once(self) -> List[Request]:
        finished = self._admit()
        self._note_kv_peak()
        if self._pending:
            finished.extend(self._advance_pendings())
        stalled = not self.interleave and bool(self._pending)
        if self.kv_mode == "paged" and not stalled:
            finished.extend(self._ensure_room(
                1 if self.spec is None else self.spec.k + 1))
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and i not in self._pending]
        if not active:
            return finished
        if stalled:
            self.stats["stall_ticks"] += 1
            if self.tracer.enabled:
                self.tracer.event("stall", tick=self.step_no,
                                  group=self.trace_group, lane="engine",
                                  pending=len(self._pending))
            return finished
        if self.spec is not None:
            finished.extend(self._spec_step(active))
            return finished
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": self._last_tokens})
        self.stats["decode_steps"] += 1
        if self.tracer.enabled:
            self.tracer.event("decode", tick=self.step_no,
                              group=self.trace_group, lane="engine",
                              active=len(active))
        if self.kv_mode == "paged":
            for i in active:          # one KV row written per sequence
                self.tables[i].n_tokens += 1
        # per-slot sampling: each slot draws its own engine-stream key,
        # unless the request carries a per-request seed (_request_key)
        for i in active:
            req = self.slots[i]
            self.rng, ki = jax.random.split(self.rng)
            tok = int(sample(logits[i:i + 1], self._request_key(req, ki),
                             req.sampler)[0])
            req.output.append(tok)
            self.stats["tokens_generated"] += 1
            self._last_tokens = self._last_tokens.at[i, 0].set(tok)
            hit_cap = len(req.output) >= req.max_new_tokens
            hit_len = int(self.cache["pos"][i]) + 1 >= self.cache_len - 1
            if tok == SPECIALS["<eos>"] or hit_cap or hit_len:
                self._finish_now(req, "eos" if tok == SPECIALS["<eos>"]
                                 else "max_new_tokens" if hit_cap
                                 else "cache_len")
                finished.append(req)
                self.slots[i] = None
                self.cache["pos"] = self.cache["pos"].at[i].set(0)
                if self.kv_mode == "paged":
                    self._release_slot(i)
        return finished

    def _spec_step(self, active: List[int]) -> List[Request]:
        """One speculative round: K greedy draft steps, one target
        verify forward over W = K+1 positions per slot, then per-slot
        sample-and-match acceptance (serving/specdec.py has the
        protocol and the bitwise-parity argument).

        Every emitted token is sampled from the target's verify logits
        with the exact key schedule non-speculative decoding would use
        (per-request fold_in streams; the engine stream still splits
        once per sampled token), and the finish checks replicate
        step()'s eos/max_new_tokens/cache_len decisions token by token
        — so outputs AND finish reasons match the non-speculative
        engine bitwise."""
        k = self.spec.k
        pos0 = np.asarray(self.cache["pos"])
        drafts = self.spec.draft(self._last_tokens)           # (B, k)
        toks = jnp.concatenate(
            [self._last_tokens, jnp.asarray(drafts, jnp.int32)], axis=1)
        vlogits, self.cache = self._verify(self.params, self.cache,
                                           {"tokens": toks})
        self.stats["decode_steps"] += 1
        self.stats["spec_rounds"] += 1
        new_pos = pos0.copy()
        finished: List[Request] = []
        full_accept = False
        round_accepted = 0
        for i in active:
            req = self.slots[i]
            emitted = accepted = 0
            reason = None
            for j in range(k + 1):
                self.rng, kj = jax.random.split(self.rng)
                key = self._request_key(req, kj)
                tok = int(sample(vlogits[i, j][None], key,
                                 req.sampler)[0])
                req.output.append(tok)
                emitted += 1
                self.stats["tokens_generated"] += 1
                matched = j < k and tok == int(drafts[i, j])
                if matched:
                    accepted += 1
                hit_cap = len(req.output) >= req.max_new_tokens
                hit_len = int(pos0[i]) + j + 2 >= self.cache_len - 1
                reason = ("eos" if tok == SPECIALS["<eos>"]
                          else "max_new_tokens" if hit_cap
                          else "cache_len" if hit_len else None)
                if reason is not None or not matched:
                    break
            self.stats["spec_drafted"] += k
            self.stats["spec_accepted"] += accepted
            round_accepted += accepted
            full_accept = full_accept or accepted == k
            new_pos[i] = int(pos0[i]) + emitted
            self._last_tokens = self._last_tokens.at[i, 0].set(
                req.output[-1])
            if self.kv_mode == "paged":
                # rollback IS this truncation: rejected rows sit in
                # blocks the table already holds and are overwritten
                # before kv_len ever reaches them
                self.tables[i].n_tokens = int(pos0[i]) + emitted
            if reason is not None:
                self._finish_now(req, reason)
                finished.append(req)
                self.slots[i] = None
                new_pos[i] = 0
                if self.kv_mode == "paged":
                    self._release_slot(i)
        if self.tracer.enabled:
            self.tracer.event("spec_round", tick=self.step_no,
                              group=self.trace_group, lane="engine",
                              active=len(active),
                              drafted=k * len(active),
                              accepted=round_accepted)
        self.cache["pos"] = jnp.asarray(new_pos, jnp.int32)
        if full_accept:
            self.spec.catch_up()
        self.spec.set_pos(new_pos)
        return finished

    def run_until_done(self, max_iters: int = 10_000) -> List[Request]:
        done: List[Request] = []
        it = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and it < max_iters:
            done.extend(self.step())
            it += 1
        return done

    def throughput_stats(self) -> Dict[str, float]:
        st = {**self.stats, **self.kv_memory_stats()}
        # tokens per TARGET forward — the number speculative decoding
        # moves (> busy slots when drafts are accepted); accept rate =
        # accepted / drafted over every speculative round
        st["tokens_per_step"] = round(
            st["tokens_generated"] / max(st["decode_steps"], 1), 4)
        st["spec_accept_rate"] = round(
            st["spec_accepted"] / max(st["spec_drafted"], 1), 4)
        st["spec_k"] = self.spec_k
        return st

    def kv_memory_stats(self) -> Dict:
        """KV-memory accounting, apples-to-apples across modes:
        ``kv_bytes_allocated`` is the physical reservation (dense: the
        full (max_batch, cache_len) slab; paged: the block pool),
        ``kv_bytes_in_use``/``kv_bytes_peak`` what live requests
        actually hold (dense reserves a whole slot per request), and
        ``kv_shared_frac`` the fraction of in-use blocks CoW-shared
        between holders (dense never shares)."""
        if self.kv_mode == "paged":
            ps = self.pool.stats()
            bpb = self._kv_bytes_total // max(self.kv_blocks, 1)
            used = ps["kv_blocks_used"]
            return {**ps, "kv_mode": "paged",
                    "kv_bytes_allocated": self._kv_bytes_total,
                    "kv_bytes_in_use": used * bpb,
                    "kv_bytes_peak": self._kv_peak_blocks * bpb,
                    "kv_blocks_used_peak": self._kv_peak_blocks,
                    "kv_blocks_shared_peak": self._kv_peak_shared,
                    # peak-based: after a run drains, request tables are
                    # freed and the instantaneous shared count is ~0 —
                    # the peaks are what the run actually exercised
                    "kv_shared_frac": round(
                        self._kv_peak_shared
                        / max(self._kv_peak_blocks, 1), 4)}
        per_slot = self._kv_bytes_total // max(self.max_batch, 1)
        return {"kv_mode": "dense",
                "kv_bytes_allocated": self._kv_bytes_total,
                "kv_bytes_in_use": self.busy_slots() * per_slot,
                "kv_bytes_peak": self._kv_peak_slots * per_slot,
                "kv_blocks_total": 0, "kv_blocks_used": 0,
                "kv_blocks_free": 0, "kv_blocks_shared": 0,
                "kv_blocks_owned": 0, "kv_blocks_used_peak": 0,
                "kv_blocks_shared_peak": 0, "kv_shared_frac": 0.0}


@dataclass
class EngineSession:
    """One Copilot conversation multiplexed over the engine's slots.

    Each planner/gate turn becomes one engine request tagged with the
    session's ``prefix_key`` (its gated intent), so every turn of every
    session sharing an intent reuses the same cached system-prompt
    prefill. Turns from many sessions interleave freely in the slot pool
    — the engine does not reserve a slot per session.
    """
    engine: InferenceEngine
    session_id: int
    prefix_key: Optional[str] = None
    pending: List[int] = field(default_factory=list)
    turns: List[Request] = field(default_factory=list)

    def submit_turn(self, text: str, max_new_tokens: int = 16,
                    sampler: SamplerConfig = SamplerConfig()) -> int:
        rid = self.engine.add_request(text, max_new_tokens, sampler,
                                      prefix_key=self.prefix_key,
                                      session_id=self.session_id)
        self.pending.append(rid)
        return rid

    def collect(self, finished: List[Request]) -> List[Request]:
        """Claim this session's turns from an engine ``step`` result.
        Matches on (session_id, request_id): a cluster merges finished
        lists from many replicas, and request ids are only engine-local."""
        mine = [r for r in finished if r.session_id == self.session_id
                and r.request_id in self.pending]
        for r in mine:
            self.pending.remove(r.request_id)
            self.turns.append(r)
        return mine

    @property
    def idle(self) -> bool:
        return not self.pending
