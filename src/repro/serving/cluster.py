"""Intent-affinity serving cluster: N engine replicas behind a router.

The paper's platform is "a massively parallel Copilot platform with
over 100 GPT-4-Turbo nodes"; a single ``InferenceEngine`` models its
token economics but not its fleet shape. ``EngineCluster`` owns N
replicas — each with its own slot pool, prompt-prefix cache and kernel
backend — and places every request through a pluggable router:

  * ``round_robin``        — cycle replicas in submission order;
  * ``least_loaded``       — min (busy slots + queue depth), ties to the
                             lowest replica index;
  * ``intent_affinity``    — consistent-hash (rendezvous) the request's
    ``prefix_key`` onto the replica that registered that intent's
    prompt prefix, so same-intent traffic lands where the prefix
    prefill is already cached — the serving-side analogue of GeckOpt's
    token savings. Optionally spills to least-loaded when the home
    replica's load crosses ``spill_load``; keyless requests fall back
    to least-loaded.

``register_prefix`` installs an intent prefix on its rendezvous *home*
replica only: affinity keeps hitting that cache while oblivious
policies pay a full prefill on every other replica — per-replica
prefix-hit rates in ``ClusterStats`` quantify the gap
(benchmarks/cluster_bench.py tabulates it).

Time is the deterministic tick clock of ``step()`` (one continuous-
batching iteration on every replica per tick); ``run_workload`` drives
a ``serving/workload.py`` schedule through the cluster and collects
TTFT / E2E / queue-wait percentiles, per-replica utilization and SLA
attainment — no wall-clock anywhere, so runs are exactly reproducible.

Replicas share one set of jitted step functions (same config, cache
length and backend => identical traces), so an N-replica cluster
compiles once, not N times. Outputs are bit-identical across routing
policies when requests carry sampler seeds: prefix-extend logits match
full-prefill logits bitwise (tests/test_cluster.py proves parity).

The cluster is interface-compatible with the single engine where the
serving pipeline needs it (``register_prefix`` / ``prefixes`` /
``open_session`` / ``step`` / ``run_until_done`` /
``throughput_stats``), so ``GeckOptPipeline(engine=cluster)`` works
unchanged — sessions get replica affinity by their intent prefix.
"""
from __future__ import annotations

import hashlib
import heapq
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs import MetricsRegistry, NULL_TRACER, percentile
from repro.serving.engine import EngineSession, InferenceEngine, Request
from repro.serving.sampling import SamplerConfig
from repro.serving.workload import WorkloadRequest

ROUTER_POLICIES = ("round_robin", "least_loaded", "intent_affinity")


def rendezvous_hash(key: str, indices) -> int:
    """Highest-random-weight (rendezvous) placement of ``key`` over the
    replica ``indices``. Deterministic across processes (sha256, not the
    salted builtin hash) and stable under replica-set growth: adding a
    replica only remaps the keys the new replica wins."""
    return max(indices, key=lambda i: int.from_bytes(
        hashlib.sha256(f"{key}|{i}".encode()).digest()[:8], "big"))


@dataclass(frozen=True)
class ReplicaView:
    """Router-visible snapshot of one replica's occupancy."""
    index: int
    busy_slots: int
    queue_depth: int
    holds_prefix: bool = False

    @property
    def load(self) -> int:
        return self.busy_slots + self.queue_depth


def _least_loaded(views: Sequence[ReplicaView]) -> int:
    return min(views, key=lambda v: (v.load, v.index)).index


class Router:
    name = "base"

    def select(self, views: Sequence[ReplicaView],
               prefix_key: Optional[str] = None,
               slack: Optional[int] = None) -> int:
        """Place one request. ``slack`` is its SLA budget in ticks
        (``sla_ticks``) at arrival, None when it carries no deadline —
        policies may use it to keep tight-deadline traffic off loaded
        replicas; the stateless policies ignore it."""
        raise NotImplementedError

    def reset(self):
        """Drop routing state (the cluster's reset() calls this)."""


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._next = 0

    def select(self, views, prefix_key=None, slack=None) -> int:
        i = views[self._next % len(views)].index
        self._next += 1
        return i

    def reset(self):
        self._next = 0


class LeastLoadedRouter(Router):
    name = "least_loaded"

    def select(self, views, prefix_key=None, slack=None) -> int:
        return _least_loaded(views)


class IntentAffinityRouter(Router):
    name = "intent_affinity"

    def __init__(self, spill_load: Optional[int] = None,
                 sla_spill: bool = False):
        # spill_load: home-replica load (busy+queued) at which keyed
        # traffic overflows to least-loaded; None = never spill (keeps
        # placement a pure function of the key, the parity-test mode).
        # sla_spill: additionally spill a deadline-carrying request
        # whose slack is smaller than the home replica's load — the
        # queue ahead of it would eat its whole SLA budget before it
        # even admits, so prefix affinity can't be worth the miss
        self.spill_load = spill_load
        self.sla_spill = sla_spill

    def select(self, views, prefix_key=None, slack=None) -> int:
        if prefix_key is None:
            return _least_loaded(views)
        holders = [v.index for v in views if v.holds_prefix]
        home = rendezvous_hash(prefix_key,
                               holders or [v.index for v in views])
        by_index = {v.index: v for v in views}
        if (self.spill_load is not None
                and by_index[home].load >= self.spill_load):
            return _least_loaded(views)
        if (self.sla_spill and slack is not None
                and by_index[home].load > slack):
            return _least_loaded(views)
        return home


def make_router(policy, spill_load: Optional[int] = None,
                sla_spill: bool = False) -> Router:
    if isinstance(policy, Router):
        return policy
    if policy == "round_robin":
        return RoundRobinRouter()
    if policy == "least_loaded":
        return LeastLoadedRouter()
    if policy == "intent_affinity":
        return IntentAffinityRouter(spill_load=spill_load,
                                    sla_spill=sla_spill)
    raise ValueError(f"unknown router {policy!r}; "
                     f"choose from {ROUTER_POLICIES}")


@dataclass
class RequestTrace:
    """Cluster-side lifecycle record of one routed request (ticks)."""
    index: int                     # workload index (-1: ad-hoc submit)
    replica: int
    request_id: int
    intent: Optional[str]
    prefix_key: Optional[str]
    arrival_tick: int
    sla_ticks: Optional[int]
    session_id: Optional[int]
    turn: int
    admit_tick: Optional[int] = None
    # tick the request's FIRST TOKEN was sampled (the engine's
    # first_token_step) — true TTFT; admit_tick only measures queue wait
    first_token_tick: Optional[int] = None
    finish_tick: Optional[int] = None
    request: Optional[Request] = None   # engine object (output, reason)


def _pct(values: List[int], q: float) -> Optional[float]:
    """Percentile, or None for an empty series — 0.0 would read as a
    perfect latency for a run that finished nothing (the bench renders
    None as "n/a"). One implementation for the whole serving stack:
    obs.metrics.percentile."""
    return percentile(values, q)


@dataclass
class ClusterStats:
    """End-of-run metrics: request latency distributions (ticks) plus
    per-replica engine counters and slot utilization."""
    ticks: int
    traces: List[RequestTrace]
    per_replica: List[Dict]

    def outputs(self) -> Dict[int, Tuple[int, ...]]:
        """Workload index -> generated tokens (parity comparisons)."""
        return {t.index: tuple(t.request.output) for t in self.traces
                if t.request is not None and t.index >= 0}

    def summary(self) -> Dict:
        done = [t for t in self.traces if t.finish_tick is not None]
        # sla_expired requests were dropped from the queue without ever
        # producing a token: they count as requests and SLA misses, but
        # latency percentiles are over requests actually served
        served = [t for t in done
                  if not (t.request is not None
                          and t.request.finish_reason == "sla_expired")]
        # TRUE time-to-first-token: the tick the first token was
        # sampled, which includes queue wait AND prefill time. (+1 so a
        # same-tick arrival-to-token costs one tick, not zero.) The old
        # admit-proxy metric — which saw none of the prefill — survives
        # as admit_wait_*.
        ttft = [t.first_token_tick - t.arrival_tick + 1 for t in served
                if t.first_token_tick is not None]
        awt = [t.admit_tick - t.arrival_tick + 1 for t in served
               if t.admit_tick is not None]
        e2e = [t.finish_tick - t.arrival_tick + 1 for t in served]
        qwait = [t.admit_tick - t.arrival_tick for t in served
                 if t.admit_tick is not None]
        # a deadline-carrying request still in flight at cutoff has
        # missed its SLA by construction — count it, don't drop it;
        # sla_expired drops finish past their deadline, so they are
        # automatic misses under the same formula
        sla = [t.finish_tick is not None
               and (t.finish_tick - t.arrival_tick + 1) <= t.sla_ticks
               for t in self.traces if t.sla_ticks is not None]
        expired = sum(1 for t in done
                      if t.request is not None
                      and t.request.finish_reason == "sla_expired")
        adm = sum(r["admissions"] for r in self.per_replica)
        hits = sum(r["prefix_hits"] for r in self.per_replica)
        # speculative decoding: fleet accept rate and tokens per target
        # forward (== mean busy slots without spec; grows with accepted
        # drafts when spec decode is on)
        steps = sum(r["decode_steps"] for r in self.per_replica)
        toks = sum(r["tokens_generated"] for r in self.per_replica)
        drafted = sum(r.get("spec_drafted", 0)
                      for r in self.per_replica)
        accepted = sum(r.get("spec_accepted", 0)
                       for r in self.per_replica)
        # KV-memory accounting (engine.kv_memory_stats per replica):
        # fleet-wide peak bytes, preemption pressure and the
        # shared-vs-owned block split of the paged pools — peak-based,
        # since a drained run's instantaneous shared count is ~0
        shared = sum(r.get("kv_blocks_shared_peak", 0)
                     for r in self.per_replica)
        used = sum(r.get("kv_blocks_used_peak", 0)
                   for r in self.per_replica)
        # toolset-retrieval prefixes (core/retriever.py): requests whose
        # prompt prefix is a retrieved toolset ("toolset:<sha1>") rather
        # than an intent — distinct keys vs turns served shows how much
        # co-retrieval sharing the router preserved
        toolset_turns = [t for t in self.traces
                         if t.prefix_key is not None
                         and t.prefix_key.startswith("toolset:")]
        toolset_keys = {t.prefix_key for t in toolset_turns}
        return {
            "ticks": self.ticks,
            "requests": len(self.traces),
            "finished": len(served),
            "sla_expired": expired,
            "ttft_p50": _pct(ttft, 50), "ttft_p95": _pct(ttft, 95),
            "ttft_p99": _pct(ttft, 99),
            "admit_wait_p50": _pct(awt, 50),
            "admit_wait_p95": _pct(awt, 95),
            "e2e_p50": _pct(e2e, 50), "e2e_p95": _pct(e2e, 95),
            "queue_wait_p50": _pct(qwait, 50),
            "queue_wait_p95": _pct(qwait, 95),
            "prefix_hit_ratio": round(hits / max(adm, 1), 4),
            "sla_attainment": (round(sum(sla) / len(sla), 4)
                               if sla else 1.0),
            "tokens_out": sum(len(t.request.output) for t in done
                              if t.request is not None),
            "tokens_decoded": toks,
            "decode_steps": steps,
            "tokens_per_step": round(toks / max(steps, 1), 4),
            "spec_rounds": sum(r.get("spec_rounds", 0)
                               for r in self.per_replica),
            "spec_accept_rate": round(accepted / max(drafted, 1), 4),
            "kv_bytes_allocated": sum(r.get("kv_bytes_allocated", 0)
                                      for r in self.per_replica),
            "kv_bytes_peak": sum(r.get("kv_bytes_peak", 0)
                                 for r in self.per_replica),
            "preemptions": sum(r.get("preemptions", 0)
                               for r in self.per_replica),
            "resumes": sum(r.get("resumes", 0)
                           for r in self.per_replica),
            "prefix_evictions": sum(r.get("prefix_evictions", 0)
                                    for r in self.per_replica),
            "kv_blocks_shared_peak": shared,
            "kv_shared_frac": round(shared / max(used, 1), 4),
            "toolset_prefixes": len(toolset_keys),
            "toolset_turns": len(toolset_turns),
            "per_replica": self.per_replica,
        }


class EngineCluster:
    """N ``InferenceEngine`` replicas behind a routing policy."""

    def __init__(self, cfg=None, params=None, n_replicas: int = 2, *,
                 engines: Optional[List[InferenceEngine]] = None,
                 router="round_robin", spill_load: Optional[int] = None,
                 sla_spill: bool = False,
                 max_batch: Optional[int] = None,
                 cache_len: Optional[int] = None,
                 seed: Optional[int] = None,
                 backend: Optional[str] = None,
                 kv_mode: Optional[str] = None,
                 kv_blocks: Optional[int] = None,
                 block_size: Optional[int] = None,
                 spec_decode=None,
                 prefill_budget: Optional[int] = None,
                 interleave: Optional[bool] = None,
                 admission: Optional[str] = None,
                 tracer=None, metrics: Optional[MetricsRegistry] = None):
        # one shared registry for the fleet: each replica publishes
        # through a replica=i facade, the cluster's own counters and
        # latency histograms sit unlabeled beside them. One shared
        # tracer: replicas stamp their replica index as the track
        # group, so exported traces are keyed (replica, slot).
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if engines is not None:
            # prebuilt replicas keep their own configuration; sizing
            # kwargs would be silently dropped, so refuse them
            if any(v is not None for v in (cfg, params, max_batch,
                                           cache_len, seed, backend,
                                           kv_mode, kv_blocks,
                                           block_size, spec_decode,
                                           prefill_budget, interleave,
                                           admission)):
                raise ValueError(
                    "engines= is mutually exclusive with cfg/params/"
                    "max_batch/cache_len/seed/backend/kv_mode/"
                    "kv_blocks/block_size/spec_decode/prefill_budget/"
                    "interleave/admission (prebuilt replicas keep "
                    "their own configuration)")
            self.replicas = list(engines)
            for i, e in enumerate(self.replicas):
                # prebuilt replicas keep their own registries; the
                # shared tracer (when given) still replaces their
                # default NullTracer so the fleet records one trace
                e.trace_group = i
                if tracer is not None:
                    e.tracer = tracer
        else:
            assert cfg is not None and params is not None
            max_batch = 8 if max_batch is None else max_batch
            cache_len = 512 if cache_len is None else cache_len
            seed = 0 if seed is None else seed
            kv_mode = "dense" if kv_mode is None else kv_mode
            self.replicas = []
            for i in range(n_replicas):
                e = InferenceEngine(cfg, params, max_batch=max_batch,
                                    cache_len=cache_len, seed=seed + i,
                                    backend=backend, kv_mode=kv_mode,
                                    kv_blocks=kv_blocks,
                                    block_size=block_size,
                                    spec_decode=spec_decode,
                                    prefill_budget=prefill_budget,
                                    interleave=(True if interleave
                                                is None else interleave),
                                    admission=admission or "fifo",
                                    tracer=self.tracer,
                                    metrics=self.metrics.labeled(
                                        replica=i))
                e.trace_group = i
                if self.replicas:
                    # identical (cfg, cache_len, backend) closures =>
                    # replicas share one jit cache: compile once, not N×
                    e0 = self.replicas[0]
                    e._prefill, e._decode, e._extend = \
                        e0._prefill, e0._decode, e0._extend
                    if e.spec is not None:
                        e._verify = e0._verify
                        e.spec.share_compiled(e0.spec)
                self.replicas.append(e)
        self.router = make_router(router, spill_load=spill_load,
                                  sla_spill=sla_spill)
        self.backend = self.replicas[0].backend
        self.kv_mode = self.replicas[0].kv_mode
        self.spec_k = self.replicas[0].spec_k
        self.tick = 0
        self.traces: Dict[Tuple[int, int], RequestTrace] = {}
        self._next_session = 0
        self._prefix_home: Dict[str, int] = {}
        self._util_ticks = [0] * len(self.replicas)
        self._finished_traces: List[RequestTrace] = []
        # cluster-level registry slice: routed submissions plus the
        # served-request latency distributions in ticks (the same
        # numbers ClusterStats.summary percentiles — one storage)
        self._c_routed = self.metrics.counter("cluster_requests_routed")
        self._h_ttft = self.metrics.histogram("cluster_ttft_ticks")
        self._h_e2e = self.metrics.histogram("cluster_e2e_ticks")

    def reset(self, seed: Optional[int] = None):
        """Recycle the whole cluster between workloads: reset every
        replica (slots, queues, stats, prefix caches — jit caches are
        kept, so it serves warm), zero the tick clock, drop traces and
        routing state. Prefixes must be re-registered afterwards."""
        # full-registry sweep first (zeroes the cluster histograms and
        # every replica facade's slice in one pass), then per-replica
        # resets re-publish their fresh pool gauges
        self.metrics.reset()
        for i, e in enumerate(self.replicas):
            e.reset(None if seed is None else seed + i)
        self.router.reset()
        self.tick = 0
        self.traces = {}
        self._next_session = 0
        self._prefix_home = {}
        self._util_ticks = [0] * len(self.replicas)
        self._finished_traces = []

    # ----------------------------------------------------- prefixes ----
    @property
    def prefixes(self) -> Dict[str, int]:
        """Registered prefix key -> home replica index (``in``-compatible
        with the single engine's ``prefixes`` dict)."""
        return dict(self._prefix_home)

    def register_prefix(self, key: str, prefix_text_or_ids,
                        replicate: bool = False) -> int:
        """Prefill the shared prefix on its rendezvous home replica (or
        on every replica with ``replicate=True`` — which erases the
        affinity advantage but serves hot intents from all replicas).
        Returns the prefix length in tokens."""
        home = rendezvous_hash(key, range(len(self.replicas)))
        self._prefix_home[key] = home
        if replicate:
            return max(e.register_prefix(key, prefix_text_or_ids)
                       for e in self.replicas)
        return self.replicas[home].register_prefix(key,
                                                   prefix_text_or_ids)

    # ------------------------------------------------------ routing ----
    def _views(self, prefix_key: Optional[str] = None
               ) -> List[ReplicaView]:
        return [ReplicaView(i, e.busy_slots(), e.queue_depth(),
                            holds_prefix=(prefix_key is not None
                                          and prefix_key in e.prefixes))
                for i, e in enumerate(self.replicas)]

    def route(self, prefix_key: Optional[str] = None,
              slack: Optional[int] = None) -> int:
        return self.router.select(self._views(prefix_key), prefix_key,
                                  slack)

    def submit(self, prompt, max_new_tokens: int = 32,
               sampler: SamplerConfig = SamplerConfig(),
               prefix_key: Optional[str] = None,
               session_id: Optional[int] = None, *,
               intent: Optional[str] = None,
               sla_ticks: Optional[int] = None,
               index: int = -1, turn: int = 0) -> Tuple[int, int]:
        """Route one request; returns (replica index, request id)."""
        r = self.route(prefix_key, slack=sla_ticks)
        self._c_routed.inc()
        rid = self.replicas[r].add_request(
            prompt, max_new_tokens, sampler, prefix_key=prefix_key,
            session_id=session_id, sla_ticks=sla_ticks)
        self.traces[(r, rid)] = RequestTrace(
            index=index, replica=r, request_id=rid, intent=intent,
            prefix_key=prefix_key, arrival_tick=self.tick,
            sla_ticks=sla_ticks, session_id=session_id, turn=turn)
        return r, rid

    def open_session(self, prefix_key: Optional[str] = None
                     ) -> EngineSession:
        """Pin a conversation to one replica (chosen by the router, so
        an intent-keyed session lands on its prefix's home replica).
        Session ids are cluster-unique: replicas' engine-local request
        ids collide, so ``EngineSession.collect`` disambiguates by
        session id."""
        sid = self._next_session
        self._next_session += 1
        return self.replicas[self.route(prefix_key)].open_session(
            prefix_key, session_id=sid)

    # ------------------------------------------------------ stepping ----
    def step(self) -> List[Request]:
        """One cluster tick: every replica admits + decodes once.
        Returns newly finished requests across all replicas."""
        finished: List[Request] = []
        self._finished_traces = []
        for i, e in enumerate(self.replicas):
            done = e.step()
            # slots active during this tick's decode = still occupied
            # after the step + finishers that actually held a slot.
            # Terminal-at-admission requests (len(output) == 1) never
            # did — a slot finisher always has its admission token plus
            # >= 1 decoded token
            decoded = sum(1 for r in done if len(r.output) > 1)
            self._util_ticks[i] += e.busy_slots() + decoded
            for req in done:
                t = self.traces.get((i, req.request_id))
                if t is not None:
                    # engine step numbers advance in lockstep with the
                    # cluster tick clock (both count the same step()
                    # calls), so the engine's stamps ARE cluster ticks
                    if t.admit_tick is None:
                        t.admit_tick = (req.admit_step
                                        if req.admit_step is not None
                                        else self.tick)
                    t.first_token_tick = req.first_token_step
                    t.finish_tick = self.tick
                    t.request = req
                    self._finished_traces.append(t)
                    if req.finish_reason != "sla_expired":
                        # served requests only — expired drops never
                        # produced a token and would poison the
                        # latency distributions (summary() applies
                        # the same exclusion)
                        if t.first_token_tick is not None:
                            self._h_ttft.observe(t.first_token_tick
                                                 - t.arrival_tick + 1)
                        self._h_e2e.observe(t.finish_tick
                                            - t.arrival_tick + 1)
            for s in e.slots:
                if s is not None:
                    t = self.traces.get((i, s.request_id))
                    if t is not None and t.admit_tick is None:
                        t.admit_tick = (s.admit_step
                                        if s.admit_step is not None
                                        else self.tick)
            finished.extend(done)
        self.tick += 1
        return finished

    def is_idle(self) -> bool:
        return all(e.is_idle() for e in self.replicas)

    def run_until_done(self, max_iters: int = 10_000) -> List[Request]:
        out: List[Request] = []
        it = 0
        while not self.is_idle() and it < max_iters:
            out.extend(self.step())
            it += 1
        return out

    # ----------------------------------------------------- workloads ----
    def run_workload(self, requests: Sequence[WorkloadRequest],
                     max_ticks: int = 100_000) -> ClusterStats:
        """Drive a synthetic workload: submit turn-0 requests at their
        arrival ticks, release follow-up turns ``turn_gap`` ticks after
        the previous turn of their session finishes, step until drained.

        Requires a fresh cluster clock (stats and traces are cumulative;
        ``reset()`` — then re-register prefixes — between workloads)."""
        if self.tick != 0 or self.traces:
            raise RuntimeError(
                "run_workload on a used cluster would mix runs in "
                "ClusterStats; call cluster.reset() (and re-register "
                "prefixes) between workloads")
        openers = deque(sorted((w for w in requests if w.turn == 0),
                               key=lambda w: (w.arrival_tick, w.index)))
        followups = {(w.session_id, w.turn): w
                     for w in requests if w.turn > 0}
        ready: List[Tuple[int, int, WorkloadRequest]] = []   # heap

        def _submit(w: WorkloadRequest):
            self.submit(w.prompt, w.max_new_tokens,
                        SamplerConfig(temperature=w.temperature,
                                      seed=w.sampler_seed),
                        prefix_key=w.prefix_key,
                        session_id=w.session_id, intent=w.intent,
                        sla_ticks=w.sla_ticks, index=w.index,
                        turn=w.turn)

        while ((openers or ready or followups or not self.is_idle())
               and self.tick < max_ticks):
            while openers and openers[0].arrival_tick <= self.tick:
                _submit(openers.popleft())
            while ready and ready[0][0] <= self.tick:
                _, _, w = heapq.heappop(ready)
                _submit(w)
            if (followups and not openers and not ready
                    and self.is_idle()):
                # nothing in flight can ever release these turns — fail
                # fast instead of spinning max_ticks no-op iterations
                raise ValueError(
                    "workload has follow-up turns whose predecessor "
                    f"turn never runs: {sorted(followups)}")
            self.step()
            for t in self._finished_traces:
                if t.session_id is None:
                    continue
                nxt = followups.pop((t.session_id, t.turn + 1), None)
                if nxt is not None:
                    heapq.heappush(ready, (t.finish_tick
                                           + nxt.arrival_tick,
                                           nxt.index, nxt))
        # a max_ticks cutoff can leave requests never submitted (late
        # openers, unreleased follow-up turns): record them as traces
        # with no admit/finish so `requests` and sla_attainment still
        # account for the whole workload (they count as SLA misses)
        leftovers = (list(openers) + [w for _, _, w in ready]
                     + list(followups.values()))
        for w in leftovers:
            self.traces[(-1, w.index)] = RequestTrace(
                index=w.index, replica=-1, request_id=-1,
                intent=w.intent, prefix_key=w.prefix_key,
                arrival_tick=(w.arrival_tick if w.turn == 0
                              else self.tick),
                sla_ticks=w.sla_ticks, session_id=w.session_id,
                turn=w.turn)
        per_replica = [
            dict(e.stats, **e.kv_memory_stats(), replica=i,
                 hit_ratio=round(e.stats["prefix_hits"]
                                 / max(e.stats["admissions"], 1), 4),
                 utilization=round(self._util_ticks[i]
                                   / max(self.tick * e.max_batch, 1), 4))
            for i, e in enumerate(self.replicas)]
        return ClusterStats(ticks=self.tick,
                            traces=sorted(self.traces.values(),
                                          key=lambda t: t.index),
                            per_replica=per_replica)

    # -------------------------------------------------------- stats ----
    def throughput_stats(self) -> Dict:
        """Engine-stat aggregate (single-engine-compatible keys, KV
        byte/block counters summed fleet-wide) plus a ``per_replica``
        breakdown."""
        keys = self.replicas[0].stats.keys()
        agg: Dict = {k: sum(e.stats[k] for e in self.replicas)
                     for k in keys}
        kv = [e.kv_memory_stats() for e in self.replicas]
        # every numeric kv counter sums fleet-wide; the schema lives in
        # engine.kv_memory_stats alone (no key list to keep in sync)
        agg.update({k: sum(m[k] for m in kv) for k in kv[0]
                    if k not in ("kv_mode", "kv_shared_frac")})
        agg["kv_mode"] = self.kv_mode
        agg["kv_shared_frac"] = round(
            sum(m["kv_blocks_shared_peak"] for m in kv)
            / max(sum(m["kv_blocks_used_peak"] for m in kv), 1), 4)
        agg["tokens_per_step"] = round(
            agg["tokens_generated"] / max(agg["decode_steps"], 1), 4)
        agg["spec_accept_rate"] = round(
            agg["spec_accepted"] / max(agg["spec_drafted"], 1), 4)
        agg["spec_k"] = self.spec_k
        agg["per_replica"] = [dict(e.stats, **m, replica=i)
                              for i, (e, m) in enumerate(
                                  zip(self.replicas, kv))]
        return agg
