"""Speculative decoding: a cheap draft model proposes K greedy tokens
per slot, the target verifies all of them in ONE forward.

GeckOpt's intent gating skews serving traffic onto a handful of hot
intents whose completions are highly predictable — exactly the regime
where a small ``planner_proxy_100m``-class draft agrees with the target
often enough that one target forward emits several tokens. The protocol
(wired into ``InferenceEngine.step`` when the engine is built with
``spec_decode=SpecConfig(...)``):

  1. **draft** — K greedy single-token steps of the draft model over
     every active slot (continuous batching, the draft keeps its own
     dense KV cache mirroring the target's per-slot fill levels);
  2. **verify** — ONE target ``verify_extend`` forward scores the
     carried last token plus all K proposals (W = K+1 rows per slot)
     against the target's dense or paged KV cache;
  3. **accept** — per slot, walk the W rows in order: sample the
     target's token for each position with the request's OWN sampler
     stream (``SamplerConfig.seed`` fold_in by output index — the same
     key schedule non-speculative decoding uses) and accept the draft
     proposal only if it EQUALS that sample. The first mismatch (or
     terminal token) stops the walk; the mismatched position emits the
     target's sample, a fully-accepted window emits the bonus K+1'th
     sample.

Because every emitted token is the target sampler's own draw under the
non-speculative key schedule, the emitted stream is BITWISE identical
to non-speculative decoding — at T=0 unconditionally (argmax ignores
keys), at any temperature for seeded requests. Classic stochastic
speculative sampling (accept with prob min(1, p/q)) only preserves the
distribution, not the realized sequence, so it cannot meet the engine's
determinism contract; sample-and-match trades a little acceptance for
exactness. Rejected tokens roll back by KV-length truncation: free in
the paged engine (the rows sit in blocks the slot already owns and are
overwritten before ever becoming visible), masked in dense storage.

The draft's KV cache is always dense (the draft is small — its slab is
the cheap part) and is rebuilt by a chunk-aligned prefill on paged
preempt-resume. The draft runs K+1 decode steps per round: K to propose
and one trailing step that writes the last proposal's KV row, so a
fully-accepted window leaves no hole in the draft cache (the engine
skips that step when no slot accepted the whole window).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.models.model import (decode_step, init_cache, prefill,
                                prefill_extend)

_SPEC_KINDS = {"full", "dense", "moe"}


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs for ``InferenceEngine``.

    draft_cfg/draft_params: the draft model (any pure-attention stack;
    typically a much smaller config than the target — the benches use
    the target itself as a perfect-agreement stand-in, since the repo
    ships no trained weights to distill a real draft from).
    k: draft tokens proposed per round (the verify forward scores k+1
    positions, so a round emits between 1 and k+1 tokens).
    draft_backend: kernel backend for the draft steps (default: the
    engine's backend)."""
    draft_cfg: ModelConfig
    draft_params: Any
    k: int = 4
    draft_backend: Optional[str] = None


def _spec_stack_error(what: str, kinds) -> str:
    return (f"spec_decode {what} needs a pure-attention stack "
            f"(kinds within {sorted(_SPEC_KINDS)} and no encoder): "
            f"recurrent state cannot be rolled back by KV-length "
            f"truncation; got kinds {sorted(kinds)}")


def check_spec_stack(cfg: ModelConfig, what: str):
    """Raise unless ``cfg`` supports multi-token verify + rollback."""
    kinds = {k for unit, _ in cfg.segments for k in unit}
    if cfg.n_enc_layers or not kinds <= _SPEC_KINDS:
        raise ValueError(_spec_stack_error(what, kinds))


class SpecDecoder:
    """Draft-model side of speculative decoding: owns the draft params,
    the draft's dense KV cache (one slot per engine slot, same
    ``cache_len``) and the jitted draft step functions. The engine owns
    acceptance, stats and the shared per-slot ``pos`` semantics: the
    draft cache holds KV for exactly the tokens the target cache holds
    (context minus the carried last token), and rolls back the same way
    (``set_pos`` truncation)."""

    def __init__(self, spec: SpecConfig, *, max_batch: int,
                 cache_len: int, backend: str, metrics=None):
        from repro.kernels.backend import get_backend
        if spec.k < 1:
            raise ValueError(f"spec_decode needs k >= 1, got {spec.k}")
        # optional obs registry publishers: draft forwards actually run
        # and catch-up steps spent (the engine's spec_* counters track
        # the protocol; these track the draft model's compute)
        self._c_draft = (metrics.counter("spec_draft_forwards")
                         if metrics else None)
        self._c_catchup = (metrics.counter("spec_catch_ups")
                           if metrics else None)
        check_spec_stack(spec.draft_cfg, "draft model")
        self.cfg = spec.draft_cfg
        self.params = spec.draft_params
        self.k = spec.k
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.backend = get_backend(spec.draft_backend or backend).name
        cfg, be = self.cfg, self.backend
        self.cache = init_cache(cfg, max_batch, cache_len)
        self.cache["pos"] = jnp.zeros((max_batch,), jnp.int32)
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, cache_len=cache_len,
                                 backend=be))
        self._decode = jax.jit(
            lambda p, c, b: decode_step(p, cfg, c, b, backend=be))
        self._extend = jax.jit(
            lambda p, c, b, n: prefill_extend(p, cfg, c, b, n_valid=n,
                                              backend=be))
        self._catchup_tokens: Optional[jnp.ndarray] = None

    def share_compiled(self, other: "SpecDecoder"):
        """Adopt another decoder's jitted step functions (cluster
        replicas with identical draft configs compile once, not N×)."""
        self._prefill = other._prefill
        self._decode = other._decode
        self._extend = other._extend

    def reset(self):
        """Back to the just-constructed state (cache storage is reused;
        stale rows are masked by the zeroed ``pos`` and overwritten at
        the next admission — same contract as ``InferenceEngine.reset``)."""
        self.cache["pos"] = jnp.zeros((self.max_batch,), jnp.int32)
        self._catchup_tokens = None

    # ------------------------------------------------------ admission ----
    def admit(self, slot: int, ctx_ids):
        """Prefill the draft over a request's context (its prompt — or
        prompt + output[:-1] when a preempted request resumes, the
        target's swap restores its KV but the draft's was dropped) and
        install it in ``slot``. Long contexts prefill on their
        chunk-aligned head and extend over the tail, like the engine's
        ``register_prefix``."""
        from repro.common.perf import get_flags
        from repro.serving.engine import (_insert_slot,
                                          advance_cache_through)
        ids = list(ctx_ids)
        assert 0 < len(ids) < self.cache_len, (len(ids), self.cache_len)
        align = get_flags().attn_chunk
        head = (ids if len(ids) <= align
                else ids[:(len(ids) // align) * align])
        prompt = jnp.asarray(head, jnp.int32)[None, :]
        logits, cache = self._prefill(self.params, {"tokens": prompt})
        cache = dict(cache)
        cache["pos"] = jnp.asarray(len(head), jnp.int32)
        _, cache = advance_cache_through(
            self.params, logits, cache, ids[len(head):],
            decode_fn=self._decode, extend_fn=self._extend,
            can_extend=True, pad_extend=True, cache_len=self.cache_len)
        self.cache = _insert_slot(self.cache, cache, slot)
        self.cache["pos"] = self.cache["pos"].at[slot].set(len(ids))

    # ------------------------------------------------------- drafting ----
    def draft(self, last_tokens) -> np.ndarray:
        """K greedy draft steps over every slot (continuous batching;
        idle slots ride along harmlessly, like the target's decode).
        Returns the (B, k) int proposals and stages the trailing
        catch-up token (see ``catch_up``). Leaves the draft cache's
        ``pos`` advanced by k — the engine overwrites it with the
        accepted lengths (``set_pos``)."""
        toks = last_tokens
        outs = []
        if self._c_draft is not None:
            self._c_draft.inc(self.k)
        for _ in range(self.k):
            logits, self.cache = self._decode(self.params, self.cache,
                                              {"tokens": toks})
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            outs.append(np.asarray(toks))
        self._catchup_tokens = toks
        return np.concatenate(outs, axis=1)

    def catch_up(self):
        """Write the last proposal's KV row (one extra draft step,
        logits discarded). Needed only when some slot accepted its
        whole window — its next-round context includes the K'th draft
        token, whose KV the K proposal steps never wrote. Harmless for
        other slots: the row lands past their truncated ``pos`` and is
        overwritten before becoming visible."""
        if self._c_catchup is not None:
            self._c_catchup.inc()
        _, self.cache = self._decode(self.params, self.cache,
                                     {"tokens": self._catchup_tokens})

    def set_pos(self, new_pos):
        """Adopt the target's post-acceptance fill levels — the
        KV-length truncation that rolls back rejected draft rows."""
        self.cache["pos"] = jnp.asarray(new_pos, jnp.int32)
