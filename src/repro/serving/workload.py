"""Seeded synthetic traffic generator for the serving cluster.

The paper measures GeckOpt on a live Copilot platform; we cannot replay
that traffic, so this module synthesizes it: a deterministic request
schedule drawn entirely from one ``numpy`` rng — NO wall-clock
randomness — so any two runs (or any two cluster configurations) see
the exact same traffic. Time is measured in abstract *ticks*: one tick
is one cluster step (one continuous-batching decode iteration per
replica), which keeps every latency metric reproducible.

A workload is a list of ``WorkloadRequest``:

  * **intents** are drawn from a configurable mix over
    ``core.intents.INTENTS`` (``uniform_mix`` / ``skewed_mix`` presets —
    the skewed mix is what makes intent-affinity routing measurably
    better than round-robin);
  * every request of an intent shares that intent's prompt prefix
    (``intent_prefix``), so replicas that registered the prefix serve it
    from the prompt-prefix cache;
  * **arrival profiles**: ``uniform`` (evenly spaced by the
    inter-arrival parameter), ``poisson`` (seeded exponential gaps) and
    ``bursty`` (bursts of ``burst_size`` simultaneous arrivals, spaced
    so the mean rate matches);
  * **multi-turn sessions**: a session draws 1..max_turns turns; turn 0
    carries an absolute ``arrival_tick``, later turns carry the gap
    after the previous turn finishes (the cluster releases them);
  * per-request **SLA deadlines** (ticks) and per-request sampler seeds
    (``SamplerConfig.seed``), so outputs are a pure function of the
    workload — the cluster parity tests depend on this.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.intents import INTENT_DESCRIPTIONS, INTENTS

PROFILES = ("uniform", "poisson", "bursty")

# single-word places: prompts stay one fixed token length per intent
_PLACES = ("Tampa", "Rotterdam", "Singapore", "Nairobi", "Oslo",
           "Lima", "Osaka", "Perth")

# One template per intent, fixed word count so prompt token lengths stay
# per-intent constant (the engine jit-retraces per distinct prefill
# shape; a handful of lengths keeps cluster tests warm).
_QUERY_TEMPLATES = {
    "load_filter_plot": "plot filtered imagery tiles around {place} now",
    "ui_web_navigation": "open the catalog browser page for {place}",
    "information_seeking": "look up archive facts describing {place}",
    "detection_analysis": "count detected ships moored near {place}",
    "landcover_analysis": "compare dominant landcover classes at {place}",
    "visual_qa": "describe what is shown above {place}",
    "speech_transcription": "transcribe the field recording from {place}",
    "code_analysis": "tabulate the analysis results for {place}",
}


def intent_prefix(intent: str) -> str:
    """The shared per-intent system prompt (every request of the intent
    starts with it; the cluster registers it once on its home replica)."""
    return (f"System: you are the {intent} copilot of the platform. "
            f"Scope: {INTENT_DESCRIPTIONS[intent]}. Answer tersely.")


def prefix_key_for(intent: str) -> str:
    return f"intent:{intent}"


def uniform_mix(intents=INTENTS) -> Dict[str, float]:
    return {i: 1.0 / len(intents) for i in intents}


def skewed_mix(hot: str = "load_filter_plot", hot_frac: float = 0.7,
               intents=INTENTS) -> Dict[str, float]:
    """One hot intent takes ``hot_frac`` of traffic; the rest split the
    remainder evenly (the cluster-bench's affinity-vs-round-robin mix).
    ``hot_frac=1.0`` is the degenerate all-hot-intent workload."""
    if hot not in intents or not 0.0 < hot_frac <= 1.0 \
            or len(intents) < 2:
        raise ValueError(f"skewed_mix needs >= 2 intents, hot among "
                         f"them and 0 < hot_frac <= 1, got "
                         f"{hot!r}, {hot_frac}, {len(intents)} intents")
    cold = (1.0 - hot_frac) / (len(intents) - 1)
    return {i: (hot_frac if i == hot else cold) for i in intents}


@dataclass(frozen=True)
class WorkloadRequest:
    index: int                 # position in the generated workload
    session_id: int
    turn: int                  # 0-based turn within the session
    n_turns: int
    arrival_tick: int          # absolute (turn 0) / gap after the
    #                            previous turn finishes (turn > 0)
    intent: str
    prefix_key: Optional[str]
    prompt: str
    max_new_tokens: int
    sla_ticks: int             # e2e deadline in ticks from arrival
    sampler_seed: int
    temperature: float


@dataclass
class WorkloadConfig:
    n_sessions: int = 16
    seed: int = 0
    intent_mix: Optional[Dict[str, float]] = None   # default: uniform
    profile: str = "uniform"
    inter_arrival: float = 1.0   # mean ticks between session arrivals
    burst_size: int = 4          # arrivals per burst ("bursty" profile)
    max_turns: int = 1           # session length drawn from 1..max_turns
    turn_gap: int = 1            # ticks between turn finish and next turn
    max_new_tokens: int = 4
    temperature: float = 0.0
    sla_ticks: int = 64
    use_prefix: bool = True      # tag requests with the intent prefix key
    # long-prompt tail (the stall-free-scheduling bench's bursty mixed
    # workload): each session is long with probability long_frac, and a
    # long session pads every turn's prompt with ~long_words extra
    # words (~1 token each). 0.0 keeps the rng stream — and therefore
    # every existing workload — bit-identical.
    long_frac: float = 0.0
    long_words: int = 128


def _arrival_schedule(cfg: WorkloadConfig, rng: np.random.Generator,
                      n: int) -> List[int]:
    ia = max(cfg.inter_arrival, 1e-6)
    if cfg.profile == "uniform":
        return [int(i * ia) for i in range(n)]
    if cfg.profile == "poisson":
        gaps = rng.exponential(ia, size=n)
        return [int(t) for t in np.cumsum(gaps) - gaps[0]]
    if cfg.profile == "bursty":
        return [int((i // cfg.burst_size) * ia * cfg.burst_size)
                for i in range(n)]
    raise ValueError(f"unknown profile {cfg.profile!r}; "
                     f"choose from {PROFILES}")


def make_workload(cfg: WorkloadConfig) -> List[WorkloadRequest]:
    """Generate the full request list, sorted by (arrival, index) for
    turn-0 requests with follow-up turns interleaved after their
    session's opener. Deterministic: same config => identical list."""
    mix = cfg.intent_mix or uniform_mix()
    intents = sorted(mix)
    probs = np.asarray([mix[i] for i in intents], dtype=np.float64)
    probs = probs / probs.sum()
    rng = np.random.default_rng(cfg.seed)

    arrivals = _arrival_schedule(cfg, rng, cfg.n_sessions)
    out: List[WorkloadRequest] = []
    for sid in range(cfg.n_sessions):
        intent = intents[int(rng.choice(len(intents), p=probs))]
        n_turns = (1 if cfg.max_turns <= 1
                   else 1 + int(rng.integers(0, cfg.max_turns)))
        place = _PLACES[int(rng.integers(0, len(_PLACES)))]
        # draw the long flag ONLY when the tail is enabled: long_frac=0
        # consumes no rng, so pre-existing workloads stay bit-identical
        long = (cfg.long_frac > 0.0
                and float(rng.random()) < cfg.long_frac)
        prefix = intent_prefix(intent)
        for turn in range(n_turns):
            idx = len(out)
            query = _QUERY_TEMPLATES[intent].format(place=place)
            if long:
                # ~1 token per short word; fixed filler keeps prompt
                # lengths per-(intent, long) constant so the engine's
                # jit stays warm across sessions
                query += " context " + " ".join(
                    ["item"] * max(cfg.long_words - 1, 0))
            prompt = (f"{prefix} Session {sid:03d} turn {turn} "
                      f"request {idx:04d}: {query}")
            out.append(WorkloadRequest(
                index=idx, session_id=sid, turn=turn, n_turns=n_turns,
                arrival_tick=(arrivals[sid] if turn == 0
                              else cfg.turn_gap),
                intent=intent,
                prefix_key=(prefix_key_for(intent) if cfg.use_prefix
                            else None),
                prompt=prompt,
                max_new_tokens=cfg.max_new_tokens,
                sla_ticks=cfg.sla_ticks + int(rng.integers(
                    0, max(cfg.sla_ticks // 4, 1))),
                sampler_seed=int(rng.integers(0, 2**31 - 1)),
                temperature=cfg.temperature))
    return out


def workload_intents(requests: List[WorkloadRequest]) -> Dict[str, int]:
    """Per-SESSION intent counts (turns of one session share an intent)."""
    seen: Dict[int, str] = {}
    for w in requests:
        seen.setdefault(w.session_id, w.intent)
    counts: Dict[str, int] = {}
    for intent in seen.values():
        counts[intent] = counts.get(intent, 0) + 1
    return counts


def register_workload_prefixes(target, requests: List[WorkloadRequest]
                               ) -> Dict[str, int]:
    """Register every intent prefix appearing in the workload on
    ``target`` (an ``InferenceEngine`` or ``EngineCluster``); returns
    {prefix_key: prefix_len}."""
    done: Dict[str, int] = {}
    for w in requests:
        if w.prefix_key and w.prefix_key not in done:
            done[w.prefix_key] = target.register_prefix(
                w.prefix_key, intent_prefix(w.intent))
    return done
