"""Paged KV-cache block allocator: refcounted fixed-size blocks with
copy-on-write forking.

The dense engine reserves ``max_batch * cache_len`` KV rows up front and
physically copies the per-intent prefix cache into every slot it admits.
This module is the vLLM-style alternative: KV memory is a fixed budget of
``n_blocks`` blocks of ``block_size`` token rows each, and every request
holds a *block table* — an ordered list of block ids covering its logical
``[0, n_tokens)`` rows. Sharing is by refcount:

  * ``fork``     — share every block of an existing table (refcount++,
                   zero copies). The engine forks a registered prefix's
                   table into each admission, so N same-intent slots hold
                   the prefix once, not N times.
  * ``cow_from`` — copy-on-write: replace the table's entries from block
                   ``j`` on with freshly-owned blocks (the physical row
                   copy is the caller's single scatter — the pool only
                   manages ownership). A forked table CoWs its partial
                   tail block before the slot writes suffix/decode rows
                   into it; fully-shared prefix blocks are never written.
  * ``grow``     — extend a table to cover more tokens (decode appends).
  * ``free``     — drop the table; blocks return to the free list when
                   their refcount hits zero.

Allocation order is deterministic (lowest-id free block first, via a min
heap), so a paged engine run is exactly reproducible — the property the
dense-vs-paged bitwise parity tests rest on. The pool is pure host-side
bookkeeping: device storage lives in the engine's paged cache pytree
(models/model.py ``init_paged_cache``), indexed by these block ids.

The pool does not evict on its own: the engine decides *what* is cold
(LRU prefix pins) and *who* is lowest priority (preempt-and-requeue);
the pool exposes the refcount/free-count facts those policies need.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List


class KVPoolExhausted(RuntimeError):
    """No free block. The engine should have evicted or preempted first;
    reaching this means an accounting bug, so fail loudly."""


@dataclass
class BlockTable:
    """One request's (or pinned prefix's) view of the pool: ordered block
    ids covering logical token rows [0, n_tokens)."""
    blocks: List[int] = field(default_factory=list)
    n_tokens: int = 0

    def __len__(self) -> int:
        return len(self.blocks)


class KVBlockPool:
    """Deterministic refcounted allocator over ``n_blocks`` fixed-size
    blocks. All methods are O(log n) or O(table); none touch device
    memory."""

    def __init__(self, n_blocks: int, block_size: int, metrics=None):
        if n_blocks <= 0 or block_size <= 0:
            raise ValueError(f"need positive n_blocks/block_size, got "
                             f"{n_blocks}/{block_size}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.ref = [0] * n_blocks
        self._free = list(range(n_blocks))      # min-heap: lowest id first
        heapq.heapify(self._free)
        # incremental count of blocks with ref > 1: shared/owned stats
        # are read every engine step, so no O(n_blocks) scans there
        self._n_shared = 0
        # optional obs registry gauges, refreshed after every mutator
        self._g_used = metrics.gauge("kv_blocks_used") if metrics else None
        self._g_free = metrics.gauge("kv_blocks_free") if metrics else None
        self._g_shared = (metrics.gauge("kv_blocks_shared")
                          if metrics else None)
        self._publish()

    def _publish(self):
        if self._g_used is not None:
            self._g_used.set(self.used_blocks())
            self._g_free.set(self.free_blocks())
            self._g_shared.set(self.shared_blocks())

    # ------------------------------------------------------- introspection ----
    def free_blocks(self) -> int:
        return len(self._free)

    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def shared_blocks(self) -> int:
        """Blocks referenced by more than one table (CoW-shared)."""
        return self._n_shared

    def owned_blocks(self) -> int:
        """Blocks referenced by exactly one table."""
        return self.used_blocks() - self._n_shared

    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)         # ceil div

    # --------------------------------------------------------- allocation ----
    def _alloc_block(self) -> int:
        if not self._free:
            raise KVPoolExhausted(
                f"all {self.n_blocks} KV blocks in use")
        b = heapq.heappop(self._free)
        assert self.ref[b] == 0, (b, self.ref[b])
        self.ref[b] = 1
        return b

    def alloc(self, n_tokens: int) -> BlockTable:
        """Fresh table covering ``n_tokens`` rows, all blocks owned."""
        need = self.blocks_needed(n_tokens)
        if need > len(self._free):
            raise KVPoolExhausted(
                f"need {need} blocks, {len(self._free)} free")
        t = BlockTable([self._alloc_block() for _ in range(need)],
                       n_tokens)
        self._publish()
        return t

    def fork(self, table: BlockTable, n_tokens: int = -1) -> BlockTable:
        """Share every block of ``table`` (refcount++, zero copies).
        ``n_tokens`` defaults to the source's length."""
        for b in table.blocks:
            assert self.ref[b] > 0, b
            if self.ref[b] == 1:
                self._n_shared += 1
            self.ref[b] += 1
        self._publish()
        return BlockTable(list(table.blocks),
                          table.n_tokens if n_tokens < 0 else n_tokens)

    def cow_from(self, table: BlockTable, j: int) -> List[int]:
        """Copy-on-write: give ``table`` exclusive ownership of entries
        [j, len). Shared entries are swapped for fresh blocks (the caller
        scatters the row data); already-exclusive entries are kept.
        Returns the logical indices that changed block id."""
        changed: List[int] = []
        for i in range(j, len(table.blocks)):
            old = table.blocks[i]
            if self.ref[old] == 1:
                continue                       # already exclusive
            # alloc BEFORE release: if the pool is exhausted mid-walk
            # the table still references only live blocks
            new = self._alloc_block()
            self._release(old)
            table.blocks[i] = new
            changed.append(i)
        self._publish()
        return changed

    def append_block(self, table: BlockTable) -> int:
        """Append one freshly-owned block (decode growth). Does not
        advance ``n_tokens`` — the caller advances it as rows are
        actually written. Returns the new block id."""
        b = self._alloc_block()
        table.blocks.append(b)
        self._publish()
        return b

    def grow(self, table: BlockTable, n_tokens: int) -> List[int]:
        """Extend ``table`` to cover ``n_tokens`` rows; returns the
        logical indices of the appended blocks."""
        need = self.blocks_needed(n_tokens)
        if n_tokens < table.n_tokens:
            raise ValueError(f"grow would shrink: {n_tokens} < "
                             f"{table.n_tokens}")
        added: List[int] = []
        while len(table.blocks) < need:
            self.append_block(table)
            added.append(len(table.blocks) - 1)
        table.n_tokens = n_tokens
        return added

    # -------------------------------------------------------------- free ----
    def _release(self, b: int):
        assert 0 <= b < self.n_blocks, b
        if self.ref[b] <= 0:
            raise KVPoolExhausted(f"double free of block {b}")
        if self.ref[b] == 2:
            self._n_shared -= 1
        self.ref[b] -= 1
        if self.ref[b] == 0:
            heapq.heappush(self._free, b)

    def free(self, table: BlockTable):
        """Release every block of ``table`` and empty it (a freed table
        cannot be double-freed — it holds no blocks)."""
        for b in table.blocks:
            self._release(b)
        table.blocks = []
        table.n_tokens = 0
        self._publish()

    # -------------------------------------------------------------- stats ----
    def stats(self) -> Dict[str, int]:
        return {"kv_blocks_total": self.n_blocks,
                "kv_blocks_used": self.used_blocks(),
                "kv_blocks_free": self.free_blocks(),
                "kv_blocks_shared": self.shared_blocks(),
                "kv_blocks_owned": self.owned_blocks()}

    def check_invariants(self):
        """Internal-consistency assertions (the property tests call this
        after every operation)."""
        assert len(self._free) + sum(1 for r in self.ref if r > 0) \
            == self.n_blocks, "free + referenced != total"
        assert all(r >= 0 for r in self.ref)
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "duplicate free block"
        assert all(self.ref[b] == 0 for b in free_set), \
            "referenced block on the free list"
        assert self._n_shared == sum(1 for r in self.ref if r > 1), \
            "incremental shared count drifted from the refcounts"
