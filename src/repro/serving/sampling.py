"""Token sampling policies (pure JAX)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => no top-k truncation
    # Per-request sampling stream. None (default): the engine draws from
    # its own rng, so tokens depend on engine seed and admission order.
    # An int decouples the request from its engine: token i is sampled
    # with fold_in(PRNGKey(seed), i), making outputs a pure function of
    # (prompt, seed) — the property the multi-replica cluster relies on
    # for exact token parity across routing policies (serving/cluster.py).
    seed: Optional[int] = None


def sample(logits, rng, cfg: SamplerConfig):
    """logits: (B, V) fp32 -> token ids (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jnp.sort(scaled, axis=-1)[:, -cfg.top_k][:, None]
        scaled = jnp.where(scaled < kth, -1e30, scaled)
    return jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)
