"""Neural GeckOpt components served by our own engine.

``NeuralIntentClassifier`` replaces the scripted gate classifier with a
real model: the planner-proxy LM scores each intent label as a
continuation of the gate prompt (constrained decoding over the 8-way
intent grammar — no free-form generation can escape the taxonomy).

``BatchedNeuralIntentClassifier`` makes the same decisions but scores
every (query, intent) pair of a pipeline admission wave in ONE jitted
``(Q*8, L)`` forward pass instead of Q*8 sequential B=1 calls — the gate
hot path of serving/pipeline.py (benchmarks/pipeline_bench.py measures
the speedup; tests/test_pipeline.py proves decision equivalence).

``make_intent_dataset`` builds (query -> intent) LM training pairs from
the task generator; examples/train_planner.py fine-tunes the proxy on
them and plugs the result into the Table-2 harness.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.core.intents import INTENTS
from repro.models import layers as L
from repro.models.model import _apply_stack, _embed_inputs, _logits
from repro.serving.tokenizer import TOKENIZER


def encode_pair(query: str, intent: str, seq_len: int) -> Tuple[np.ndarray,
                                                                np.ndarray]:
    """LM pair: loss only on the intent suffix."""
    q = TOKENIZER.encode(f"classify intent: {query} => ")
    a = TOKENIZER.encode(intent)
    toks = (q + a)[:seq_len]
    labels = ([-1] * len(q) + list(a))[:seq_len]
    pad = seq_len - len(toks)
    tokens = np.array(toks + [0] * pad, np.int32)
    labs = np.array([-1] + labels[1:] + [-1] * pad, np.int32)
    # labels are next-token: shift left by one
    labs = np.concatenate([labs[1:], [-1]]).astype(np.int32)
    return tokens, labs


def make_intent_dataset(tasks, seq_len: int = 64, batch: int = 16):
    pairs = [encode_pair(t.query, t.intent, seq_len) for t in tasks]
    rng = np.random.default_rng(0)

    def batches():
        while True:
            idx = rng.integers(0, len(pairs), batch)
            toks = np.stack([pairs[i][0] for i in idx])
            labs = np.stack([pairs[i][1] for i in idx])
            yield {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}
    return batches()


def per_example_loss(params, cfg: ModelConfig, batch,
                     chunk: int = 16) -> jnp.ndarray:
    """Per-row masked LM loss (B,) — ``train_loss`` without the
    cross-example mean, chunked over S so (B,S,V) logits never
    materialize. MoE aux loss is omitted: it is a load-balancing
    regularizer, not a per-example likelihood (the intent argmin only
    compares label-token losses)."""
    x, positions = _embed_inputs(params, cfg, batch)
    x, _, _ = _apply_stack(params, cfg, x, mode="train",
                           positions=positions, remat=False)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    B, S, d = x.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    xs = x.reshape(B, nc, chunk, d).swapaxes(0, 1)
    ls = batch["labels"].reshape(B, nc, chunk).swapaxes(0, 1)

    def body(acc, inp):
        xc, lc = inp
        logits = _logits(params, cfg, xc)                  # (B,C,V) fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        loss = jnp.sum((lse - picked) * mask, axis=-1)     # (B,)
        return (acc[0] + loss, acc[1] + jnp.sum(mask, axis=-1)), ()

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((B,)), jnp.zeros((B,))), (xs, ls))
    return tot / jnp.maximum(cnt, 1.0)


class NeuralIntentClassifier:
    """Scores each intent by LM loss of its label continuation.

    Scoring uses ``per_example_loss`` (pure label-token likelihood, MoE
    aux excluded) so the batched classifier's single-pass decisions
    match this one by construction on every stack kind."""

    def __init__(self, cfg: ModelConfig, params, seq_len: int = 64):
        self.cfg = cfg
        self.params = params
        self.seq_len = seq_len
        self._loss = jax.jit(
            lambda p, b: per_example_loss(p, cfg, b)[0])

    def classify(self, query: str) -> Tuple[str, str]:
        losses = []
        for intent in INTENTS:
            toks, labs = encode_pair(query, intent, self.seq_len)
            batch = {"tokens": jnp.asarray(toks)[None],
                     "labels": jnp.asarray(labs)[None]}
            losses.append(float(self._loss(self.params, batch)))
        best = INTENTS[int(np.argmin(losses))]
        return best, best

    def classify_batch(self, queries: Sequence[str]
                       ) -> List[Tuple[str, str]]:
        return [self.classify(q) for q in queries]

    def accuracy(self, tasks) -> float:
        hits = sum(self.classify(t.query)[0] == t.intent for t in tasks)
        return hits / max(len(tasks), 1)


class BatchedNeuralIntentClassifier:
    """Same decisions as ``NeuralIntentClassifier``, one forward pass.

    All Q queries × 8 intents are encoded into a single ``(Q*8, L)``
    batch and scored by one jitted ``per_example_loss`` call; the intent
    with the minimum label-suffix loss wins per query. Row counts are
    padded to a power of two (by repeating the last row) so jit retraces
    O(log Q) times across varying pipeline wave sizes, not once per Q.
    """

    def __init__(self, cfg: ModelConfig, params, seq_len: int = 64):
        self.cfg = cfg
        self.params = params
        self.seq_len = seq_len
        self._losses = jax.jit(
            lambda p, b: per_example_loss(p, cfg, b))

    def _encode_rows(self, queries: Sequence[str]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        pairs = [encode_pair(q, intent, self.seq_len)
                 for q in queries for intent in INTENTS]
        return (np.stack([p[0] for p in pairs]),
                np.stack([p[1] for p in pairs]))

    def losses(self, queries: Sequence[str]) -> np.ndarray:
        """(Q, 8) label-suffix loss matrix for all queries/intents."""
        toks, labs = self._encode_rows(queries)
        rows = toks.shape[0]
        padded = max(8, 1 << (rows - 1).bit_length())
        if padded > rows:
            reps = padded - rows
            toks = np.concatenate([toks, np.repeat(toks[-1:], reps, 0)])
            labs = np.concatenate([labs, np.repeat(labs[-1:], reps, 0)])
        out = self._losses(self.params, {"tokens": jnp.asarray(toks),
                                         "labels": jnp.asarray(labs)})
        return np.asarray(out)[:rows].reshape(len(queries), len(INTENTS))

    def classify_batch(self, queries: Sequence[str]
                       ) -> List[Tuple[str, str]]:
        if not queries:
            return []
        best = np.argmin(self.losses(queries), axis=1)
        return [(INTENTS[int(i)],) * 2 for i in best]

    def classify(self, query: str) -> Tuple[str, str]:
        return self.classify_batch([query])[0]

    def accuracy(self, tasks) -> float:
        decisions = self.classify_batch([t.query for t in tasks])
        hits = sum(d[0] == t.intent for d, t in zip(decisions, tasks))
        return hits / max(len(tasks), 1)
