"""Neural GeckOpt components served by our own engine.

``NeuralIntentClassifier`` replaces the scripted gate classifier with a
real model: the planner-proxy LM scores each intent label as a
continuation of the gate prompt (constrained decoding over the 8-way
intent grammar — no free-form generation can escape the taxonomy).

``make_intent_dataset`` builds (query -> intent) LM training pairs from
the task generator; examples/train_planner.py fine-tunes the proxy on
them and plugs the result into the Table-2 harness.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.core.intents import INTENTS
from repro.models.model import train_loss
from repro.serving.tokenizer import TOKENIZER


def encode_pair(query: str, intent: str, seq_len: int) -> Tuple[np.ndarray,
                                                                np.ndarray]:
    """LM pair: loss only on the intent suffix."""
    q = TOKENIZER.encode(f"classify intent: {query} => ")
    a = TOKENIZER.encode(intent)
    toks = (q + a)[:seq_len]
    labels = ([-1] * len(q) + list(a))[:seq_len]
    pad = seq_len - len(toks)
    tokens = np.array(toks + [0] * pad, np.int32)
    labs = np.array([-1] + labels[1:] + [-1] * pad, np.int32)
    # labels are next-token: shift left by one
    labs = np.concatenate([labs[1:], [-1]]).astype(np.int32)
    return tokens, labs


def make_intent_dataset(tasks, seq_len: int = 64, batch: int = 16):
    pairs = [encode_pair(t.query, t.intent, seq_len) for t in tasks]
    rng = np.random.default_rng(0)

    def batches():
        while True:
            idx = rng.integers(0, len(pairs), batch)
            toks = np.stack([pairs[i][0] for i in idx])
            labs = np.stack([pairs[i][1] for i in idx])
            yield {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}
    return batches()


class NeuralIntentClassifier:
    """Scores each intent by LM loss of its label continuation."""

    def __init__(self, cfg: ModelConfig, params, seq_len: int = 64):
        self.cfg = cfg
        self.params = params
        self.seq_len = seq_len
        self._loss = jax.jit(
            lambda p, b: train_loss(p, cfg, b, remat=False))

    def classify(self, query: str) -> Tuple[str, str]:
        losses = []
        for intent in INTENTS:
            toks, labs = encode_pair(query, intent, self.seq_len)
            batch = {"tokens": jnp.asarray(toks)[None],
                     "labels": jnp.asarray(labs)[None]}
            losses.append(float(self._loss(self.params, batch)))
        best = INTENTS[int(np.argmin(losses))]
        return best, best

    def accuracy(self, tasks) -> float:
        hits = sum(self.classify(t.query)[0] == t.intent for t in tasks)
        return hits / max(len(tasks), 1)
