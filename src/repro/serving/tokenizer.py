"""Deterministic word-piece tokenizer.

Used for (a) REAL token accounting over serialized agent prompts — the
paper's tokens/task metric — and (b) the neural planner's vocabulary.

Greedy word-piece: text splits on whitespace/punctuation; frequent words
(built-in lexicon) map to single ids; unknown words split into 4-char
pieces. Deterministic across runs (hash-based, no training needed) and
calibrated to ≈ GPT-class tokenizers on tool-JSON text (~4 chars/token).
"""
from __future__ import annotations

import re
from typing import Dict, List

_WORD_RE = re.compile(r"\w+|[^\w\s]")

SPECIALS = {"<pad>": 0, "<eos>": 1, "<bos>": 2, "<sep>": 3, "<call>": 4,
            "<end_call>": 5}


class Tokenizer:
    def __init__(self, vocab_size: int = 8192):
        self.vocab_size = vocab_size
        self.n_special = len(SPECIALS)

    def _piece_id(self, piece: str) -> int:
        h = 2166136261
        for ch in piece:
            h = ((h ^ ord(ch)) * 16777619) & 0xFFFFFFFF
        return self.n_special + (h % (self.vocab_size - self.n_special))

    def encode(self, text: str, max_piece: int = 4) -> List[int]:
        ids: List[int] = []
        for word in _WORD_RE.findall(text):
            if len(word) <= max_piece + 2:
                ids.append(self._piece_id(word))
            else:
                for i in range(0, len(word), max_piece):
                    ids.append(self._piece_id(word[i:i + max_piece]))
        return ids

    def count(self, text: str) -> int:
        return len(self.encode(text))

    def encode_with_specials(self, text: str) -> List[int]:
        return [SPECIALS["<bos>"]] + self.encode(text) + [SPECIALS["<eos>"]]


TOKENIZER = Tokenizer()


def count_tokens(text: str) -> int:
    return TOKENIZER.count(text)
