"""Concurrent GeckOpt request pipeline: gate → plan → execute for many
Copilot sessions at once.

The paper's setting is a massively parallel Copilot platform ("over 100
GPT-4-Turbo nodes"); the sequential Table-2 loop (one task to
completion, then the next) models its *token* economics but not its
*serving* shape. This module runs N sessions through the three stages
concurrently, the way a real fleet does:

  1. **Admission.** Pending tasks are admitted in arrival order until
     ``max_concurrent`` sessions are in flight (a fresh wave whenever
     slots free up).
  2. **Batched gating.** Each admission wave is classified in ONE
     batched gate call (``IntentGate.batch``): with a
     ``BatchedNeuralIntentClassifier`` that is a single jitted
     ``(Q*8, L)`` forward pass over every (query, intent) pair instead
     of Q*8 sequential B=1 calls.
  3. **Interleaved planning.** Active sessions advance round-robin, one
     planner step per pipeline tick — continuous batching at the agent
     level. Per-session state (workspace rng, planner rng, ledger) is
     isolated and the World is read-only, so results are bit-identical
     to the sequential harness at the same seed
     (tests/test_pipeline.py asserts this; DESIGN.md §Pipeline
     concurrency has the argument).

Optionally the pipeline mirrors its LLM traffic onto a real
``InferenceEngine``: every session's planner prompt shares a per-intent
prefix (the gated system prompt + catalog, see
``ScriptedPlanner.serialize_prompt_prefix``), which the engine prefills
once per intent and reuses across all sessions via its prompt-prefix
cache — examples/serve_pipeline.py and benchmarks/pipeline_bench.py
drive this path.

``engine`` may equally be a multi-replica ``EngineCluster``
(serving/cluster.py): the cluster exposes the same ``register_prefix``
/ ``prefixes`` / ``open_session`` / ``step`` / ``run_until_done``
surface, and its router pins every session to its intent prefix's home
replica — examples/serve_pipeline.py ``--replicas N --router
intent_affinity`` serves the pipeline on a fleet.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.agent import Agent, AgentSession, TaskResult
from repro.core.planner import CompiledStep
from repro.env.evaluator import EvalReport, evaluate_results
from repro.env.tasks import Task
from repro.env.tools_impl import execute_graph_batch
from repro.obs import MetricsRegistry, NULL_TRACER
from repro.serving.sampling import SamplerConfig


@dataclass
class PipelineConfig:
    max_concurrent: int = 16     # in-flight session cap (slot pool)
    gate_batch: int = 32         # max queries per batched gate call
    # engine mirroring: serve each session's first planner turn through
    # the InferenceEngine with per-intent prefix caching
    engine_turns: bool = True
    engine_max_new_tokens: int = 8
    # cross-session fusion: when the agent's planner compiles plans
    # (PlannerConfig.compile_plans), merge every active session's
    # round-trip DAG into ONE batched tool execution per tick
    # (env/tools_impl.execute_graph_batch). Per-session outcomes are
    # bitwise identical either way (disjoint workspaces + fixed
    # (session, node) reconciliation order); this just makes the wave
    # the execution unit, the way a fleet batches its tool backends.
    fuse_sessions: bool = True


# registry-backed PipelineStats fields (attribute surface preserved as
# properties reading/writing the underlying metric objects):
#   admitted/gate_batches/ticks/engine_turns — stage throughput;
#   fused_batches/fused_calls/plan_round_trips/plan_virtual_steps — the
#     tool-graph compiler's cross-session fused execution;
#   peak_concurrent/fused_sessions_peak — high-water gauges.
_PIPE_COUNTERS = ("admitted", "gate_batches", "ticks", "engine_turns",
                  "fused_batches", "fused_calls", "plan_round_trips",
                  "plan_virtual_steps", "retrievals", "retrieval_widens")
_PIPE_GAUGES = ("peak_concurrent", "fused_sessions_peak")


class PipelineStats:
    """Pipeline stage counters, now views over an obs metrics registry
    (``pipeline_*`` metrics) — the attribute surface of the old
    dataclass is preserved via properties, so existing readers
    (`stats.admitted += 1`, benches, tests) are untouched. The engine_*
    descriptor fields stay plain attributes: they describe the serving
    configuration, not the run."""

    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self.metrics = (metrics if metrics is not None
                        else MetricsRegistry())
        self._c = {k: self.metrics.counter("pipeline_" + k)
                   for k in _PIPE_COUNTERS}
        self._g = {k: self.metrics.gauge("pipeline_" + k)
                   for k in _PIPE_GAUGES}
        self._h_gate = self.metrics.histogram("pipeline_gate_batch_size")
        self.engine_backend = ""     # kernel backend of mirrored engine
        self.engine_replicas = 0     # 1 = single engine, N = cluster
        self.engine_kv_mode = ""     # "dense" | "paged"
        self.engine_spec_k = 0       # draft tokens/round (0 = spec off)
        self.engine_prefill_budget = 0   # chunked-prefill tokens (0=off)
        self.engine_admission = ""   # "fifo" | "slack"

    @property
    def gate_batch_sizes(self) -> List[int]:
        return [int(v) for v in self._h_gate.values]

    def observe_gate_batch(self, n: int):
        self._h_gate.observe(n)

    def summary(self) -> Dict[str, float]:
        # mean_gate_batch follows the empty-series convention: None
        # (rendered "n/a"), never a fabricated 0.0
        return {"admitted": self.admitted,
                "gate_batches": self.gate_batches,
                "mean_gate_batch": self._h_gate.mean(),
                "ticks": self.ticks,
                "peak_concurrent": self.peak_concurrent,
                "engine_turns": self.engine_turns,
                "engine_backend": self.engine_backend,
                "engine_replicas": self.engine_replicas,
                "engine_kv_mode": self.engine_kv_mode,
                "engine_spec_k": self.engine_spec_k,
                "engine_prefill_budget": self.engine_prefill_budget,
                "engine_admission": self.engine_admission,
                "fused_batches": self.fused_batches,
                "fused_calls": self.fused_calls,
                "fused_sessions_peak": self.fused_sessions_peak,
                "plan_round_trips": self.plan_round_trips,
                "plan_virtual_steps": self.plan_virtual_steps,
                "retrievals": self.retrievals,
                "retrieval_widens": self.retrieval_widens}


def _metric_prop(store: str, key: str) -> property:
    return property(
        lambda self: getattr(self, store)[key].value,
        lambda self, v: setattr(getattr(self, store)[key], "value", v))


for _k in _PIPE_COUNTERS:
    setattr(PipelineStats, _k, _metric_prop("_c", _k))
for _k in _PIPE_GAUGES:
    setattr(PipelineStats, _k, _metric_prop("_g", _k))


class GeckOptPipeline:
    """Drives many agent sessions through gate → plan → execute.

    ``engine`` is optional: without it the pipeline is the pure
    agent-level scheduler the Table-2 harness uses; with it, planner
    turns are additionally served by the continuous-batching engine so
    prefix-cache reuse and tokens/s are measurable.
    """

    def __init__(self, agent: Agent, config: Optional[PipelineConfig]
                 = None, engine=None, *, tracer=None,
                 metrics: Optional[MetricsRegistry] = None):
        self.agent = agent
        self.config = config or PipelineConfig()
        self.engine = engine
        # observability is injected like the engine's: pass the engine's
        # tracer/metrics to correlate pipeline-level gate/plan/execute
        # spans with the per-request engine spans in one trace
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if tracer is not None and agent.tracer is NULL_TRACER:
            # surface the agent's retrieve/widen spans in the same trace
            agent.tracer = tracer
        self.stats = PipelineStats(metrics)
        if engine is not None:
            # kernel backend rides in with the engine (see engine.py);
            # surfaced here so pipeline summaries record which backend
            # served the run end-to-end
            self.stats.engine_backend = getattr(engine, "backend", "")
            # an EngineCluster carries .replicas; a bare engine is 1
            self.stats.engine_replicas = len(
                getattr(engine, "replicas", ())) or 1
            self.stats.engine_kv_mode = getattr(engine, "kv_mode", "")
            self.stats.engine_spec_k = getattr(engine, "spec_k", 0)
            # scheduling knobs live on the engine; a cluster's replicas
            # are homogeneous, so replica 0 speaks for the fleet
            e0 = (getattr(engine, "replicas", None) or [engine])[0]
            self.stats.engine_prefill_budget = \
                getattr(e0, "prefill_budget", None) or 0
            self.stats.engine_admission = getattr(e0, "admission", "")
        self._engine_sessions = []

    # ---------------------------------------------------------- stages ----
    def _admit(self, queue: deque, active: List[AgentSession]
               ) -> List[AgentSession]:
        wave: List[AgentSession] = []
        while queue and len(active) + len(wave) < \
                self.config.max_concurrent:
            index, task = queue.popleft()
            session = self.agent.start_session(task, task_seed=index)
            session.index = index
            wave.append(session)
        self.stats.admitted += len(wave)
        return wave

    def _gate_wave(self, wave: List[AgentSession]):
        """One batched gate call per admission wave (chunked to
        ``gate_batch``), in task order — so a stateful classifier (the
        scripted one draws from one rng stream) sees the exact same
        call sequence as the sequential harness."""
        if self.agent.gate is None or not wave:
            return
        cb = self.config.gate_batch
        for lo in range(0, len(wave), cb):
            chunk = wave[lo:lo + cb]
            h = self.tracer.begin("gate", tick=self.stats.ticks,
                                  group="pipeline", lane="gate",
                                  batch=len(chunk))
            decisions = self.agent.gate.batch(
                [s.task.query for s in chunk],
                [s.ledger for s in chunk])
            self.stats.gate_batches += 1
            self.stats.observe_gate_batch(len(chunk))
            for session, (intent, libs) in zip(chunk, decisions):
                self.agent.apply_gate_result(session, intent, libs)
            self.tracer.end(h, tick=self.stats.ticks)

    def _retrieve_wave(self, wave: List[AgentSession]):
        """One batched retrieval per admission wave (the analogue of
        ``_gate_wave``): every query's full-catalog ranking is computed
        in ONE jitted scoring call, fused with the per-session gated
        intent prior."""
        ag = self.agent
        if ag.exposure != "retrieved" or not wave:
            return
        h = self.tracer.begin("retrieve", tick=self.stats.ticks,
                              group="pipeline", lane="retrieve",
                              batch=len(wave))
        exposures = ag.retriever.retrieve_batch(
            [s.task.query for s in wave], [s.intent for s in wave])
        for session, exposure in zip(wave, exposures):
            ag.apply_retrieval_result(session, exposure)
        self.stats.retrievals += len(wave)
        self.tracer.end(h, tick=self.stats.ticks)

    def _mirror_to_engine(self, session: AgentSession):
        """Serve the session's first planner turn on the engine. All
        sessions gated to the same intent share one cached prefix
        prefill (the gated system prompt + catalog) — and with toolset
        retrieval on, sessions retrieving the same toolset share one
        prefix keyed by the canonical ``toolset_key`` (rendezvous-routed
        across a cluster like an intent prefix)."""
        if self.engine is None or not self.config.engine_turns:
            return
        if session.exposure is not None:
            key = session.exposure.key_str
        else:
            key = f"planner:{session.intent or 'full-catalog'}"
        prefix_text = session.planner.serialize_prompt_prefix(
            session.catalog)
        if key not in self.engine.prefixes:
            self.engine.register_prefix(key, prefix_text)
        es = self.engine.open_session(prefix_key=key)
        es.submit_turn(f"{prefix_text}\nTask: {session.task.query}",
                       max_new_tokens=self.config.engine_max_new_tokens,
                       sampler=SamplerConfig(temperature=0.0))
        self._engine_sessions.append(es)
        self.stats.engine_turns += 1

    def _tick_sessions(self, active: List[AgentSession]
                       ) -> List[AgentSession]:
        """Advance every active session one planner round-trip; returns
        the sessions that finished this tick.

        With the tool-graph compiler on (and ``fuse_sessions``), the
        tick is three phases instead of per-session loops: every session
        plans its compiled round-trip, ALL their DAGs execute in one
        fused ``execute_graph_batch`` wave run, and observations
        reconcile back per session in (session, node id) order — the
        pipeline's cross-session execution path. Outcomes are bitwise
        identical to stepping each session alone (disjoint workspaces).
        """
        fusing = (self.config.fuse_sessions
                  and self.agent.planner_cfg.compile_plans)
        tick = self.stats.ticks
        if not fusing:
            h = self.tracer.begin("plan", tick=tick, group="pipeline",
                                  lane="plan", sessions=len(active))
            done = [s for s in active if self.agent.step_session(s)]
            self.tracer.end(h, tick=tick, finished=len(done))
            return done
        h = self.tracer.begin("plan", tick=tick, group="pipeline",
                              lane="plan", sessions=len(active))
        planned = [(s, self.agent.plan_step(s)) for s in active]
        self.tracer.end(h, tick=tick, round_trips=len(planned))
        entries = [(s.index, s.workspace, step.graph)
                   for s, step in planned
                   if isinstance(step, CompiledStep) and step.graph.nodes]
        n_calls = sum(len(g.nodes) for _, _, g in entries)
        hx = self.tracer.begin("execute_wave", tick=tick,
                               group="pipeline", lane="execute",
                               sessions=len(entries), calls=n_calls)
        observations = execute_graph_batch(entries) if entries else {}
        self.tracer.end(hx, tick=tick)
        if entries:
            self.stats.fused_batches += 1
            self.stats.fused_calls += n_calls
            self.stats.fused_sessions_peak = max(
                self.stats.fused_sessions_peak, len(entries))
        self.stats.plan_round_trips += len(planned)
        self.stats.plan_virtual_steps += sum(
            step.n_virtual for _, step in planned
            if isinstance(step, CompiledStep))
        return [s for s, step in planned
                if self.agent.apply_step(s, step,
                                         observations.get(s.index))]

    # ------------------------------------------------------------- run ----
    def run(self, tasks: Sequence[Task]) -> List[TaskResult]:
        """Run every task to completion; TaskResults in task order."""
        queue = deque(enumerate(tasks))
        active: List[AgentSession] = []
        results: List[Optional[TaskResult]] = [None] * len(tasks)
        finished_turns = []
        while queue or active:
            wave = self._admit(queue, active)
            self._gate_wave(wave)
            self._retrieve_wave(wave)
            for session in wave:
                self._mirror_to_engine(session)
            active.extend(wave)
            self.stats.peak_concurrent = max(self.stats.peak_concurrent,
                                             len(active))
            self.stats.ticks += 1
            if self.engine is not None:
                # overlap engine decode with agent ticks
                finished_turns.extend(self.engine.step())
            finished = self._tick_sessions(active)
            for session in finished:
                results[session.index] = session.result()
                if session.exposure is not None:
                    self.stats.retrieval_widens += session.exposure.widens
            done_ids = {id(s) for s in finished}
            active = [s for s in active if id(s) not in done_ids]
        if self.engine is not None:
            finished_turns.extend(self.engine.run_until_done())
            for es in self._engine_sessions:
                es.collect(finished_turns)
        return [r for r in results if r is not None]


def run_pipeline(agent: Agent, tasks: Sequence[Task],
                 max_concurrent: int = 16, engine=None,
                 config: Optional[PipelineConfig] = None
                 ) -> List[TaskResult]:
    cfg = config or PipelineConfig(max_concurrent=max_concurrent)
    return GeckOptPipeline(agent, cfg, engine=engine).run(tasks)


def evaluate_pipeline(agent: Agent, tasks: Sequence[Task],
                      name: str = "run", max_concurrent: int = 16,
                      engine=None) -> EvalReport:
    """Drop-in concurrent replacement for env.evaluator.evaluate —
    same metrics, N sessions in flight."""
    return evaluate_results(
        run_pipeline(agent, tasks, max_concurrent, engine=engine), name)
