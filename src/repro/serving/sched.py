"""Deterministic admission scheduling for the inference engine.

The engine's admission queue used to be a plain FIFO deque; under SLA
deadlines FIFO is the wrong order — a long-slack request admitted ahead
of a nearly-expired one burns the tight one's deadline for nothing.
``AdmissionQueue`` keeps both policies behind one surface:

  * ``"fifo"``  — arrival order (the seed behavior): a deque whose head
    is popped once per free slot; preemption requeues at the head so a
    swapped-out request resumes before new arrivals.
  * ``"slack"`` — earliest-deadline-first: requests carrying an SLA
    deadline (``Request.sla_ticks``, deadline = enqueue step + sla)
    admit in deadline order; deadline-less requests sort AFTER every
    deadline-carrying one, in arrival order. The order is a pure
    function of (deadline, request_id) — two integer keys, no dict or
    hash iteration anywhere — so the same arrivals produce the same
    admission order on any machine and under any PYTHONHASHSEED
    (tests/test_interleave.py asserts it).

Both policies are strict total orders, so the queue never depends on
heap insertion history: ``pop`` always returns the unique minimum.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Iterator, List, Optional

ADMISSION_POLICIES = ("fifo", "slack")

# deadline sentinel for requests with no SLA: sorts after every real
# deadline while keeping the key an int (no float("inf") keys — the
# determinism lint bans float ordering keys in serving)
NO_DEADLINE = 1 << 62


def deadline_step(req) -> int:
    """Absolute step by which ``req`` must FINISH to meet its SLA
    (``NO_DEADLINE`` when it carries none). e2e latency is
    ``finish_step - enqueue_step + 1`` ticks, so the last step that can
    still meet an ``sla_ticks`` budget is ``enqueue + sla - 1``; the
    deadline is the first step that cannot."""
    if req.sla_ticks is None:
        return NO_DEADLINE
    return req.enqueue_step + req.sla_ticks


class AdmissionQueue:
    """Engine admission queue with a pluggable, deterministic order."""

    def __init__(self, policy: str = "fifo", metrics=None):
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"admission policy must be one of "
                             f"{ADMISSION_POLICIES}, got {policy!r}")
        self.policy = policy
        self._fifo: deque = deque()
        self._heap: List[tuple] = []
        # optional obs registry publishers (the engine passes its
        # registry; standalone queues skip the bookkeeping entirely)
        self._c_push = metrics.counter("queue_pushes") if metrics else None
        self._c_pop = metrics.counter("queue_pops") if metrics else None
        self._g_depth = (metrics.gauge("queue_depth_peak")
                         if metrics else None)

    def _key(self, req) -> tuple:
        # (deadline, request_id): request_id is engine-local and
        # monotone, so ties between same-deadline requests stay in
        # arrival order and the key is unique (the heap never compares
        # Request objects)
        return (deadline_step(req), req.request_id)

    def push(self, req, front: bool = False):
        """Enqueue. ``front=True`` is the preemption requeue: FIFO puts
        the victim back at the head (it resumes before new arrivals);
        slack mode ignores it — the victim re-competes by its deadline,
        which is what SLA-aware scheduling means."""
        if self.policy == "fifo":
            (self._fifo.appendleft if front
             else self._fifo.append)(req)
        else:
            heapq.heappush(self._heap, (*self._key(req), req))
        if self._c_push is not None:
            self._c_push.inc()
            self._g_depth.max(len(self))

    def pop(self):
        if self._c_pop is not None:
            self._c_pop.inc()
        if self.policy == "fifo":
            return self._fifo.popleft()
        return heapq.heappop(self._heap)[-1]

    def peek(self):
        if self.policy == "fifo":
            return self._fifo[0]
        return self._heap[0][-1]

    def clear(self):
        self._fifo.clear()
        self._heap.clear()

    def __len__(self) -> int:
        return (len(self._fifo) if self.policy == "fifo"
                else len(self._heap))

    def __iter__(self) -> Iterator:
        """Iterate in pop order without mutating the queue."""
        if self.policy == "fifo":
            return iter(self._fifo)
        return (item[-1] for item in sorted(self._heap))


def victim_key(req, policy: str = "fifo"):
    """Sort key whose MAXIMUM is the preferred preemption victim.

    FIFO keeps the seed rule — preempt the latest-admitted request
    (highest request_id). Slack mode preempts the request with the most
    deadline slack (latest deadline; deadline-less requests first of
    all), tie-broken by request_id so the choice stays deterministic."""
    if policy == "fifo":
        return req.request_id
    return (deadline_step(req), req.request_id)
