# Pallas TPU kernels for the serving hot paths (flash prefill/decode
# attention, fused MoE router top-k, selective-SSM scan, mLSTM scan),
# their pure-jnp oracles (ref.py), and the pluggable backend registry
# (backend.py) the model stack dispatches through — see DESIGN.md
# §Kernel backends.
