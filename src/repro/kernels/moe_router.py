"""Fused MoE router (softmax + top-k) — Pallas TPU kernel.

grid = (T / block_t,); each instance handles a (block_t, E) tile of router
logits resident in VMEM and produces normalized top-k weights + expert ids
via k iterative argmax passes (k ≤ 8, E ≤ 512 — the (block_t, E) tile and
its fp32 softmax fit VMEM comfortably).

Capacity masking is cross-token (a global cumsum) and stays outside the
kernel, in models/moe.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(x_ref, w_ref, i_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)                 # (bt, E)
    bt, E = x.shape
    m = jnp.max(x, axis=1, keepdims=True)
    p = jnp.exp(x - m)
    probs = p / jnp.sum(p, axis=1, keepdims=True)

    work = probs
    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)
    tot = jnp.zeros((bt, 1), jnp.float32)
    ws, ids = [], []
    for _ in range(k):
        best = jnp.max(work, axis=1, keepdims=True)
        best_idx = jnp.argmax(work, axis=1)            # (bt,)
        ws.append(best)
        ids.append(best_idx[:, None])
        tot = tot + best
        work = jnp.where(cols == best_idx[:, None], NEG_INF, work)
    w = jnp.concatenate(ws, axis=1) / jnp.maximum(tot, 1e-9)
    i = jnp.concatenate(ids, axis=1)
    w_ref[...] = w
    i_ref[...] = i.astype(jnp.int32)


def moe_router_topk(logits, k: int, *, block_t: int = 256,
                    interpret: bool = True):
    """logits: (T, E) -> (weights (T,k) fp32, idx (T,k) int32)."""
    T, E = logits.shape
    block_t = min(block_t, T)
    while T % block_t:
        block_t //= 2
    assert T % block_t == 0
    nt = T // block_t

    kernel = functools.partial(_kernel, k=k)
    w, i = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((block_t, E), lambda t: (t, 0))],
        out_specs=[pl.BlockSpec((block_t, k), lambda t: (t, 0)),
                   pl.BlockSpec((block_t, k), lambda t: (t, 0))],
        out_shape=[jax.ShapeDtypeStruct((T, k), jnp.float32),
                   jax.ShapeDtypeStruct((T, k), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(logits)
    return w, i
