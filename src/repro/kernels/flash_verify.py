"""Flash speculative-verify attention — Pallas TPU kernels.

Speculative decoding scores the K drafted tokens (plus the carried last
token) of every slot in ONE target forward: W = K+1 query rows per
sequence attend a (partially) filled KV cache, causally at per-slot
offsets. Two storage layouts share one kernel body:

  flash_verify        dense caches (B, Hkv, Sk, hd) — the k-axis grid /
                      tiling mirrors flash_decode exactly;
  flash_verify_paged  block pools (n_blocks, Hkv, bs, hd) + a per-slot
                      block table — the block-axis grid / DMA walk
                      mirrors flash_decode_paged exactly.

  grid = (B, Hkv, Sk/block_k | max_blocks), k axis sequential
  q tile    (G*W, hd)        VMEM (all G q-heads x W verify rows of one
                                   kv head; the (G*W, block_k) score
                                   tile feeds the MXU)
  k/v tiles (block_k|bs, hd) VMEM
  m/l/acc   scratch          VMEM (fp32 online softmax)

Per-slot ``kv_len`` (valid rows AFTER the verify write — query row w
sits at absolute position kv_len - W + w) arrives via scalar prefetch,
the paged variant additionally prefetching the block table into its
BlockSpec index_map like flash_decode_paged.

Mirroring matters beyond performance: every fp32 op in the online
softmax is row-independent and accumulated over the SAME k-partition as
the decode kernels, so verify row w is bitwise identical to what
flash_decode/flash_decode_paged would produce for a single token at
that position — the property that makes speculative decoding emit
exactly the non-speculative token stream (DESIGN.md §Speculative
decoding).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, cap: float, scale: float, block_k: int, nk: int, W: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    kv_len = kvlen_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                  # (G*W, hd)
    k = k_ref[...].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[...].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # row r of the tile is verify position w = r % W of q-head r // W;
    # its absolute position is kv_len - W + w (causal per row)
    w = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % W
    mask = kpos <= kv_len - W + w
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _kernel_paged(tab_ref, kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr,
                  l_scr, acc_scr, *, cap, scale, block_k, nk, W):
    # the block table is consumed by the BlockSpec index_maps only; the
    # kernel body is identical to the dense variant
    _kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            cap=cap, scale=scale, block_k=block_k, nk=nk, W=W)


def flash_verify(q, k_cache, v_cache, kv_len, *, cap: float = 0.0,
                 scale: float = 0.0, block_k: int = 512,
                 interpret: bool = True):
    """q: (B,Hq,W,hd); caches: (B,Hkv,Sk,hd); kv_len: scalar or (B,)
    int32 — valid rows after the verify write. Returns (B,Hq,W,hd)."""
    B, Hq, W, hd = q.shape
    Hkv, Sk = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale else 1.0 / math.sqrt(hd)
    # identical k-partition derivation to flash_decode: the per-row
    # accumulation order must match the decode kernel bit for bit
    block_k = min(block_k, Sk)
    while Sk % block_k:
        block_k //= 2
    assert Sk % block_k == 0
    nk = Sk // block_k

    qf = q.reshape(B, Hkv, G * W, hd)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1),
                              (B,))

    kernel = functools.partial(_kernel, cap=cap, scale=scale,
                               block_k=block_k, nk=nk, W=W)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((None, None, G * W, hd),
                         lambda b, h, ki, kvl: (b, h, 0, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda b, h, ki, kvl: (b, h, ki, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda b, h, ki, kvl: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G * W, hd),
                               lambda b, h, ki, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G * W, 1), jnp.float32),
            pltpu.VMEM((G * W, 1), jnp.float32),
            pltpu.VMEM((G * W, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G * W, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_len, qf, k_cache, v_cache)
    return out.reshape(B, Hq, W, hd)


def flash_verify_paged(q, k_pages, v_pages, block_tab, kv_len, *,
                       cap: float = 0.0, scale: float = 0.0,
                       interpret: bool = True):
    """q: (B,Hq,W,hd); pages: (n_blocks,Hkv,bs,hd); block_tab: (B,mb)
    int32 (entries >= n_blocks are sentinels); kv_len: scalar or (B,)
    int32 — valid rows after the verify write. Returns (B,Hq,W,hd)."""
    B, Hq, W, hd = q.shape
    n_blocks, Hkv, bs, _ = k_pages.shape
    G = Hq // Hkv
    mb = block_tab.shape[1]
    scale = scale if scale else 1.0 / math.sqrt(hd)

    qf = q.reshape(B, Hkv, G * W, hd)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1),
                              (B,))
    # sentinel entries must still name a resident block for the DMA;
    # the per-row kv_len/causal mask kills every row they contribute
    tab = jnp.clip(block_tab.astype(jnp.int32), 0, n_blocks - 1)

    kernel = functools.partial(_kernel_paged, cap=cap, scale=scale,
                               block_k=bs, nk=mb, W=W)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, mb),
        in_specs=[
            pl.BlockSpec((None, None, G * W, hd),
                         lambda b, h, j, tab, kvl: (b, h, 0, 0)),
            pl.BlockSpec((None, None, bs, hd),
                         lambda b, h, j, tab, kvl: (tab[b, j], h, 0, 0)),
            pl.BlockSpec((None, None, bs, hd),
                         lambda b, h, j, tab, kvl: (tab[b, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G * W, hd),
                               lambda b, h, j, tab, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G * W, 1), jnp.float32),
            pltpu.VMEM((G * W, 1), jnp.float32),
            pltpu.VMEM((G * W, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G * W, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tab, kv_len, qf, k_pages, v_pages)
    return out.reshape(B, Hq, W, hd)
