"""Flash prefill attention — Pallas TPU kernel.

Online-softmax over KV blocks with explicit VMEM tiling:
  grid = (B * Hq, Sq/block_q, Sk/block_k), k-axis "arbitrary" (sequential)
  q tile    (block_q, hd)   VMEM
  k/v tiles (block_k, hd)   VMEM
  m/l/acc   scratch         VMEM (fp32)

Causal, sliding-window and logit-softcap variants are compile-time flags.
block_q/block_k default to 128/256 — multiples of the 128-wide MXU tile,
with the (block_q, block_k) score tile + accumulators well inside the
~16 MiB/core VMEM budget for hd ≤ 256.

GQA: the kv head index is derived from the q head index in the BlockSpec
index maps (hq // group).

``q_offset`` (the absolute position of q[0], for chunked-prefill extend
against a pre-filled cache) arrives via scalar prefetch (SMEM) so one
compiled kernel serves any continuation point; cache rows at or beyond
q_offset + Sq are masked by the causal term.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qoff_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, cap: float, scale: float,
            block_q: int, block_k: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    q_off = qoff_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                 # (bq, hd)
    k = k_ref[...].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[...].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)

    qpos = q_off + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, causal: bool = True, window: int = 0,
                  cap: float = 0.0, scale: float = 0.0, q_offset=0,
                  block_q: int = 128, block_k: int = 256,
                  interpret: bool = True):
    """q: (B,Hq,Sq,hd); k,v: (B,Hkv,Sk,hd) -> (B,Hq,Sq,hd).

    q_offset: absolute position of q[0] (python int or traced scalar)."""
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    while Sq % block_q:
        block_q //= 2
    while Sk % block_k:
        block_k //= 2
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k

    qf = q.reshape(B * Hq, Sq, hd)
    kf = k.reshape(B * Hkv, Sk, hd)
    vf = v.reshape(B * Hkv, Sk, hd)
    q_off = jnp.asarray(q_offset, jnp.int32).reshape((1,))

    kernel = functools.partial(
        _kernel, causal=causal, window=window, cap=cap, scale=scale,
        block_q=block_q, block_k=block_k, nk=nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, hd),
                         lambda bh, qi, ki, qo: (bh, qi, 0)),
            pl.BlockSpec((None, block_k, hd),
                         lambda bh, qi, ki, qo, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((None, block_k, hd),
                         lambda bh, qi, ki, qo, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd),
                               lambda bh, qi, ki, qo: (bh, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_off, qf, kf, vf)
    return out.reshape(B, Hq, Sq, hd)
