"""Paged flash GQA decode attention — Pallas TPU kernel.

Single new token per sequence attending to a KV cache scattered over
fixed-size blocks of a shared physical pool (serving/kvpool.py):

  grid = (B, Hkv, max_blocks), block axis sequential
  q tile    (G, hd)          VMEM (all G q-heads of one kv head)
  k/v tiles (block_size, hd) VMEM — fetched from the HBM-resident pool
                             at the PHYSICAL block the per-sequence
                             block table names for this logical block
  m/l/acc   scratch          VMEM (fp32 online softmax)

The block table (B, max_blocks) and per-sequence kv lengths (B,) arrive
via scalar prefetch (SMEM): the table is read inside the k/v BlockSpec
index_map, so the DMA engine walks each sequence's scattered blocks
while the same compiled kernel serves any table contents. Logical
blocks at or past ceil(kv_len / block_size) are masked out entirely
(their table entries are clamped sentinels pointing at an arbitrary
resident block — the fetch is harmless and the scores never survive
the kv_len mask).

Accumulation is sequential over the logical block axis — position
order — exactly like flash_decode's k-axis, just at block_size
granularity against non-contiguous storage.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tab_ref, kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, *, cap: float, scale: float, block_size: int,
            nb: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    kv_len = kvlen_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                  # (G, hd)
    k = k_ref[...].astype(jnp.float32)                  # (bs, hd)
    v = v_ref[...].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    kpos = j * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_paged(q, k_pages, v_pages, block_tab, kv_len, *,
                       cap: float = 0.0, scale: float = 0.0,
                       interpret: bool = True):
    """q: (B,Hq,hd); pages: (n_blocks,Hkv,bs,hd); block_tab: (B,mb)
    int32 (entries >= n_blocks are sentinels); kv_len: scalar or (B,)
    int32. Returns (B,Hq,hd)."""
    B, Hq, hd = q.shape
    n_blocks, Hkv, bs, _ = k_pages.shape
    G = Hq // Hkv
    mb = block_tab.shape[1]
    scale = scale if scale else 1.0 / math.sqrt(hd)

    qf = q.reshape(B, Hkv, G, hd)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1),
                              (B,))
    # sentinel entries must still name a resident block for the DMA;
    # kv_len masks every row they would contribute
    tab = jnp.clip(block_tab.astype(jnp.int32), 0, n_blocks - 1)

    kernel = functools.partial(_kernel, cap=cap, scale=scale,
                               block_size=bs, nb=mb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, mb),
        in_specs=[
            pl.BlockSpec((None, None, G, hd),
                         lambda b, h, j, tab, kvl: (b, h, 0, 0)),
            pl.BlockSpec((None, None, bs, hd),
                         lambda b, h, j, tab, kvl: (tab[b, j], h, 0, 0)),
            pl.BlockSpec((None, None, bs, hd),
                         lambda b, h, j, tab, kvl: (tab[b, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd),
                               lambda b, h, j, tab, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tab, kv_len, qf, k_pages, v_pages)
    return out.reshape(B, Hq, hd)
