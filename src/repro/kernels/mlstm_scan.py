"""Sequential stabilized mLSTM recurrence — Pallas TPU kernel.

grid = (B * H, S/block_s), s-axis "arbitrary" (sequential); the per-head
state — C (hd, hd) matrix memory, n (1, hd) normalizer, m (1, 1) gate
stabilizer — lives in VMEM scratch across s-blocks with a fori_loop over
the block_s timesteps inside the kernel. Each step is an (hd, hd)
elementwise decay + rank-1 update plus an (hd,)·(hd,hd) matvec readout;
hd ≤ 128 keeps the whole state resident in VMEM.

Step order mirrors kernels/ref.mlstm_scan_ref exactly: the output divides
by max(|n·q|, exp(-m)), a catastrophically cancelled dot, so reassociating
the state updates is amplified without bound near zero denominators (see
models/xlstm.py). Initial state arrives as explicit inputs and the final
state is returned, so serving continues a sequence through the same
kernel (decode / chunked-prefill extend).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, i_ref, f_ref, c0_ref, n0_ref, m0_ref,
            h_ref, cf_ref, nf_ref, mf_ref, c_scr, n_scr, m_scr, *,
            block_s: int, ns: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        c_scr[...] = c0_ref[...]
        n_scr[...] = n0_ref[...]
        m_scr[...] = m0_ref[...]

    def step(t, _):
        q_t = q_ref[t, :].astype(jnp.float32)           # (hd,)
        ks_t = k_ref[t, :].astype(jnp.float32)          # pre-scaled k
        v_t = v_ref[t, :].astype(jnp.float32)
        i_t = i_ref[t, 0]
        logf = jax.nn.log_sigmoid(f_ref[t, 0])
        m_prev = m_scr[0, 0]
        m_new = jnp.maximum(logf + m_prev, i_t)
        fw = jnp.exp(logf + m_prev - m_new)
        iw = jnp.exp(i_t - m_new)
        C = c_scr[...] * fw + iw * (ks_t[:, None] * v_t[None, :])
        n = n_scr[...] * fw + iw * ks_t[None, :]        # (1, hd)
        num = jnp.sum(C * q_t[:, None], axis=0)         # C^T q, (hd,)
        den = jnp.maximum(jnp.abs(jnp.sum(n[0] * q_t)), jnp.exp(-m_new))
        h_ref[t, :] = (num / den).astype(h_ref.dtype)
        c_scr[...] = C
        n_scr[...] = n
        m_scr[0, 0] = m_new
        return _

    jax.lax.fori_loop(0, block_s, step, 0)

    @pl.when(si == ns - 1)
    def _finalize():
        cf_ref[...] = c_scr[...]
        nf_ref[...] = n_scr[...]
        mf_ref[...] = m_scr[...]


def mlstm_scan(q, k, v, i_pre, f_pre, state=None, *, scale: float = 0.0,
               block_s: int = 256, interpret: bool = True):
    """q, k, v: (B,H,S,hd); i_pre, f_pre: (B,H,S); state: optional
    (C (B,H,hd,hd), n (B,H,hd), m (B,H)) — models/xlstm.mlstm_state_init
    layout. Returns (h (B,H,S,hd) fp32, new_state)."""
    B, H, S, hd = q.shape
    scale = scale if scale else 1.0 / math.sqrt(hd)
    BH = B * H
    block_s = min(block_s, S)
    while S % block_s:
        block_s //= 2
    assert S % block_s == 0
    ns = S // block_s

    if state is None:
        state = (jnp.zeros((B, H, hd, hd), jnp.float32),
                 jnp.zeros((B, H, hd), jnp.float32),
                 jnp.full((B, H), -1e30, jnp.float32))
    C0, n0, m0 = state

    qf = q.reshape(BH, S, hd).astype(jnp.float32)
    kf = (k * scale).reshape(BH, S, hd).astype(jnp.float32)
    vf = v.reshape(BH, S, hd).astype(jnp.float32)
    i_f = i_pre.reshape(BH, S, 1).astype(jnp.float32)
    f_f = f_pre.reshape(BH, S, 1).astype(jnp.float32)
    c0 = C0.reshape(BH, hd, hd).astype(jnp.float32)
    n0f = n0.reshape(BH, 1, hd).astype(jnp.float32)
    m0f = m0.reshape(BH, 1, 1).astype(jnp.float32)

    kernel = functools.partial(_kernel, block_s=block_s, ns=ns)
    seq = pl.BlockSpec((None, block_s, hd), lambda bh, s: (bh, s, 0))
    gate = pl.BlockSpec((None, block_s, 1), lambda bh, s: (bh, s, 0))
    cspec = pl.BlockSpec((None, hd, hd), lambda bh, s: (bh, 0, 0))
    nspec = pl.BlockSpec((None, 1, hd), lambda bh, s: (bh, 0, 0))
    mspec = pl.BlockSpec((None, 1, 1), lambda bh, s: (bh, 0, 0))
    h, cf, nf, mf = pl.pallas_call(
        kernel,
        grid=(BH, ns),
        in_specs=[seq, seq, seq, gate, gate, cspec, nspec, mspec],
        out_specs=[seq, cspec, nspec, mspec],
        out_shape=[jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
                   jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
                   jax.ShapeDtypeStruct((BH, 1, hd), jnp.float32),
                   jax.ShapeDtypeStruct((BH, 1, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32),
                        pltpu.VMEM((1, hd), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, i_f, f_f, c0, n0f, m0f)
    return (h.reshape(B, H, S, hd),
            (cf.reshape(B, H, hd, hd), nf.reshape(B, H, hd),
             mf.reshape(B, H)))
