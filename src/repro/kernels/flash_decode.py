"""Flash GQA decode attention — Pallas TPU kernel.

Single new token attending to a long KV cache:
  grid = (B, Hkv, Sk/block_k), k-axis sequential
  q tile    (G, hd)        VMEM  (all G q-heads of one kv head together —
                                  the (G, block_k) score tile feeds the MXU)
  k/v tiles (block_k, hd)  VMEM  streamed from the HBM-resident cache
  m/l/acc   scratch        VMEM  (fp32)

``kv_len`` (valid cache entries) arrives via scalar prefetch (SMEM) so the
same compiled kernel serves any fill level; blocks past kv_len are masked.
A scalar kv_len serves a synchronized batch; a (B,) vector serves
continuous batching, where every slot sits at its own fill level.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            cap: float, scale: float, block_k: int, nk: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    kv_len = kvlen_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                  # (G, hd)
    k = k_ref[...].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[...].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, kv_len, *, cap: float = 0.0,
                 scale: float = 0.0, block_k: int = 512,
                 interpret: bool = True):
    """q: (B,Hq,hd); caches: (B,Hkv,Sk,hd); kv_len: scalar or (B,) int32.

    Returns (B,Hq,hd)."""
    B, Hq, hd = q.shape
    Hkv, Sk = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale else 1.0 / math.sqrt(hd)
    block_k = min(block_k, Sk)
    while Sk % block_k:
        block_k //= 2
    assert Sk % block_k == 0
    nk = Sk // block_k

    qf = q.reshape(B, Hkv, G, hd)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1),
                              (B,))

    kernel = functools.partial(_kernel, cap=cap, scale=scale,
                               block_k=block_k, nk=nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((None, None, G, hd), lambda b, h, ki, kvl: (b, h, 0, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda b, h, ki, kvl: (b, h, ki, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda b, h, ki, kvl: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd),
                               lambda b, h, ki, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_len, qf, k_cache, v_cache)
    return out.reshape(B, Hq, hd)
