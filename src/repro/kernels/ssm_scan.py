"""Selective SSM scan — Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: the recurrence is blocked as
  grid = (B, d_inner/block_d, S/block_s), s-axis sequential
with the (block_d, n) state carried in VMEM scratch across s-blocks and a
sequential fori_loop over the block_s timesteps inside the kernel (the
(block_d, n) update is a VPU-wide elementwise op; n=16 keeps the state
tile tiny, so the kernel is bandwidth-bound on dt/x streaming, which is
the roofline-optimal regime for SSMs).

The scan starts from an explicit initial state ``h0`` and returns the
final state alongside the outputs, so serving can continue a sequence
(decode / chunked-prefill extend) through the same kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hf_ref,
            h_scr, *, block_s: int, ns: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[...]

    A = a_ref[...]                                      # (bd, n)

    def step(t, h):
        dt_t = dt_ref[t, :].astype(jnp.float32)         # (bd,)
        x_t = x_ref[t, :].astype(jnp.float32)
        b_t = b_ref[t, :].astype(jnp.float32)           # (n,)
        c_t = c_ref[t, :].astype(jnp.float32)
        a = jnp.exp(dt_t[:, None] * A)                  # (bd, n)
        h = a * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[t, :] = jnp.sum(h * c_t[None, :], axis=1).astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, block_s, step, h_scr[...])

    @pl.when(si == ns - 1)
    def _finalize():
        hf_ref[...] = h_scr[...]


def ssm_scan(dt, x, B_, C_, A, h0=None, *, block_d: int = 256,
             block_s: int = 256, interpret: bool = True):
    """dt, x: (B,S,di); B_, C_: (B,S,n); A: (di,n); h0: optional initial
    state (B,di,n). Returns (y (B,S,di) fp32, h_last (B,di,n) fp32)."""
    Bsz, S, di = x.shape
    n = A.shape[-1]
    block_d = min(block_d, di)
    block_s = min(block_s, S)
    while di % block_d:
        block_d //= 2
    while S % block_s:
        block_s //= 2
    assert di % block_d == 0 and S % block_s == 0
    nd, ns = di // block_d, S // block_s
    if h0 is None:
        h0 = jnp.zeros((Bsz, di, n), jnp.float32)

    kernel = functools.partial(_kernel, block_s=block_s, ns=ns)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(Bsz, nd, ns),
        in_specs=[
            pl.BlockSpec((None, block_s, block_d),
                         lambda b, d, s: (b, s, d)),
            pl.BlockSpec((None, block_s, block_d),
                         lambda b, d, s: (b, s, d)),
            pl.BlockSpec((None, block_s, n), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((None, block_s, n), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((block_d, n), lambda b, d, s: (d, 0)),
            pl.BlockSpec((None, block_d, n), lambda b, d, s: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_s, block_d),
                         lambda b, d, s: (b, s, d)),
            pl.BlockSpec((None, block_d, n), lambda b, d, s: (b, d, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((Bsz, S, di), jnp.float32),
                   jax.ShapeDtypeStruct((Bsz, di, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dt, x, B_, C_, A.astype(jnp.float32), h0.astype(jnp.float32))
    return y, h_last
