"""Pluggable attention/op kernel backend registry.

Every compute hot-spot in the model stack (attention prefill/extend,
cache decode, MoE router top-k, selective-SSM scan, mLSTM recurrence)
dispatches through a named :class:`KernelBackend`:

  * ``reference`` — the pure-jnp paths (layers.attention's chunked
    GSPMD-friendly attention, lax.top_k routing, associative-scan SSM,
    chunkwise mLSTM). Always available, partitionable under pjit.
  * ``pallas``    — the hand-tiled Pallas TPU kernels in this package.
    On CPU they run under ``interpret=True`` (bit-accurate, slow), so
    the same selection is testable everywhere.

Selection, in precedence order:

  1. per-call  — ``backend="pallas"`` threaded through the model API
     (engine/prefill/decode_step/... all take it);
  2. scoped    — ``with use_backend("pallas"): ...``;
  3. global    — ``PerfFlags.kernel_backend`` (the ``--perf`` CLI knob).

Model-level call sites treat any backend other than ``reference`` as "use
the backend's kernels when the op is expressible" and keep the jnp path
for the rest (e.g. under an active device mesh, where GSPMD owns
partitioning — Pallas kernels are chip-local).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Union

import jax

from repro.kernels import ref as R
from repro.kernels.flash_decode import flash_decode
from repro.kernels.flash_decode_paged import flash_decode_paged
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.flash_verify import flash_verify, flash_verify_paged
from repro.kernels.mlstm_scan import mlstm_scan
from repro.kernels.moe_router import moe_router_topk
from repro.kernels.ssm_scan import ssm_scan


@dataclass(frozen=True)
class KernelBackend:
    """One implementation of the kernel op vocabulary.

    All ops share the reference signatures (see kernels/ref.py):
      attention(q, k, v, *, causal, window, cap, scale, q_offset)
      decode_attention(q, k_cache, v_cache, kv_len, *, cap, scale)
      paged_decode_attention(q, k_pages, v_pages, block_tab, kv_len, *,
                             cap, scale)
      verify_attention(q (B,Hq,W,hd), k_cache, v_cache, kv_len, *,
                       cap, scale)
      paged_verify_attention(q (B,Hq,W,hd), k_pages, v_pages, block_tab,
                             kv_len, *, cap, scale)
      router_topk(logits (T,E), k) -> (weights (T,k) fp32, idx (T,k) i32)
      selective_scan(dt, x, B_, C_, A, h0) -> (y, h_last)
      mlstm_scan(q, k, v, i_pre, f_pre, state, *, scale) -> (h, state)
    """
    name: str
    attention: Callable
    decode_attention: Callable
    paged_decode_attention: Callable
    verify_attention: Callable
    paged_verify_attention: Callable
    router_topk: Callable
    selective_scan: Callable
    mlstm_scan: Callable


_REGISTRY: Dict[str, KernelBackend] = {}
_SCOPED: Optional[str] = None


def register_backend(backend: KernelBackend) -> KernelBackend:
    _REGISTRY[backend.name] = backend
    return backend


def available_backends():
    return tuple(sorted(_REGISTRY))


def get_backend(spec: Union[None, str, KernelBackend] = None
                ) -> KernelBackend:
    """Resolve a backend: explicit arg > use_backend scope > PerfFlags."""
    if isinstance(spec, KernelBackend):
        return spec
    name = spec or _SCOPED
    if name is None:
        from repro.common.perf import get_flags
        name = get_flags().kernel_backend
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel backend {name!r}; have {available_backends()}")


@contextlib.contextmanager
def use_backend(name: str):
    """Scope the default backend (per-call args still win)."""
    global _SCOPED
    get_backend(name)          # validate eagerly
    prev = _SCOPED
    _SCOPED = name
    try:
        yield
    finally:
        _SCOPED = prev


def mesh_local() -> bool:
    """True when no device mesh is active — i.e. the Pallas (chip-local)
    kernels may replace the GSPMD-partitionable jnp paths."""
    from repro.distributed.annotate import _mesh
    return _mesh() is None


# ----------------------------------------------------------- reference ----

def _ref_router_topk(logits, k: int):
    w, i, _ = R.router_topk_ref(logits, k)
    return w, i


register_backend(KernelBackend(
    name="reference",
    attention=R.attention_ref,
    decode_attention=R.decode_attention_ref,
    paged_decode_attention=R.paged_decode_attention_ref,
    verify_attention=R.verify_attention_ref,
    paged_verify_attention=R.paged_verify_attention_ref,
    router_topk=_ref_router_topk,
    selective_scan=R.selective_scan_ref,
    mlstm_scan=R.mlstm_scan_ref,
))


# -------------------------------------------------------------- pallas ----

def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pl_attention(q, k, v, *, causal=True, window=0, cap=0.0, scale=0.0,
                  q_offset=0):
    return flash_prefill(q, k, v, causal=causal, window=window, cap=cap,
                         scale=scale, q_offset=q_offset,
                         interpret=_interpret())


def _pl_decode_attention(q, k_cache, v_cache, kv_len, *, cap=0.0,
                         scale=0.0):
    return flash_decode(q, k_cache, v_cache, kv_len, cap=cap, scale=scale,
                        interpret=_interpret())


def _pl_paged_decode_attention(q, k_pages, v_pages, block_tab, kv_len, *,
                               cap=0.0, scale=0.0):
    return flash_decode_paged(q, k_pages, v_pages, block_tab, kv_len,
                              cap=cap, scale=scale,
                              interpret=_interpret())


def _pl_verify_attention(q, k_cache, v_cache, kv_len, *, cap=0.0,
                         scale=0.0):
    return flash_verify(q, k_cache, v_cache, kv_len, cap=cap, scale=scale,
                        interpret=_interpret())


def _pl_paged_verify_attention(q, k_pages, v_pages, block_tab, kv_len, *,
                               cap=0.0, scale=0.0):
    return flash_verify_paged(q, k_pages, v_pages, block_tab, kv_len,
                              cap=cap, scale=scale,
                              interpret=_interpret())


def _pl_router_topk(logits, k: int):
    return moe_router_topk(logits, k, interpret=_interpret())


def _pl_selective_scan(dt, x, B_, C_, A, h0=None):
    return ssm_scan(dt, x, B_, C_, A, h0, interpret=_interpret())


def _pl_mlstm_scan(q, k, v, i_pre, f_pre, state=None, *, scale=0.0):
    return mlstm_scan(q, k, v, i_pre, f_pre, state, scale=scale,
                      interpret=_interpret())


register_backend(KernelBackend(
    name="pallas",
    attention=_pl_attention,
    decode_attention=_pl_decode_attention,
    paged_decode_attention=_pl_paged_decode_attention,
    verify_attention=_pl_verify_attention,
    paged_verify_attention=_pl_paged_verify_attention,
    router_topk=_pl_router_topk,
    selective_scan=_pl_selective_scan,
    mlstm_scan=_pl_mlstm_scan,
))
