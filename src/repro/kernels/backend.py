"""Pluggable attention/op kernel backend registry.

Every compute hot-spot in the model stack (attention prefill/extend,
cache decode, MoE router top-k, selective-SSM scan, mLSTM recurrence)
dispatches through a named :class:`KernelBackend`:

  * ``reference`` — the pure-jnp paths (layers.attention's chunked
    GSPMD-friendly attention, lax.top_k routing, associative-scan SSM,
    chunkwise mLSTM). Always available, partitionable under pjit.
  * ``pallas``    — the hand-tiled Pallas TPU kernels in this package.
    On CPU they run under ``interpret=True`` (bit-accurate, slow), so
    the same selection is testable everywhere.

Selection, in precedence order:

  1. per-call  — ``backend="pallas"`` threaded through the model API
     (engine/prefill/decode_step/... all take it);
  2. scoped    — ``with use_backend("pallas"): ...``;
  3. global    — ``PerfFlags.kernel_backend`` (the ``--perf`` CLI knob).

Model-level call sites treat any backend other than ``reference`` as "use
the backend's kernels when the op is expressible" and keep the jnp path
for the rest (e.g. under an active device mesh, where GSPMD owns
partitioning — Pallas kernels are chip-local).
"""
from __future__ import annotations

import contextlib
import inspect
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple, Union

import jax

from repro.kernels import ref as R
from repro.kernels.flash_decode import flash_decode
from repro.kernels.flash_decode_paged import flash_decode_paged
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.flash_verify import flash_verify, flash_verify_paged
from repro.kernels.mlstm_scan import mlstm_scan
from repro.kernels.moe_router import moe_router_topk
from repro.kernels.ssm_scan import ssm_scan


@dataclass(frozen=True)
class KernelBackend:
    """One implementation of the kernel op vocabulary.

    All ops share the reference signatures (see kernels/ref.py):
      attention(q, k, v, *, causal, window, cap, scale, q_offset)
      decode_attention(q, k_cache, v_cache, kv_len, *, cap, scale)
      paged_decode_attention(q, k_pages, v_pages, block_tab, kv_len, *,
                             cap, scale)
      verify_attention(q (B,Hq,W,hd), k_cache, v_cache, kv_len, *,
                       cap, scale)
      paged_verify_attention(q (B,Hq,W,hd), k_pages, v_pages, block_tab,
                             kv_len, *, cap, scale)
      router_topk(logits (T,E), k) -> (weights (T,k) fp32, idx (T,k) i32)
      selective_scan(dt, x, B_, C_, A, h0) -> (y, h_last)
      mlstm_scan(q, k, v, i_pre, f_pre, state, *, scale) -> (h, state)
    """
    name: str
    attention: Callable
    decode_attention: Callable
    paged_decode_attention: Callable
    verify_attention: Callable
    paged_verify_attention: Callable
    router_topk: Callable
    selective_scan: Callable
    mlstm_scan: Callable


#: The declared call surface of every op, as ``op -> (positional arg
#: names, keyword-only arg names)``. This is the machine-readable form
#: of the signature block in :class:`KernelBackend`'s docstring: model
#: call sites may pass exactly these arguments to any backend, so every
#: registered implementation must *accept* the full surface (extra
#: parameters are fine only when they carry defaults — e.g. the
#: reference ``attention``'s ``kv_len``). ``register_backend`` enforces
#: this at import time; ``repro.analysis.backend_check`` re-checks it
#: (plus registry completeness) under lint as RL301–RL303.
OP_SURFACE: Dict[str, Tuple[Tuple[str, ...], Tuple[str, ...]]] = {
    "attention": (("q", "k", "v"),
                  ("causal", "window", "cap", "scale", "q_offset")),
    "decode_attention": (("q", "k_cache", "v_cache", "kv_len"),
                         ("cap", "scale")),
    "paged_decode_attention": (
        ("q", "k_pages", "v_pages", "block_tab", "kv_len"),
        ("cap", "scale")),
    "verify_attention": (("q", "k_cache", "v_cache", "kv_len"),
                         ("cap", "scale")),
    "paged_verify_attention": (
        ("q", "k_pages", "v_pages", "block_tab", "kv_len"),
        ("cap", "scale")),
    "router_topk": (("logits", "k"), ()),
    "selective_scan": (("dt", "x", "B_", "C_", "A", "h0"), ()),
    "mlstm_scan": (("q", "k", "v", "i_pre", "f_pre", "state"), ("scale",)),
}

OPS: Tuple[str, ...] = tuple(OP_SURFACE)


class BackendContractError(TypeError):
    """A registered implementation cannot serve the declared op surface
    (missing/renamed parameters, or extras without defaults)."""


def check_op_signature(op: str, impl: Callable) -> Optional[str]:
    """Return a defect description if ``impl`` cannot accept the
    declared :data:`OP_SURFACE` call for ``op``, else None.

    Rules: the leading positional parameter names must match the
    surface exactly (callers pass them positionally); every declared
    keyword-only name must be accepted; any parameter beyond the
    surface must have a default (so surface-shaped calls still bind).
    """
    pos_names, kw_names = OP_SURFACE[op]
    try:
        params = list(inspect.signature(impl).parameters.values())
    except (TypeError, ValueError):          # builtins / C callables
        return None
    pos = [p for p in params if p.kind in (p.POSITIONAL_ONLY,
                                           p.POSITIONAL_OR_KEYWORD)]
    kws = {p.name: p for p in params if p.kind == p.KEYWORD_ONLY}
    has_var_kw = any(p.kind == p.VAR_KEYWORD for p in params)
    got = tuple(p.name for p in pos[:len(pos_names)])
    if got != pos_names:
        return (f"positional params {got} != declared {pos_names}")
    for extra in pos[len(pos_names):]:
        if extra.default is extra.empty:
            return (f"extra positional param {extra.name!r} without a "
                    f"default breaks surface-shaped calls")
    if not has_var_kw:
        missing = [n for n in kw_names if n not in kws]
        if missing:
            return f"missing keyword params {missing}"
    for name, p in kws.items():
        if name not in kw_names and p.default is p.empty:
            return (f"extra keyword-only param {name!r} without a "
                    f"default breaks surface-shaped calls")
    return None


def validate_backend(backend: KernelBackend) -> Dict[str, str]:
    """All op-surface defects of one backend, ``op -> description``."""
    defects: Dict[str, str] = {}
    for op in OPS:
        impl = getattr(backend, op, None)
        if not callable(impl):
            defects[op] = "op not implemented (field missing/not callable)"
            continue
        bad = check_op_signature(op, impl)
        if bad:
            defects[op] = bad
    return defects


_REGISTRY: Dict[str, KernelBackend] = {}
_SCOPED: Optional[str] = None


def register_backend(backend: KernelBackend) -> KernelBackend:
    defects = validate_backend(backend)
    if defects:
        raise BackendContractError(
            f"backend {backend.name!r} violates the op surface: "
            + "; ".join(f"{op}: {d}" for op, d in sorted(defects.items())))
    _REGISTRY[backend.name] = backend
    return backend


def available_backends():
    return tuple(sorted(_REGISTRY))


def get_backend(spec: Union[None, str, KernelBackend] = None
                ) -> KernelBackend:
    """Resolve a backend: explicit arg > use_backend scope > PerfFlags."""
    if isinstance(spec, KernelBackend):
        return spec
    name = spec or _SCOPED
    if name is None:
        from repro.common.perf import get_flags
        name = get_flags().kernel_backend
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel backend {name!r}; have {available_backends()}")


@contextlib.contextmanager
def use_backend(name: str):
    """Scope the default backend (per-call args still win)."""
    global _SCOPED
    get_backend(name)          # validate eagerly
    prev = _SCOPED
    _SCOPED = name
    try:
        yield
    finally:
        _SCOPED = prev


def mesh_local() -> bool:
    """True when no device mesh is active — i.e. the Pallas (chip-local)
    kernels may replace the GSPMD-partitionable jnp paths."""
    from repro.distributed.annotate import _mesh
    return _mesh() is None


# ----------------------------------------------------------- reference ----

def _ref_router_topk(logits, k: int):
    w, i, _ = R.router_topk_ref(logits, k)
    return w, i


register_backend(KernelBackend(
    name="reference",
    attention=R.attention_ref,
    decode_attention=R.decode_attention_ref,
    paged_decode_attention=R.paged_decode_attention_ref,
    verify_attention=R.verify_attention_ref,
    paged_verify_attention=R.paged_verify_attention_ref,
    router_topk=_ref_router_topk,
    selective_scan=R.selective_scan_ref,
    mlstm_scan=R.mlstm_scan_ref,
))


# -------------------------------------------------------------- pallas ----

def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pl_attention(q, k, v, *, causal=True, window=0, cap=0.0, scale=0.0,
                  q_offset=0):
    return flash_prefill(q, k, v, causal=causal, window=window, cap=cap,
                         scale=scale, q_offset=q_offset,
                         interpret=_interpret())


def _pl_decode_attention(q, k_cache, v_cache, kv_len, *, cap=0.0,
                         scale=0.0):
    return flash_decode(q, k_cache, v_cache, kv_len, cap=cap, scale=scale,
                        interpret=_interpret())


def _pl_paged_decode_attention(q, k_pages, v_pages, block_tab, kv_len, *,
                               cap=0.0, scale=0.0):
    return flash_decode_paged(q, k_pages, v_pages, block_tab, kv_len,
                              cap=cap, scale=scale,
                              interpret=_interpret())


def _pl_verify_attention(q, k_cache, v_cache, kv_len, *, cap=0.0,
                         scale=0.0):
    return flash_verify(q, k_cache, v_cache, kv_len, cap=cap, scale=scale,
                        interpret=_interpret())


def _pl_paged_verify_attention(q, k_pages, v_pages, block_tab, kv_len, *,
                               cap=0.0, scale=0.0):
    return flash_verify_paged(q, k_pages, v_pages, block_tab, kv_len,
                              cap=cap, scale=scale,
                              interpret=_interpret())


def _pl_router_topk(logits, k: int):
    return moe_router_topk(logits, k, interpret=_interpret())


def _pl_selective_scan(dt, x, B_, C_, A, h0=None):
    return ssm_scan(dt, x, B_, C_, A, h0, interpret=_interpret())


def _pl_mlstm_scan(q, k, v, i_pre, f_pre, state=None, *, scale=0.0):
    return mlstm_scan(q, k, v, i_pre, f_pre, state, scale=scale,
                      interpret=_interpret())


register_backend(KernelBackend(
    name="pallas",
    attention=_pl_attention,
    decode_attention=_pl_decode_attention,
    paged_decode_attention=_pl_paged_decode_attention,
    verify_attention=_pl_verify_attention,
    paged_verify_attention=_pl_paged_verify_attention,
    router_topk=_pl_router_topk,
    selective_scan=_pl_selective_scan,
    mlstm_scan=_pl_mlstm_scan,
))
