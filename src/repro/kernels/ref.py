"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately naive (materialize the full score matrix, sequential
scans) — correctness references, not fast paths.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0, cap=0.0, kv_len=None,
                  q_offset=0, scale=0.0):
    """q: (B,Hq,Sq,hd); k,v: (B,Hkv,Sk,hd); GQA by head repetition.

    window: sliding-window size (0 = full); cap: logit softcap;
    kv_len: number of valid kv entries — scalar or (B,) vector (decode
    against per-sequence fill levels); q positions are assumed to end at
    kv_len-1 (decode) or to start at q_offset (prefill / chunked-prefill
    extend). scale: 0 -> 1/sqrt(hd).
    """
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    scale = scale if scale else 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if cap:
        logits = cap * jnp.tanh(logits / cap)
    kpos = jnp.arange(Sk)
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        kvl = kvl[None] if kvl.ndim == 0 else kvl              # (1,)|(B,)
        qpos = kvl[:, None] - Sq + jnp.arange(Sq)[None, :]     # (1|B,Sq)
        valid = kpos[None, None, :] < kvl[:, None, None]       # (1|B,1,Sk)
    else:
        qpos = q_offset + jnp.arange(Sq)[None, :]              # (1,Sq)
        valid = jnp.ones((1, 1, Sk), bool)
    mask = jnp.broadcast_to(valid, valid.shape[:1] + (Sq, Sk))
    if causal:
        mask = mask & (kpos[None, None, :] <= qpos[..., None])
    if window:
        mask = mask & (qpos[..., None] - kpos[None, None, :] < window)
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, kv_len, *, cap=0.0,
                         scale=0.0):
    """q: (B,Hq,hd); caches: (B,Hkv,S,hd); kv_len: scalar or (B,) int."""
    out = attention_ref(q[:, :, None], k_cache, v_cache, causal=False,
                        cap=cap, kv_len=kv_len, scale=scale)
    return out[:, :, 0]


def paged_gather_kv(pages, block_tab):
    """Materialize the logical per-sequence KV view of a paged pool.

    pages: (n_blocks, Hkv, bs, hd) physical block pool (one layer);
    block_tab: (B, max_blocks) int32 block table — entries >= n_blocks
    are out-of-table sentinels and clamp to the last block (their rows
    are garbage, masked away by kv_len downstream).
    Returns (B, Hkv, max_blocks * bs, hd).
    """
    nb, Hkv, bs, hd = pages.shape
    B, mb = block_tab.shape
    view = jnp.take(pages, jnp.clip(block_tab, 0, nb - 1), axis=0)
    return view.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, mb * bs, hd)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tab, kv_len, *,
                               cap=0.0, scale=0.0):
    """Decode attention against scattered KV blocks (gather oracle).

    q: (B,Hq,hd); pages: (n_blocks,Hkv,bs,hd); block_tab: (B,mb) int32;
    kv_len: (B,) valid rows per sequence. Returns (B,Hq,hd).
    """
    return decode_attention_ref(q, paged_gather_kv(k_pages, block_tab),
                                paged_gather_kv(v_pages, block_tab),
                                kv_len, cap=cap, scale=scale)


def verify_attention_ref(q, k_cache, v_cache, kv_len, *, cap=0.0,
                         scale=0.0):
    """Speculative-verify attention: W query rows per sequence against a
    (partially) filled cache, causal at per-sequence offsets.

    q: (B,Hq,W,hd); caches: (B,Hkv,Sc,hd); kv_len: (B,) int — valid
    rows AFTER the verify write, so query row r sits at absolute
    position kv_len - W + r and attends kv positions <= that (exactly
    the mask a single-token decode at the same position would use).
    Returns (B,Hq,W,hd).
    """
    return attention_ref(q, k_cache, v_cache, causal=True, cap=cap,
                         kv_len=kv_len, scale=scale)


def paged_verify_attention_ref(q, k_pages, v_pages, block_tab, kv_len, *,
                               cap=0.0, scale=0.0):
    """Speculative-verify attention over scattered KV blocks (gather
    oracle). q: (B,Hq,W,hd); pages: (n_blocks,Hkv,bs,hd); block_tab:
    (B,mb) int32; kv_len: (B,) valid rows after the verify write.
    Returns (B,Hq,W,hd)."""
    return verify_attention_ref(q, paged_gather_kv(k_pages, block_tab),
                                paged_gather_kv(v_pages, block_tab),
                                kv_len, cap=cap, scale=scale)


def router_topk_ref(logits, k: int):
    """logits: (T,E) -> (weights (T,k), idx (T,k), probs (T,E))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    return w, idx.astype(jnp.int32), probs


def selective_scan_ref(dt, x, B_, C_, A, h0=None):
    """Sequential selective-scan oracle.

    dt, x: (B,S,di); B_, C_: (B,S,n); A: (di,n); h0: optional initial
    state (B,di,n). Returns y (B,S,di) fp32 and final state h (B,di,n).
    """
    Bsz, S, di = x.shape
    n = A.shape[-1]

    def step(h, t):
        dt_t, x_t, B_t, C_t = t
        a = jnp.exp(dt_t[..., None] * A)              # (B,di,n)
        h = a * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    if h0 is None:
        h0 = jnp.zeros((Bsz, di, n), jnp.float32)
    xs = (dt.swapaxes(0, 1).astype(jnp.float32),
          x.swapaxes(0, 1).astype(jnp.float32),
          B_.swapaxes(0, 1).astype(jnp.float32),
          C_.swapaxes(0, 1).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), h


def mlstm_scan_ref(q, k, v, i_pre, f_pre, state=None, *, scale=0.0):
    """Sequential stabilized mLSTM oracle with state carry.

    q,k,v: (B,H,S,hd) fp32; i_pre,f_pre: (B,H,S); state: optional
    (C (B,H,hd,hd), n (B,H,hd), m (B,H)). Returns (h (B,H,S,hd),
    new_state).
    """
    B, H, S, hd = q.shape
    scale = scale if scale else 1.0 / math.sqrt(hd)

    def step(carry, t):
        C, n, m = carry
        q_t, k_t, v_t, i_t, f_t = t
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m, i_t)
        fw = jnp.exp(logf + m - m_new)[..., None]
        iw = jnp.exp(i_t - m_new)[..., None]
        ks = k_t * scale
        C = C * fw[..., None] + iw[..., None] * (ks[..., :, None]
                                                 * v_t[..., None, :])
        n = n * fw + iw * ks
        num = jnp.einsum("bhde,bhd->bhe", C, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q_t)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    if state is None:
        state = (jnp.zeros((B, H, hd, hd), jnp.float32),
                 jnp.zeros((B, H, hd), jnp.float32),
                 jnp.full((B, H), -1e30, jnp.float32))
    sw = lambda t: jnp.moveaxis(t, 2, 0)
    state, hs = jax.lax.scan(step, state, (sw(q), sw(k), sw(v),
                                           sw(i_pre), sw(f_pre)))
    return jnp.moveaxis(hs, 0, 2), state


def mlstm_ref(q, k, v, i_pre, f_pre):
    """Sequential stabilized mLSTM oracle (fresh state, outputs only).

    q,k,v: (B,H,S,hd) fp32; i_pre,f_pre: (B,H,S). Returns h (B,H,S,hd).
    """
    return mlstm_scan_ref(q, k, v, i_pre, f_pre)[0]
