"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: the Pallas fast path runs on TPU (or under interpret=True
for CPU validation); the distributed pjit paths use the jnp references so
GSPMD can partition freely. ``set_kernel_mode`` flips the global default —
tests sweep both.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.flash_prefill import flash_prefill as _flash_prefill
from repro.kernels.moe_router import moe_router_topk as _moe_router
from repro.kernels.ssm_scan import ssm_scan as _ssm_scan

_MODE = "auto"          # auto | pallas | ref


def set_kernel_mode(mode: str):
    global _MODE
    assert mode in ("auto", "pallas", "ref")
    _MODE = mode


def _use_pallas() -> bool:
    if _MODE == "pallas":
        return True
    if _MODE == "ref":
        return False
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def attention(q, k, v, *, causal=True, window=0, cap=0.0):
    """Prefill/train attention. q: (B,Hq,S,hd); k,v: (B,Hkv,S,hd)."""
    if _use_pallas():
        return _flash_prefill(q, k, v, causal=causal, window=window,
                              cap=cap, interpret=_interpret())
    return ref.attention_ref(q, k, v, causal=causal, window=window, cap=cap)


def decode_attention(q, k_cache, v_cache, kv_len, *, cap=0.0):
    """Decode attention. q: (B,Hq,hd); caches (B,Hkv,S,hd)."""
    if _use_pallas():
        return _flash_decode(q, k_cache, v_cache, kv_len, cap=cap,
                             interpret=_interpret())
    return ref.decode_attention_ref(q, k_cache, v_cache, kv_len, cap=cap)


def router_topk(logits, k: int):
    """Router softmax+top-k. logits: (T,E)."""
    if _use_pallas():
        return _moe_router(logits, k, interpret=_interpret())
    w, i, _ = ref.router_topk_ref(logits, k)
    return w, i


def selective_scan(dt, x, B_, C_, A):
    """Selective SSM scan. Returns y (B,S,di) fp32."""
    if _use_pallas():
        return _ssm_scan(dt, x, B_, C_, A, interpret=_interpret())
    y, _ = ref.selective_scan_ref(dt, x, B_, C_, A)
    return y
