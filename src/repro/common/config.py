"""Configuration dataclasses for the repro framework.

Every architecture in ``repro.configs`` instantiates a :class:`ModelConfig`.
The config fully determines parameter shapes, the layer stack (as *segments*
of repeated layer-kind units, so heterogeneous stacks like Gemma-2's
local/global alternation or Hymba's sparse global-attention layers can be
``lax.scan``-ed), and serving-time cache shapes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Layer kinds understood by models/blocks.py
#   "full"    - full causal self-attention + MLP
#   "local"   - sliding-window causal self-attention + MLP
#   "moe"     - full attention + mixture-of-experts FFN (optionally + dense residual)
#   "dense"   - full attention + dense FFN inside an otherwise-MoE model
#   "hymba_g" - Hymba block (parallel attn + mamba heads), global attention
#   "hymba_w" - Hymba block, sliding-window attention
#   "mlstm"   - xLSTM matrix-LSTM block (attention-free)
#   "slstm"   - xLSTM scalar-LSTM block (attention-free, sequential)
#   "encdec"  - decoder block with self-attn + cross-attn + MLP (whisper)
ATTENTION_KINDS = ("full", "local", "moe", "dense", "hymba_g", "hymba_w", "encdec")
WINDOW_KINDS = ("local", "hymba_w")
SSM_KINDS = ("hymba_g", "hymba_w", "mlstm", "slstm")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                      # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    dense_residual_ff: int = 0         # Arctic-style parallel dense FFN (0 = off)
    shared_expert_ff: int = 0          # Kimi/DeepSeek-style always-on shared expert
    router_noise: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 1                    # d_inner = expand * d_model
    dt_rank: int = 0                   # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    # mLSTM / sLSTM head geometry; heads share the model-level n_heads.
    chunk_size: int = 64               # chunkwise-parallel mLSTM chunk length
    proj_factor: float = 2.0           # mLSTM up-projection factor


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                    # 0 -> d_model // n_heads

    # Layer stack: ((unit_kinds, n_repeat), ...). Total layers must equal
    # n_layers (encoder layers counted separately for enc-dec models).
    segments: Tuple[Tuple[Tuple[str, ...], int], ...] = ()

    # Attention details
    rope_theta: float = 10_000.0
    rope_kind: str = "rope"            # rope | mrope | none
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # t/h/w split of d_head//2
    window: int = 4096                 # sliding-window size for WINDOW_KINDS
    attn_softcap: float = 0.0          # gemma2: 50.0
    final_softcap: float = 0.0         # gemma2: 30.0
    qkv_bias: bool = False             # qwen1.5 family
    attn_scale: float = 0.0            # 0 -> 1/sqrt(d_head)

    # FFN
    mlp_act: str = "silu_glu"          # silu_glu | gelu_glu | gelu
    # Mixtures / SSM / xLSTM
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # Enc-dec (whisper): n_enc_layers encoder layers of full non-causal attn.
    n_enc_layers: int = 0
    # VLM (qwen2-vl): number of prefix positions fed as patch embeddings.
    n_vision_tokens: int = 0

    # Embeddings / head
    tie_embeddings: bool = True
    emb_scale_by_sqrt_d: bool = False  # gemma-style embedding scaling

    # Numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    # Serving
    long_context_ok: bool = False      # eligible for long_500k (sub-quadratic)

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if not self.segments:
            kind = {"moe": "moe"}.get(self.family, "full")
            object.__setattr__(self, "segments", (((kind,), self.n_layers),))
        total = sum(len(unit) * rep for unit, rep in self.segments)
        assert total == self.n_layers, (
            f"{self.name}: segments cover {total} layers, expected {self.n_layers}")
        assert self.n_heads % self.n_kv_heads == 0 or self.n_kv_heads > self.n_heads, (
            f"{self.name}: n_heads={self.n_heads} not divisible by kv={self.n_kv_heads}")

    # ---- derived quantities -------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def layer_kinds(self) -> Tuple[str, ...]:
        out = []
        for unit, rep in self.segments:
            out.extend(list(unit) * rep)
        return tuple(out)

    def param_count(self) -> int:
        """Analytic parameter count (matches init_params leaves)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}
