"""Global performance flags — the §Perf hillclimb knobs.

Defaults = paper-faithful baseline. The dry-run CLI overrides them with
``--perf k=v,k=v`` so every EXPERIMENTS.md §Perf iteration is a recorded,
reproducible configuration, not a code fork.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass
class PerfFlags:
    # selective-scan (mamba) chunking: smaller chunks bound the
    # (B, chunk, d_inner, n) associative-scan temporaries
    ssm_scan_chunk: int = 512
    # dtype of the intra-chunk scan elements (carry stays fp32)
    ssm_scan_dtype: str = "float32"
    # dtype of attention probabilities in the jnp (GSPMD) attention path
    attn_probs_dtype: str = "float32"
    # MoE dispatch algorithm: "einsum" (GShard one-hot) | "gather"
    moe_dispatch: str = "einsum"
    # attention q-chunk length in the jnp path
    attn_chunk: int = 1024
    # in-graph sharding constraints for attention q/k/v/out ("off"|"auto"):
    # pins batch/head layout when head counts don't divide the model axis
    # (GSPMD otherwise replicates attention at global batch — see
    # EXPERIMENTS.md §Perf hymba-train iteration 1)
    attn_constraint: str = "off"
    # rematerialize per-q-chunk attention probs in backward instead of
    # saving the stacked (n_blk, B, H, Cq, Sk) logits ("off"|"on")
    attn_chunk_remat: str = "off"
    # GShard-canonical sharding pins on the MoE dispatch/combine einsums
    # ("off"|"auto"): expert buffers (E,B,C,d) -> (model, data, -, -),
    # dispatch masks (B,S,E,C) -> (data, -, model, -). Lowers token
    # exchange to all-to-all instead of GSPMD's all-reduce fallback.
    moe_constraint: str = "off"
    # override the per-arch MoE capacity factor (0.0 = use the config's);
    # dispatch/one-hot/expert-buffer sizes all scale linearly with it
    moe_capacity_factor: float = 0.0
    # sliding-window layers: slice K/V to a (window+chunk) band per q-chunk
    # instead of masking full-length logits ("off"|"on") — cuts logits
    # traffic by Sk/(window+chunk) on local-attention layers
    attn_window_slice: str = "off"
    # kernel backend for attention / MoE router / SSM & mLSTM scans:
    # "reference" (pure-jnp, GSPMD-partitionable) | "pallas" (hand-tiled
    # TPU kernels; interpret-mode on CPU). Per-call backend= args and
    # kernels.backend.use_backend() override this global default.
    kernel_backend: str = "reference"

    def apply_overrides(self, spec: str) -> "PerfFlags":
        """'ssm_scan_chunk=128,moe_dispatch=gather' -> new flags."""
        out = self
        if not spec:
            return out
        for kv in spec.split(","):
            k, v = kv.split("=")
            cur = getattr(self, k.strip())
            val = v.strip()
            if isinstance(cur, bool):
                val = val == "True"
            elif isinstance(cur, int):
                val = int(v)
            elif isinstance(cur, float):
                val = float(v)
            out = dataclasses.replace(out, **{k.strip(): val})
        return out


FLAGS = PerfFlags()


def set_flags(flags: PerfFlags):
    global FLAGS
    FLAGS = flags


def get_flags() -> PerfFlags:
    return FLAGS
