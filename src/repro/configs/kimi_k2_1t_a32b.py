"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per-expert) vocab=163840.
DeepSeek-V3-style layout: layer 0 is dense (ff = top_k * d_expert), layers
1..60 are MoE with one always-on shared expert (ff=2048).
"""
from repro.common.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=2048,
    vocab_size=163_840,
    segments=((("dense",), 1), (("moe",), 60)),
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048,
                  shared_expert_ff=2048),
    rope_theta=1_000_000.0,
    mlp_act="silu_glu",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="kimi-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=64,
    vocab_size=512,
    segments=((("dense",), 1), (("moe",), 1)),
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, shared_expert_ff=64),
    tie_embeddings=False,
)
