"""Whisper-large-v3 — encoder-decoder with conv frontend (stub)
[arXiv:2212.04356].

32 decoder layers (self-attn + cross-attn) + 32 encoder layers,
d_model=1280 20H (kv=20) d_ff=5120 vocab=51866, sinusoidal positions.
The mel-spectrogram + conv feature extractor is the allowed STUB:
``input_specs`` provides frame embeddings (B, S_frames, d_model).
The decoder context is architecturally capped (448); decode shapes put the
long axis on the *encoder* side (cross-attention to S_frames states).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_head=64,
    d_ff=5120,
    vocab_size=51_866,
    segments=((("encdec",), 32),),
    n_enc_layers=32,
    rope_kind="none",
    mlp_act="gelu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_head=32,
    d_ff=256,
    vocab_size=512,
    segments=((("encdec",), 2),),
    n_enc_layers=2,
    rope_kind="none",
    mlp_act="gelu",
)
