"""planner-proxy-100m — the ~100M-param dense model the end-to-end examples
actually train and serve on CPU as the GeckOpt planner/intent-classifier.

Not part of the assigned pool; sized so a few hundred train steps run on
this container.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="planner-proxy-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_head=64,
    d_ff=2048,
    vocab_size=8192,
    segments=((("full",), 12),),
    tie_embeddings=True,
)

# An even smaller variant for tests / quick examples.
SMOKE = ModelConfig(
    name="planner-proxy-smoke",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_head=64,
    d_ff=512,
    vocab_size=8192,
    segments=((("full",), 2),),
    tie_embeddings=True,
)
