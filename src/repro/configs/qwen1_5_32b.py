"""Qwen1.5-32B — dense, QKV bias [hf:Qwen/Qwen1.5-0.5B family].

64L d_model=5120 40H (kv=40, MHA) d_ff=27392 vocab=152064.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_head=128,
    d_ff=27_392,
    vocab_size=152_064,
    segments=((("full",), 64),),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    mlp_act="silu_glu",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen1.5-32b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_head=32,
    d_ff=256,
    vocab_size=512,
    segments=((("full",), 2),),
    qkv_bias=True,
    tie_embeddings=False,
)
