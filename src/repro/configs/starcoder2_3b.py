"""StarCoder2-3B — GQA + RoPE, 4k sliding-window attention [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152. Served in its
documented sliding-window mode (window=4096), which makes it eligible for
long_500k decode (window KV cache).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_head=128,
    d_ff=12288,
    vocab_size=49_152,
    segments=((("local",), 30),),
    window=4096,
    rope_theta=100_000.0,
    qkv_bias=True,
    mlp_act="gelu",
    tie_embeddings=True,
    long_context_ok=True,   # sliding-window variant (per-brief carve-in)
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=256,
    vocab_size=512,
    segments=((("local",), 2),),
    window=32,
    qkv_bias=True,
    mlp_act="gelu",
    long_context_ok=True,
)
