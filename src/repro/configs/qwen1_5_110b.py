"""Qwen1.5-110B — dense, GQA, QKV bias [hf:Qwen/Qwen1.5-0.5B family].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=49_152,
    vocab_size=152_064,
    segments=((("full",), 80),),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    mlp_act="silu_glu",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen1.5-110b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_head=16,
    d_ff=256,
    vocab_size=512,
    segments=((("full",), 2),),
    qkv_bias=True,
    tie_embeddings=False,
)
