"""Hymba-1.5B — hybrid parallel attention + mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Layer pattern: the paper keeps full (global) attention only at the first,
middle and last layers, sliding-window elsewhere. To keep the stack
scan-able we use the periodic approximation global@{0,16} with 15 window
layers after each (noted in DESIGN.md §Arch-applicability).
"""
from repro.common.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32_001,
    segments=(((("hymba_g",) + ("hymba_w",) * 15), 2),),
    window=1024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=1),
    rope_theta=10_000.0,
    mlp_act="silu_glu",
    tie_embeddings=True,
    long_context_ok=True,   # mamba state + sliding window; 2 global layers
)

SMOKE = ModelConfig(
    name="hymba-smoke",
    family="hybrid",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=256,
    vocab_size=512,
    segments=((("hymba_g", "hymba_w"), 1),),
    window=32,
    ssm=SSMConfig(d_state=8, d_conv=4, expand=1),
    long_context_ok=True,
)
