"""xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H (kv=4, attention-free — heads are xLSTM heads)
d_ff=0 (FFN folded into the block up/down projections) vocab=50304.
Block ratio follows the paper's mostly-mLSTM mix: unit = 3×mLSTM + 1×sLSTM.
"""
from repro.common.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    segments=((("mlstm", "mlstm", "mlstm", "slstm"), 3),),
    xlstm=XLSTMConfig(chunk_size=64, proj_factor=2.0),
    rope_kind="none",
    tie_embeddings=True,
    long_context_ok=True,   # pure recurrent state
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    segments=((("mlstm", "slstm"), 1),),
    xlstm=XLSTMConfig(chunk_size=16, proj_factor=2.0),
    rope_kind="none",
    long_context_ok=True,
)
