"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the FULL assigned config (exercised only via
the dry-run); ``get_smoke_config(name)`` returns the reduced same-family
variant used by CPU smoke tests (<=2-ish layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "hymba-1.5b",
    "arctic-480b",
    "xlstm-125m",
    "starcoder2-3b",
    "qwen2-vl-72b",
    "whisper-large-v3",
    "qwen1.5-32b",
    "gemma2-2b",
    "kimi-k2-1t-a32b",
    "qwen1.5-110b",
)

ALL_IDS = ARCH_IDS + ("planner-proxy-100m",)


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke_config(name: str):
    return _module(name).SMOKE
