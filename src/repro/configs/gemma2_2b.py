"""Gemma-2 2B — alternating local/global attention, logit softcaps
[arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) head_dim=256 d_ff=9216 vocab=256000.
unit=(local, global) repeated 13x; attn softcap 50, final logit softcap 30;
GeGLU MLP; embeddings scaled by sqrt(d_model). Eligible for long_500k via
the alternating-window pattern (window caches for local layers,
seq-sharded cache for global layers).
"""
import math

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=9216,
    vocab_size=256_000,
    segments=((("local", "full"), 13),),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    attn_scale=1.0 / math.sqrt(256.0),
    mlp_act="gelu_glu",
    emb_scale_by_sqrt_d=True,
    tie_embeddings=True,
    long_context_ok=True,
)

SMOKE = ModelConfig(
    name="gemma2-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=256,
    vocab_size=512,
    segments=((("local", "full"), 1),),
    window=32,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_act="gelu_glu",
    emb_scale_by_sqrt_d=True,
    long_context_ok=True,
)
