"""Snowflake Arctic 480B — 128-expert top-2 MoE + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 (per-expert) vocab=32000.
Arctic's "dense-MoE hybrid" runs a dense residual FFN in parallel with the
routed experts.
"""
from repro.common.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,
    vocab_size=32_000,
    segments=((("moe",), 35),),
    moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864,
                  dense_residual_ff=4864),
    rope_theta=1_000_000.0,
    mlp_act="silu_glu",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="arctic-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=96,
    vocab_size=512,
    segments=((("moe",), 2),),
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=96, dense_residual_ff=96),
    tie_embeddings=False,
)
