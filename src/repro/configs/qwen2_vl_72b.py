"""Qwen2-VL-72B — M-RoPE, dynamic resolution [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
The ViT vision encoder + projector is the allowed STUB: ``input_specs``
provides precomputed patch embeddings (B, n_vision_tokens, d_model); the
language backbone (M-RoPE over (t,h,w) position ids) is fully implemented.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29_568,
    vocab_size=152_064,
    segments=((("full",), 80),),
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    mlp_act="silu_glu",
    tie_embeddings=False,
    n_vision_tokens=1024,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke",
    family="vlm",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=256,
    vocab_size=512,
    segments=((("full",), 2),),
    rope_kind="mrope",
    mrope_sections=(4, 6, 6),
    qkv_bias=True,
    tie_embeddings=False,
    n_vision_tokens=16,
)
