"""Synthetic LM data pipeline with document packing.

Documents are drawn from a deterministic seeded "corpus" generator (the
planner examples feed real serialized agent transcripts through the same
packing path). Packing concatenates documents with an EOS separator and
emits fixed-length (tokens, labels) windows; labels are shifted tokens with
-100-style masking (-1 here) across document boundaries optionally kept.
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Sequence

import numpy as np

EOS = 1
PAD = 0


class PackedLMDataset:
    """Streams packed (tokens, labels) batches from a token-id document
    iterator."""

    def __init__(self, docs: Iterator[Sequence[int]], batch: int,
                 seq_len: int, vocab_size: int, mask_boundaries: bool = False):
        self.docs = iter(docs)
        self.batch = batch
        self.seq_len = seq_len
        self.vocab = vocab_size
        self.mask_boundaries = mask_boundaries
        self._buf: List[int] = []

    def _fill(self, n: int):
        while len(self._buf) < n:
            try:
                doc = next(self.docs)
            except StopIteration:
                # loop the corpus
                self._buf.extend([EOS] * (n - len(self._buf)))
                return
            self._buf.extend(list(doc))
            self._buf.append(EOS)

    def __iter__(self):
        return self

    def __next__(self):
        need = self.batch * (self.seq_len + 1)
        self._fill(need)
        chunk = np.array(self._buf[:need], np.int32)
        self._buf = self._buf[need:]
        chunk = chunk.reshape(self.batch, self.seq_len + 1)
        tokens = chunk[:, :-1]
        labels = chunk[:, 1:].copy()
        if self.mask_boundaries:
            labels[tokens == EOS] = -1
        return {"tokens": tokens, "labels": labels}


def synthetic_docs(vocab_size: int, seed: int = 0,
                   mean_len: int = 256) -> Iterator[List[int]]:
    """Infinite stream of synthetic documents with Zipf-ish unigrams and a
    local bigram structure (so a small LM has something learnable)."""
    rng = np.random.default_rng(seed)
    # Fixed random bigram transition "grammar" over a small state space.
    n_states = 64
    trans = rng.integers(2, vocab_size, size=(n_states, 8))
    while True:
        length = max(8, int(rng.exponential(mean_len)))
        state = int(rng.integers(0, n_states))
        doc = []
        for _ in range(length):
            tok = int(trans[state, int(rng.integers(0, 8))])
            doc.append(tok)
            state = tok % n_states
        yield doc
