"""Flat-npz checkpointing for arbitrary pytrees (no orbax dependency).

Pytree leaves are flattened to path-keyed arrays; structure is recovered
from the live template on load, so checkpoints survive refactors that keep
shapes/paths stable.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    # npz has no bfloat16: store a lossless fp32 upcast; load_checkpoint
    # casts back to the template dtype.
    def to_np(l):
        a = np.asarray(l)
        return a.astype(np.float32) if a.dtype.name == "bfloat16" else a
    arrays = {_path_str(p): to_np(l) for p, l in flat}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def load_checkpoint(path: str, template: Any) -> Any:
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, tpl in flat:
            key = _path_str(p)
            arr = data[key]
            assert arr.shape == tpl.shape, (key, arr.shape, tpl.shape)
            leaves.append(jax.numpy.asarray(arr, dtype=tpl.dtype))
    paths_treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(paths_treedef, leaves)
