"""Training step factory + a minimal host-side training loop.

``make_train_step(cfg)`` returns the pure (params, opt_state, batch) ->
(params, opt_state, metrics) function that the launcher jits with mesh
shardings; the same function lowers in the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.model import init_params, train_loss
from repro.training.optimizer import AdamWState, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, *, lr: float = 3e-4,
                    weight_decay: float = 0.01, remat: bool = True,
                    grad_clip: float = 1.0) -> Callable:
    def train_step(params, opt_state: AdamWState, batch):
        def loss_fn(p):
            return train_loss(p, cfg, batch, remat=remat)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, gnorm = adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay,
            grad_clip=grad_clip)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state.step}
        return params, opt_state, metrics
    return train_step


def train(cfg: ModelConfig, data_iter, n_steps: int, *, seed: int = 0,
          lr: float = 3e-4, remat: bool = False,
          log_every: int = 10, callback: Optional[Callable] = None,
          clock: Optional[Callable[[], float]] = None):
    """Single-host training loop used by the examples (CPU-scale).

    ``clock`` follows the serving engine's injected-clock convention
    (the RL106 boundary rule): callers that want real ``wall_s`` in the
    history pass ``time.time``; the default zero clock keeps the loop
    wall-free and the history deterministic."""
    clock = clock or (lambda: 0.0)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, lr=lr, remat=remat))
    history = []
    t0 = clock()
    for step in range(n_steps):
        batch = next(data_iter)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["wall_s"] = clock() - t0
            history.append(m)
            if callback:
                callback(step, m)
    return params, opt_state, history
