"""AdamW in pure JAX over arbitrary param pytrees.

Optimizer state is a pytree with the same structure as params (m, v in
fp32), so the distributed layer can apply ZeRO-1-style sharding specs to it
independently of the parameter specs.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: AdamWState, *,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.01,
                 grad_clip: float = 1.0):
    """Returns (new_params, new_state, grad_norm)."""
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
    gf = jax.tree.map(lambda g: g * scale, gf)

    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, gf)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, gf)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm


def cosine_lr(step, *, base_lr: float, warmup: int, total: int,
              min_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.minimum(warm, cos)
