"""Token & request accounting — the paper's cost metric.

Every LLM interaction (the gate call and each planner step) is recorded
with REAL token counts from the serialized prompt/completion text
(serving.tokenizer), not estimates.

With the tool-graph compiler (DESIGN.md §Tool-graph compiler) one
"plan" entry is one planner ROUND-TRIP that may fuse several virtual
linear steps; entries carry ``tool_calls``/``virtual_steps`` so the
round-trip and token deltas the compiler buys are first-class metrics
(surfaced in benchmarks/table2.py and benchmarks/steps_tools.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.serving.tokenizer import count_tokens


@dataclass
class LedgerEntry:
    kind: str              # "gate" | "plan" | "widen"
    prompt_tokens: int
    completion_tokens: int
    tool_calls: int = 0    # tool calls emitted in this round-trip
    virtual_steps: int = 0  # linear planner steps fused into it (1 when
    #                         the linear planner emitted it directly)


@dataclass
class TokenLedger:
    entries: List[LedgerEntry] = field(default_factory=list)

    def record(self, kind: str, prompt_text: str, completion_text: str,
               tool_calls: int = 0, virtual_steps: int = 0):
        self.entries.append(LedgerEntry(
            kind, count_tokens(prompt_text), count_tokens(completion_text),
            tool_calls=tool_calls, virtual_steps=virtual_steps))

    @property
    def prompt_tokens(self) -> int:
        return sum(e.prompt_tokens for e in self.entries)

    @property
    def completion_tokens(self) -> int:
        return sum(e.completion_tokens for e in self.entries)

    @property
    def total_tokens(self) -> int:
        return self.prompt_tokens + self.completion_tokens

    @property
    def n_requests(self) -> int:
        return len(self.entries)

    @property
    def n_plan_steps(self) -> int:
        """Planner LLM requests (round-trips). Pre-compiler this equals
        virtual steps; with compile_plans it is what fusion shrinks."""
        return sum(1 for e in self.entries if e.kind == "plan")

    # round-trip accounting (tool-graph compiler) ------------------------
    n_round_trips = n_plan_steps

    @property
    def n_virtual_steps(self) -> int:
        """Linear planner steps the round-trips cover: invariant under
        compilation (the behaviour model is shared), so the compiler's
        win is exactly ``n_virtual_steps / n_round_trips``."""
        return sum(e.virtual_steps for e in self.entries
                   if e.kind == "plan")

    @property
    def n_tool_calls(self) -> int:
        return sum(e.tool_calls for e in self.entries if e.kind == "plan")

    @property
    def plan_prompt_tokens(self) -> int:
        """Prompt tokens across plan round-trips only — the serialized
        catalog+history re-sends that fusing round-trips eliminates."""
        return sum(e.prompt_tokens for e in self.entries
                   if e.kind == "plan")

    # toolset-retrieval miss-and-widen accounting ------------------------
    @property
    def n_widens(self) -> int:
        """Miss-and-widen re-issues: a "widen" entry is one k-escalation
        re-serialization after the planner emitted a call outside the
        retrieved toolset (TOOL_NOT_RETRIEVED). Widen entries carry
        tokens (total_tokens includes them) but zero virtual steps, so
        they never move round-trip or step metrics."""
        return sum(1 for e in self.entries if e.kind == "widen")

    def summary(self) -> Dict[str, float]:
        return {"total_tokens": self.total_tokens,
                "prompt_tokens": self.prompt_tokens,
                "completion_tokens": self.completion_tokens,
                "requests": self.n_requests,
                "plan_steps": self.n_plan_steps,
                "round_trips": self.n_round_trips,
                "virtual_steps": self.n_virtual_steps,
                "tool_calls": self.n_tool_calls,
                "plan_prompt_tokens": self.plan_prompt_tokens,
                "widens": self.n_widens}
