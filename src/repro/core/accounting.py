"""Token & request accounting — the paper's cost metric.

Every LLM interaction (the gate call and each planner step) is recorded
with REAL token counts from the serialized prompt/completion text
(serving.tokenizer), not estimates.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.serving.tokenizer import count_tokens


@dataclass
class LedgerEntry:
    kind: str              # "gate" | "plan"
    prompt_tokens: int
    completion_tokens: int


@dataclass
class TokenLedger:
    entries: List[LedgerEntry] = field(default_factory=list)

    def record(self, kind: str, prompt_text: str, completion_text: str):
        self.entries.append(LedgerEntry(
            kind, count_tokens(prompt_text), count_tokens(completion_text)))

    @property
    def prompt_tokens(self) -> int:
        return sum(e.prompt_tokens for e in self.entries)

    @property
    def completion_tokens(self) -> int:
        return sum(e.completion_tokens for e in self.entries)

    @property
    def total_tokens(self) -> int:
        return self.prompt_tokens + self.completion_tokens

    @property
    def n_requests(self) -> int:
        return len(self.entries)

    @property
    def n_plan_steps(self) -> int:
        return sum(1 for e in self.entries if e.kind == "plan")

    def summary(self) -> Dict[str, float]:
        return {"total_tokens": self.total_tokens,
                "prompt_tokens": self.prompt_tokens,
                "completion_tokens": self.completion_tokens,
                "requests": self.n_requests,
                "plan_steps": self.n_plan_steps}
