"""Scalable synthetic tool catalog: seeded generation of 8–512-tool
registries.

The paper's platform carries a ~dozen-library catalog; production
copilots carry hundreds of tools. This module scales the registry the
way such platforms grow — by adding tool *families* (new API libraries
with many near-duplicate endpoints) around the hand-written core — so
retrieval (core/retriever.py) has a realistically crowded catalog to
narrow.

Construction is fully deterministic: ``build_catalog(n, seed)`` is a
pure function of its arguments (names/descriptions drawn from one
seeded numpy rng in a fixed order), so two runs — or the CI gate and a
committed baseline — see byte-identical catalog text.

Sizing semantics:

  * ``n <= 48`` (the base registry): the first ``n`` base tools in
    registration order. SQL_apis registers first, so the planner's
    read-only derail pool is non-empty at every size (the behaviour
    model never divides by an empty toolset).
  * ``n > 48``: the full base registry plus ``n - 48`` generated tools,
    round-robin across the ten families below so every catalog size
    exercises every family.

Every generated tool is *dispatchable*: ``env/tools_impl.py`` backs
each family with a real handler branch (``_execute_family``) and a
``CATALOG_FAMILY_EFFECTS`` entry, so the PR 7 effects race detector and
the tool-graph compiler cover generated tools exactly like hand-written
ones. Family name prefixes deliberately avoid the planner's derail-pool
prefixes (``sql_``/``wiki_``/``ui_read``/``suggest_``/``web_search``) —
growing the catalog must not change which tools the scripted planner
can wander to relative to the seed registry semantics.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.intents import INTENTS, TABLE1_MAP, IntentMap
from repro.core.tools import DEFAULT_REGISTRY, Tool, ToolRegistry


@dataclass(frozen=True)
class ToolFamily:
    """One generated API library: a name prefix, its home intent, and
    the uniform effects footprint every member tool declares (mirrored
    literally in ``env/tools_impl.CATALOG_FAMILY_EFFECTS`` for the
    static analyzer; an import-time assert keeps the two in sync)."""
    name: str                     # tool-name prefix + handler family
    library: str                  # registry library (``{name}_apis``)
    intent: str                   # the intent this family serves
    reads: str                    # space-separated hazard reads
    writes: str                   # space-separated hazard writes
    verbs: Tuple[str, ...]
    nouns: Tuple[str, ...]
    quals: Tuple[str, ...]        # seeded description qualifiers
    blurb: str                    # template over {verb}/{noun}/{qual}
    params: Tuple[Tuple[str, str, str], ...]


_HANDLE_PARAM = (("handles", "array", "workspace image handles"),)

FAMILIES: Tuple[ToolFamily, ...] = (
    ToolFamily(
        "catalogue", "catalogue_apis", "load_filter_plot",
        reads="", writes="",
        verbs=("list", "probe", "count", "inspect", "resolve", "scan",
               "index", "audit"),
        nouns=("granules", "footprints", "orbits", "archives", "swaths",
               "revisits", "quicklooks", "manifests"),
        quals=("acquisition", "staging", "mission", "ingest-queue"),
        blurb="{verb} the {noun} partition of the {qual} metadata "
              "catalog and return matching identifiers",
        params=(("filter", "string", "metadata filter expression"),)),
    ToolFamily(
        "ingest", "ingest_apis", "load_filter_plot",
        reads="handles", writes="handles",
        verbs=("stage", "dedupe", "trim", "align", "refresh", "subset",
               "validate", "order"),
        nouns=("rasters", "scenes", "tiles", "stacks", "batches",
               "mosaics", "strips", "chips"),
        quals=("loaded", "pending", "calibrated", "co-registered"),
        blurb="{verb} the {qual} {noun} held in the session workspace, "
              "updating the active handle set in place",
        params=_HANDLE_PARAM),
    ToolFamily(
        "carto", "carto_apis", "load_filter_plot",
        reads="", writes="map",
        verbs=("style", "overlay", "annotate", "shade", "contour",
               "label", "cluster", "symbolize"),
        nouns=("basemap", "choropleth", "hillshade", "graticule",
               "legend", "viewport", "isolines", "callouts"),
        quals=("interactive", "print-ready", "web-mercator", "tiled"),
        blurb="{verb} a {qual} {noun} layer onto the current map view",
        params=(("layer", "string", "layer name or handle"),)),
    ToolFamily(
        "detector", "detector_apis", "detection_analysis",
        reads="handles", writes="detections rng",
        verbs=("localize", "screen", "flag", "triage", "score",
               "enumerate", "verify", "sweep"),
        nouns=("vessels", "aircraft", "structures", "vehicles",
               "containers", "pads", "spans", "derricks"),
        quals=("high-recall", "low-latency", "ensemble", "cascade"),
        blurb="{verb} {noun} in the loaded imagery with the {qual} "
              "detector checkpoint; results land in the detection store",
        params=_HANDLE_PARAM),
    ToolFamily(
        "terrain", "terrain_apis", "landcover_analysis",
        reads="handles", writes="landcover rng",
        verbs=("grade", "segment", "profile", "bin", "rate", "survey",
               "stratify", "partition"),
        nouns=("slopes", "canopy", "wetlands", "parcels", "surfaces",
               "basins", "ridgelines", "floodplains"),
        quals=("per-pixel", "regional", "seasonal", "multi-temporal"),
        blurb="{verb} {noun} cover with the {qual} terrain model and "
              "store class fractions per handle",
        params=_HANDLE_PARAM),
    ToolFamily(
        "scene", "scene_apis", "visual_qa",
        reads="handles", writes="answer rng",
        verbs=("narrate", "interpret", "summarize", "assess", "answer",
               "explain", "review", "brief"),
        nouns=("context", "activity", "layout", "condition", "usage",
               "composition", "changes", "anomalies"),
        quals=("grounded", "concise", "analyst-grade", "multi-image"),
        blurb="{verb} the {noun} of a workspace image in {qual} natural "
              "language via the vision-language backend",
        params=(("handle", "string", "image handle"),)),
    ToolFamily(
        "webnav", "webnav_apis", "ui_web_navigation",
        reads="", writes="ui",
        verbs=("focus", "toggle", "drag", "hover", "pin", "expand",
               "dismiss", "snap"),
        nouns=("sidebar", "workbench", "inspector", "breadcrumb",
               "modal", "toolbar", "minimap", "console"),
        quals=("application", "dashboard", "review", "browser"),
        blurb="{verb} the {noun} element of the {qual} surface and "
              "record the interaction in the UI session state",
        params=(("target", "string", "element label or selector"),)),
    ToolFamily(
        "corpus", "corpus_apis", "information_seeking",
        reads="", writes="answer rng",
        verbs=("digest", "excerpt", "cite", "collate", "trace",
               "cross_reference", "abstract", "curate"),
        nouns=("briefings", "glossaries", "bulletins", "datasheets",
               "advisories", "gazetteers", "almanacs", "dossiers"),
        quals=("curated", "versioned", "authoritative", "indexed"),
        blurb="{verb} {noun} from the {qual} knowledge corpus into a "
              "sourced textual answer",
        params=(("topic", "string", "lookup topic"),)),
    ToolFamily(
        "audio", "audio_apis", "speech_transcription",
        reads="", writes="answer rng",
        verbs=("segment", "diarize", "caption", "denoise", "timestamp",
               "summarize", "detect_language", "align"),
        nouns=("briefing", "standup", "interview", "broadcast",
               "voicemail", "fieldnote", "readout", "debrief"),
        quals=("multi-speaker", "noisy-channel", "long-form", "archived"),
        blurb="{verb} a {qual} {noun} recording through the speech "
              "backend and return the text",
        params=(("clip", "string", "audio clip id"),)),
    ToolFamily(
        "notebook", "notebook_apis", "code_analysis",
        reads="", writes="artifacts",
        verbs=("chart", "pivot", "export", "snapshot", "diff",
               "profile", "render", "bundle"),
        nouns=("metrics", "ledgers", "rollups", "matrices", "notebooks",
               "reports", "extracts", "summaries"),
        quals=("reproducible", "sandboxed", "scheduled", "pinned"),
        blurb="{verb} workspace {noun} into a {qual} analysis artifact",
        params=(("spec", "string", "analysis specification"),)),
)

FAMILY_NAMES: Tuple[str, ...] = tuple(f.name for f in FAMILIES)

#: intent -> generated libraries serving it (alongside TABLE1_MAP)
_FAMILY_LIBS_BY_INTENT: Dict[str, Tuple[str, ...]] = {
    intent: tuple(sorted(f.library for f in FAMILIES
                         if f.intent == intent))
    for intent in sorted({f.intent for f in FAMILIES})
}

N_BASE_TOOLS = len(DEFAULT_REGISTRY.tools)

# derail-pool prefixes the scripted planner wanders to
# (core/planner.py); generated names must never collide with them
_DERAIL_PREFIXES = ("sql_", "wiki_", "ui_read", "suggest_", "web_search")
assert not any(f"{f.name}_".startswith(p) or p.startswith(f"{f.name}_")
               for f in FAMILIES for p in _DERAIL_PREFIXES)
assert all(f.intent in INTENTS for f in FAMILIES)


def family_of(name: str) -> Optional[str]:
    """The generated family a tool name belongs to, else None (base
    tools and unknown names)."""
    for fam in FAMILIES:
        if name.startswith(fam.name + "_"):
            return fam.name
    return None


def _generated_tool(fam: ToolFamily, index: int,
                    rng: np.random.Generator) -> Tool:
    """The ``index``-th member of a family; the verb/noun grid gives 64
    distinct names per family, an index suffix extends past that."""
    verb = fam.verbs[index % len(fam.verbs)]
    noun = fam.nouns[(index // len(fam.verbs)) % len(fam.nouns)]
    name = f"{fam.name}_{verb}_{noun}"
    if index >= len(fam.verbs) * len(fam.nouns):
        name = f"{name}_{index:03d}"
    qual = fam.quals[int(rng.integers(0, len(fam.quals)))]
    desc = fam.blurb.format(verb=verb.replace("_", " "),
                            noun=noun, qual=qual)
    return Tool(name, fam.library, desc, fam.params)


def build_catalog(n_tools: int, seed: int = 0) -> ToolRegistry:
    """A deterministic registry of exactly ``n_tools`` tools (see the
    module docstring for sizing semantics). Same ``(n_tools, seed)`` ⇒
    byte-identical ``catalog_text()``."""
    if n_tools < 1:
        raise ValueError(f"build_catalog needs n_tools >= 1, "
                         f"got {n_tools}")
    base = list(DEFAULT_REGISTRY.tools.values())   # registration order
    reg = ToolRegistry()
    for tool in base[:n_tools]:
        reg.register(tool)
    if n_tools <= len(base):
        return reg
    rng = np.random.default_rng(seed)
    counts = [0] * len(FAMILIES)
    for j in range(n_tools - len(base)):
        fam_idx = j % len(FAMILIES)
        reg.register(_generated_tool(FAMILIES[fam_idx], counts[fam_idx],
                                     rng))
        counts[fam_idx] += 1
    return reg


def catalog_intent_libraries(registry: ToolRegistry
                             ) -> Dict[str, Tuple[str, ...]]:
    """Intent -> libraries *present in this registry*, extending the
    paper's Table-1 map with each generated family's home intent.
    Intents with no surviving library are omitted, so the gate falls
    back to the full catalog instead of emptying the visible toolset
    (a truncated registry must never make ``visible`` empty)."""
    present = set(registry.libraries())
    out: Dict[str, Tuple[str, ...]] = {}
    for intent in INTENTS:
        libs = (set(TABLE1_MAP.get(intent, ()))
                | set(_FAMILY_LIBS_BY_INTENT.get(intent, ()))) & present
        if libs:
            out[intent] = tuple(sorted(libs))
    return out


def catalog_intent_map(registry: ToolRegistry) -> IntentMap:
    """The ``IntentMap`` the gate and the retriever prior share for a
    generated catalog."""
    return IntentMap(catalog_intent_libraries(registry))
