"""GeckOpt runtime intent gate.

One extra (cheap) LLM call per query classifies intent and selects the
relevant API libraries BEFORE any tool-specific prompting. The classifier
backend is pluggable:

  * ScriptedIntentClassifier — GPT-4-proxy with a calibrated accuracy
    (keyword-matching plus seeded confusion), used by the Table-2 harness;
  * NeuralIntentClassifier — our own served planner-proxy model with a
    constrained intent head (examples/train_planner.py trains it);
  * BatchedNeuralIntentClassifier — same decisions, but all queries of a
    pipeline admission wave scored in ONE jitted forward pass
    (serving/neural_planner.py).

Classifiers expose ``classify(query)`` and optionally
``classify_batch(queries)``; ``IntentGate.batch`` uses the batched
entry point when present so the serving pipeline amortizes the gate
model call across concurrent sessions. The gate prompt is real text and
is charged to each session's ledger either way.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.accounting import TokenLedger
from repro.core.intents import INTENT_DESCRIPTIONS, INTENTS, IntentMap

GATE_SYSTEM = (
    "You are the intent router of a geospatial Copilot platform. "
    "Classify the user query into exactly one intent and reply with the "
    "intent name only.\nIntents:\n" + "\n".join(
        f"- {k}: {v}" for k, v in INTENT_DESCRIPTIONS.items()))

_KEYWORDS = {
    "load_filter_plot": ("plot", "show", "map", "display", "visualize"),
    "detection_analysis": ("how many", "detect", "count", "detection",
                           "bounding"),
    "landcover_analysis": ("land cover", "landcover", "dominant",
                           "vegetation", "fraction"),
    "information_seeking": ("look up", "summarize what we know", "wiki",
                            "knowledge base"),
    "ui_web_navigation": ("search the web", "open", "browse", "click",
                          "navigate", "bing"),
    "visual_qa": ("describe", "what is shown", "is there", "question about"),
    "speech_transcription": ("transcribe", "audio", "speech", "recording"),
    "code_analysis": ("tabulate", "table", "script", "python"),
}


def keyword_intent(query: str) -> str:
    q = query.lower()
    best, score = "load_filter_plot", 0
    for intent, kws in _KEYWORDS.items():
        s = sum(1 for kw in kws if kw in q)
        if s > score:
            best, score = intent, s
    return best


@dataclass
class ScriptedIntentClassifier:
    accuracy: float = 0.97
    rng: np.random.Generator = None

    def __post_init__(self):
        if self.rng is None:
            self.rng = np.random.default_rng(0)

    def classify(self, query: str) -> Tuple[str, str]:
        """Returns (intent, completion_text)."""
        intent = keyword_intent(query)
        if self.rng.random() > self.accuracy:
            others = [i for i in INTENTS if i != intent]
            intent = others[int(self.rng.integers(0, len(others)))]
        return intent, intent

    def classify_batch(self, queries: Sequence[str]
                       ) -> List[Tuple[str, str]]:
        """Batched entry point; draws from the SAME rng stream in query
        order, so a wave-batched run reproduces the sequential one."""
        return [self.classify(q) for q in queries]


def gate_prompt(query: str) -> str:
    """The serialized gate request (what the ledger charges)."""
    return f"{GATE_SYSTEM}\n\nQuery: {query}\nIntent:"


class IntentGate:
    def __init__(self, intent_map: IntentMap, classifier,
                 all_libraries: Sequence[str]):
        self.intent_map = intent_map
        self.classifier = classifier
        self.all_libraries = tuple(all_libraries)

    def __call__(self, query: str, ledger: TokenLedger
                 ) -> Tuple[str, Tuple[str, ...]]:
        intent, completion = self.classifier.classify(query)
        ledger.record("gate", gate_prompt(query), completion)
        libs = self.intent_map.libraries_for(intent, self.all_libraries)
        return intent, libs

    def batch(self, queries: Sequence[str], ledgers: Sequence[TokenLedger]
              ) -> List[Tuple[str, Tuple[str, ...]]]:
        """Gate a whole admission wave. Uses the classifier's batched
        forward when it has one; token accounting is identical to the
        per-query path (each session is charged its own gate prompt)."""
        assert len(queries) == len(ledgers)
        if hasattr(self.classifier, "classify_batch"):
            decisions = self.classifier.classify_batch(queries)
        else:
            decisions = [self.classifier.classify(q) for q in queries]
        out = []
        for query, ledger, (intent, completion) in zip(queries, ledgers,
                                                       decisions):
            ledger.record("gate", gate_prompt(query), completion)
            out.append((intent,
                        self.intent_map.libraries_for(
                            intent, self.all_libraries)))
        return out
