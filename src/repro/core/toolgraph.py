"""Tool-graph compiler: DAG representation, validation and deterministic
wave scheduling for fused parallel function calling.

Grounded in "An LLM-Tool Compiler for Fused Parallel Function Calling"
(PAPERS.md): instead of one linear wave of tool calls per planner
round-trip, the planner emits a DAG of ``{tool, args, deps}`` nodes and
the runtime executes *independent* nodes together in topological waves.
GeckOpt's gating narrows the catalog so the planner aggregates more
calls per step; compiling those calls into a DAG multiplies the win —
whole multi-stage programs collapse into one LLM round-trip.

Determinism contract (DESIGN.md §Tool-graph compiler):

  * dependencies are inferred from *workspace data-flow hazards* —
    read-after-write, write-after-read and write-after-write conflicts
    on named workspace resources (handles, map, detections, landcover,
    artifacts, answer, ui, rng). Two nodes whose relative order can
    affect workspace state or observations are ALWAYS ordered by a
    dependency chain; in particular the session rng is a write resource,
    so every stochastic tool is serialized against every other.
  * consequently ANY topological execution order — including the wave
    schedule — produces bitwise-identical workspace end-state and
    per-node observations to sequential emission-order execution.
  * ``wave_schedule`` itself is deterministic: wave k holds exactly the
    nodes whose longest dependency chain has length k, each wave sorted
    by node id. No dict-iteration order leaks into the schedule.

Validation rejects malformed graphs with *typed* errors (cycles,
unknown tools, dangling deps, duplicate ids) so callers can distinguish
planner bugs from environment failures.

This module is dependency-free w.r.t. the environment: callers supply
the per-tool effect table (``env.tools_impl.TOOL_EFFECTS`` is the
authoritative one) so core → env import direction stays acyclic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, FrozenSet, List, Mapping,
                    Optional, Sequence, Tuple)


# ----------------------------------------------------------- typed errors --

class ToolGraphError(Exception):
    """Base class for all tool-graph validation failures."""


class CycleError(ToolGraphError):
    """The dependency graph has a cycle (or a self-dependency)."""


class UnknownToolError(ToolGraphError):
    """A node names a tool with no known implementation/effects."""


class UnknownDepError(ToolGraphError):
    """A node depends on a node id that is not in the graph."""


class DuplicateNodeError(ToolGraphError):
    """Two nodes share the same node id."""


# ------------------------------------------------------------- data model --

#: The hazard alphabet: every named workspace resource dependency
#: inference may order on. ``env/tools_impl.WORKSPACE_RESOURCE_ATTRS``
#: maps each name to the concrete ``Workspace`` attribute it denotes;
#: the static analyzer (``repro.analysis``) and the import-time
#: ``core.tools.validate_effects`` check both directions against this
#: set, so an effects entry can never silently name a resource the
#: hazard analysis does not know.
WORKSPACE_RESOURCES: FrozenSet[str] = frozenset({
    "handles", "map", "detections", "landcover", "artifacts",
    "answer", "ui", "rng",
})


@dataclass(frozen=True)
class ToolEffects:
    """Workspace resources a tool reads/writes — the hazard alphabet.

    ``writes`` membership implies the tool conflicts with every earlier
    reader AND writer of that resource; ``reads`` only with earlier
    writers. The pseudo-resource ``"rng"`` marks tools that consume the
    workspace's seeded random stream: it is modelled as a *write* so all
    stochastic tools form a serial chain (their relative order changes
    draws, hence observations).
    """
    reads: FrozenSet[str] = frozenset()
    writes: FrozenSet[str] = frozenset()

    def resources(self) -> FrozenSet[str]:
        return self.reads | self.writes

    def unknown_resources(self, alphabet: FrozenSet[str] = WORKSPACE_RESOURCES
                          ) -> FrozenSet[str]:
        """Resource names this entry uses that ``alphabet`` lacks —
        non-empty means hazard inference would silently ignore them."""
        return self.resources() - alphabet


@dataclass(frozen=True)
class ToolNode:
    """One compiled call: ``deps`` are node ids that must execute first."""
    node_id: int
    tool: str
    args: Dict[str, Any] = field(default_factory=dict)
    deps: Tuple[int, ...] = ()

    def to_json(self) -> Dict[str, Any]:
        return {"id": self.node_id, "tool": self.tool, "args": self.args,
                "deps": list(self.deps)}


@dataclass
class ToolGraph:
    """A validated DAG of tool calls for one planner round-trip."""
    nodes: List[ToolNode] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def node_ids(self) -> List[int]:
        return [n.node_id for n in self.nodes]

    def node(self, node_id: int) -> ToolNode:
        for n in self.nodes:
            if n.node_id == node_id:
                return n
        raise UnknownDepError(f"no node with id {node_id}")

    # ------------------------------------------------------- validation ----
    def validate(self, known_tools: Optional[Sequence[str]] = None
                 ) -> "ToolGraph":
        """Raise a typed ``ToolGraphError`` subclass on the first defect;
        return self when the graph is a well-formed DAG."""
        seen: set = set()
        for n in self.nodes:
            if n.node_id in seen:
                raise DuplicateNodeError(
                    f"duplicate node id {n.node_id} ({n.tool})")
            seen.add(n.node_id)
        if known_tools is not None:
            known = set(known_tools)
            for n in self.nodes:
                if n.tool not in known:
                    raise UnknownToolError(
                        f"node {n.node_id}: unknown tool {n.tool!r}")
        for n in self.nodes:
            for d in n.deps:
                if d not in seen:
                    raise UnknownDepError(
                        f"node {n.node_id} ({n.tool}) depends on "
                        f"unknown node id {d}")
        self.wave_schedule()          # raises CycleError on cycles
        return self

    # -------------------------------------------------------- scheduling ----
    def wave_schedule(self) -> List[List[int]]:
        """Deterministic topological wave schedule.

        Wave k = node ids whose longest dependency chain has length k
        (so every node lands in the earliest wave its deps allow),
        sorted ascending within the wave. Raises ``CycleError`` if the
        graph is not a DAG. Depth is computed with Kahn's algorithm over
        sorted worklists — no dict/iteration order reaches the result.
        """
        deps = {n.node_id: tuple(n.deps) for n in self.nodes}
        dependents: Dict[int, List[int]] = {i: [] for i in deps}
        indeg = {i: 0 for i in deps}
        for nid, ds in deps.items():
            for d in ds:
                if d == nid:
                    raise CycleError(f"node {nid} depends on itself")
                dependents[d].append(nid)
                indeg[nid] += 1
        depth = {i: 0 for i in deps}
        ready = sorted(i for i, k in indeg.items() if k == 0)
        done = 0
        while ready:
            nid = ready.pop(0)
            done += 1
            for child in sorted(dependents[nid]):
                depth[child] = max(depth[child], depth[nid] + 1)
                indeg[child] -= 1
                if indeg[child] == 0:
                    ready.append(child)
            ready.sort()
        if done != len(deps):
            stuck = sorted(i for i, k in indeg.items() if k > 0)
            raise CycleError(f"dependency cycle through nodes {stuck}")
        waves: Dict[int, List[int]] = {}
        for nid in sorted(depth):
            waves.setdefault(depth[nid], []).append(nid)
        return [sorted(waves[k]) for k in sorted(waves)]

    def to_json(self) -> List[Dict[str, Any]]:
        return [n.to_json() for n in self.nodes]


# --------------------------------------------------------- dep inference ----

EffectsFn = Callable[[str], ToolEffects]


def _effects_fn(effects: "Mapping[str, ToolEffects] | EffectsFn"
                ) -> EffectsFn:
    if callable(effects):
        return effects
    table = effects

    def lookup(tool: str) -> ToolEffects:
        try:
            return table[tool]
        except KeyError:
            raise UnknownToolError(f"no effects entry for tool {tool!r}")
    return lookup


def infer_deps(calls: Sequence, effects: "Mapping[str, ToolEffects] | "
               "EffectsFn") -> ToolGraph:
    """Compile an emission-ordered call list into a hazard DAG.

    ``calls`` is any sequence of objects with ``.tool`` and ``.args``
    (e.g. ``env.tasks.ToolCall``); node ids are assigned 0..n-1 in
    emission order. Node j depends on:

      * the last prior writer of every resource j reads   (RAW)
      * the last prior writer of every resource j writes  (WAW)
      * every prior reader since that writer, for every
        resource j writes                                 (WAR)

    Unknown tools raise ``UnknownToolError`` at compile time — before
    anything executes.
    """
    lookup = _effects_fn(effects)
    last_writer: Dict[str, int] = {}
    readers_since: Dict[str, List[int]] = {}
    nodes: List[ToolNode] = []
    for i, call in enumerate(calls):
        eff = lookup(call.tool)
        deps = set()
        for r in eff.reads:
            if r in last_writer:
                deps.add(last_writer[r])
        for r in eff.writes:
            if r in last_writer:
                deps.add(last_writer[r])
            deps.update(readers_since.get(r, ()))
        deps.discard(i)
        nodes.append(ToolNode(i, call.tool, dict(call.args),
                              tuple(sorted(deps))))
        for r in eff.reads:
            readers_since.setdefault(r, []).append(i)
        for r in eff.writes:
            last_writer[r] = i
            readers_since[r] = []
    return ToolGraph(nodes)


def compile_calls(calls: Sequence, effects: "Mapping[str, ToolEffects] | "
                  "EffectsFn") -> ToolGraph:
    """infer_deps + validate: the planner's one-stop compile entry."""
    g = infer_deps(calls, effects)
    g.validate()
    return g
