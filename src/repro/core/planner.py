"""Compositional planners: CoT / ReAct, zero/few-shot, ±GeckOpt.

``ScriptedPlanner`` is the GPT-4-Turbo proxy for the Table-2 harness: it
plans against the task's ground-truth stage list with a calibrated
competence/noise model (we cannot call the paper's GPT-4 fleet; the
*token accounting* is fully mechanical — real serialized prompts — while
planner quality is parameterized; see DESIGN.md §Assumption changes).

The paper's central empirical lever is reproduced mechanically:
the probability of aggregating a whole stage (multi-tool per step) rises
as the visible toolset shrinks — "a narrower selection of tools ...
encourages the aggregation of more tools per step".
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.toolgraph import ToolGraph, compile_calls
from repro.core.tools import Tool, ToolRegistry
from repro.env.tasks import Task, ToolCall
from repro.env.tools_impl import tool_effects

SYSTEM_PROMPT = (
    "You are the planning agent of the GeoLLM-Engine geospatial Copilot "
    "platform. You complete user tasks by calling the API tools listed "
    "below. Emit one or more tool calls per step as a JSON array of "
    "{tool, args} objects; the platform executes them in order and "
    "returns one observation per call. Finish with a line starting with "
    "'Final:' containing the answer shown to the user. If a required tool "
    "is unavailable reply TOOL_NOT_FOUND and nothing else.\n"
    "Platform rules:\n"
    "- image handles are catalog ids (img_NNNNN); never invent handles — "
    "always obtain them from SQL_apis queries before loading;\n"
    "- workspace state persists across steps: loaded handles, map layers, "
    "detection results, classified rasters and exported artifacts remain "
    "available to subsequent tools;\n"
    "- visualization tools (map_apis) operate on the current workspace; "
    "call them after the data they render exists;\n"
    "- model-backed tools (detect_apis, landcover_apis, vqa_apis, "
    "vision_apis, speech_apis) are expensive: batch their inputs into a "
    "single call where possible;\n"
    "- argument values must be grounded in prior observations or the user "
    "query; quote dates as ISO yyyy-mm-dd; cloud cover is a 0-1 fraction;\n"
    "- if a tool call errors, read the error, correct the arguments or "
    "choose the right tool, and retry in the next step;\n"
    "- do not repeat a successful call; do not call tools outside the "
    "catalog; keep answers concise and grounded in observations.")

COT_INSTRUCTIONS = (
    "Think step by step about the sub-tasks required, then emit the tool "
    "calls for the next sub-task.")
REACT_INSTRUCTIONS = (
    "Use the Thought/Action/Observation format: write a Thought analyzing "
    "the current state, then an Action containing tool calls, then wait "
    "for the Observation.")

PLATFORM_CONTEXT = (
    "Platform reference (read before planning):\n"
    "Catalog sensors: xview1 (30cm pan-sharpened, object-detection grade), "
    "sentinel2 (10m multispectral, 13 bands B1-B12+B8A, 5-day revisit), "
    "landsat8 (30m, thermal B10/B11), naip (60cm aerial, CONUS only), "
    "worldview3 (31cm, SWIR capable). Imagery metadata columns: image_id, "
    "sensor, region, date (ISO-8601), cloud (0-1), footprint (WGS84 "
    "polygon), off_nadir_deg, sun_elevation_deg, processing_level.\n"
    "Supported CRS targets: EPSG:4326 (WGS84 geographic), EPSG:3857 (web "
    "mercator), UTM zones via EPSG:326xx. Reprojection resamples bilinear "
    "for continuous rasters and nearest for class maps.\n"
    "Detection checkpoints: dino-airplane-v2 (AP50 0.91 on xview1), "
    "dino-ship-v2 (AP50 0.88, handles wakes), dino-storage-tank-v1, "
    "yolo-vehicle-s (fast, use for >10 images), dino-helipad-v1, "
    "dino-bridge-v1, dino-crane-v1. Land-cover model: esa-worldcover-v2 "
    "(water/trees/crops/built/bare/grass, 10m). VQA/captioning backend: "
    "qwen2-vl-72b served on the inference mesh; speech backend: "
    "whisper-large-v3. Model-backed calls are billed per image — batch "
    "inputs whenever the plan allows.\n"
    "Workspace semantics: load_images materializes rasters into the "
    "session workspace; filters mutate the handle set in place; map state "
    "is additive (layers stack); export_geotiff and screenshot_map write "
    "to the artifact store; run_python executes in a sandbox with numpy "
    "and the workspace mounted read-only.\n"
    "Quota notes: SQL queries are free; raster loads count against the "
    "session raster budget (256 scenes); detector and classifier calls "
    "run on shared GPU pools and may queue under load; web and UI tools "
    "execute in an isolated browser profile.\n"
    "Output contract: every Action must be a JSON array; every Final line "
    "must summarize counts, classes or artifacts produced, and reference "
    "handles by id. Observations are authoritative — never contradict "
    "them.\n"
    "Error codes: E101 unknown handle (re-query the catalog), E102 empty "
    "workspace (load before processing), E103 CRS mismatch (reproject "
    "first), E201 detector queue timeout (retry once), E202 class not "
    "supported by checkpoint (consult suggest_model), E301 map has no "
    "layers (plot before screenshot), E401 article not found (search "
    "first), E402 page fetch blocked (use a result url from web_search), "
    "E501 sandbox limit exceeded (reduce input size). On any error, fix "
    "the root cause in the next step rather than repeating the call.\n"
    "Region glossary: named regions resolve through sql_query_regions to "
    "catalog region ids with WGS84 bounding boxes; coastal regions "
    "include a 12nm maritime buffer (relevant for ship detection); "
    "metropolitan regions clip to the administrative boundary; polar "
    "acquisitions may have low sun elevation — prefer sensors with SWIR "
    "when shadows matter. Dates filter on acquisition time in UTC; "
    "revisit gaps differ per sensor (see sensor list above).")

SESSION_DIGEST = (
    "Recent session digest (for continuity):\n"
    "- 09:12 user asked for sentinel2 coverage of the Rotterdam port "
    "expansion; 14 scenes loaded, NDVI computed, composite exported as "
    "workspace://ndvi_rotterdam_q2.tif; map centered on 51.95N 4.14E.\n"
    "- 09:31 ship detection over the maritime buffer: dino-ship-v2 on 9 "
    "scenes, 143 detections, heatmap layer saved; two scenes skipped for "
    "cloud cover 0.71 and 0.64 (threshold 0.4).\n"
    "- 09:47 land-cover comparison 2021 vs 2023 for the reclaimed area: "
    "built fraction 0.31 -> 0.38, water 0.22 -> 0.16; histogram artifact "
    "tabulated and pinned to the project dashboard.\n"
    "- 10:02 knowledge-base lookup on sentinel-2 band designations cited "
    "in the quarterly report draft; summary stored under notes/bands.md.\n"
    "- Active preferences: EPSG:3857 for web maps, bilinear resampling, "
    "detector confidence threshold 0.35, max 24 scenes per load, artifact "
    "names kebab-case with date suffix.\n"
    "- 10:18 UI session: dashboard panel rearranged, notes panel pinned "
    "left, detection review queue cleared (11 items approved, 2 flagged "
    "for re-inference at higher confidence).\n"
    "- 10:26 audio: two stand-up recordings transcribed and filed under "
    "notes/standups/; action items extracted to the project tracker.\n"
    "- 10:33 web research: three vendor pages on SAR tasking APIs "
    "captured to the evidence folder with citations.\n"
    "- Data dictionary reminders: 'cloud' is scene-average from the "
    "sensor QA mask, not AOI-clipped; 'off_nadir_deg' above 25 degrades "
    "detection recall; sentinel2 B10 is cirrus-only and excluded from "
    "surface composites; NAIP has no SWIR so NDVI uses B4/B1 mapping; "
    "detection results are immutable once written — re-run the detector "
    "rather than editing boxes; land-cover class 'bare' includes beaches "
    "and quarries; exports default to cloud-optimized GeoTIFF.")

FEW_SHOT_EXAMPLES = """Example task: Plot sentinel2 images of Rotterdam.
Thought: I need region + catalog query, then load and plot.
Action: [{"tool":"sql_query_regions","args":{"place":"Rotterdam"}},
{"tool":"sql_query_images","args":{"sensor":"sentinel2","region":"Rotterdam"}}]
Observation: {"regions":["Rotterdam"],"image_ids":["img_00031"]}
Action: [{"tool":"load_images","args":{"image_ids":["img_00031"]}},
{"tool":"plot_map","args":{"region":"Rotterdam"}}]
Observation: {"map":"rendered"}
Final: rendered 1 sentinel2 image of Rotterdam.

Example task: How many ships are docked near Singapore?
Thought: query catalog, load, detect ships, count.
Action: [{"tool":"sql_query_images","args":{"sensor":"xview1","region":"Singapore"}}]
Observation: {"image_ids":["img_00007","img_00104"]}
Action: [{"tool":"load_images","args":{"image_ids":["img_00007","img_00104"]}},
{"tool":"detect_objects","args":{"classes":["ship"]}},
{"tool":"count_objects","args":{"classes":["ship"]}}]
Observation: {"detections":{"ship":9}}
Final: 9 ships detected.
"""


@dataclass(frozen=True)
class PlannerConfig:
    mode: str = "cot"            # cot | react
    few_shot: bool = False
    temperature: float = 0.3
    # competence model (GPT-4-Turbo proxy calibration)
    p_wrong_tool_zs: float = 0.030
    p_wrong_tool_fs: float = 0.018
    p_task_derail_cot: float = 0.360
    p_task_derail_react: float = 0.310
    p_derail_recover: float = 0.35
    derail_fs_factor: float = 0.82   # few-shot derails less often
    p_skip_side_effect: float = 0.08
    max_steps: int = 12
    # tool-graph compiler: emit DAG-of-calls round-trips that fuse many
    # virtual planner steps into one LLM request (DESIGN.md §Tool-graph
    # compiler). The behaviour model is unchanged — the same next_step
    # rng stream drives both modes — so workspace outcomes are bitwise
    # identical to the linear planner; only round-trip/token accounting
    # moves.
    compile_plans: bool = False

    @property
    def name(self) -> str:
        shot = "few_shot" if self.few_shot else "zero_shot"
        return f"{self.mode}_{shot}"


@dataclass
class PlanStep:
    thought: str
    calls: List[ToolCall]
    final: Optional[str] = None
    tool_not_found: bool = False


@dataclass
class CompiledStep:
    """One compiled planner round-trip: a hazard-DAG of tool calls that
    fuses ``n_virtual`` consecutive linear planner steps, optionally
    terminated by the final answer. ``graph`` node ids are assigned in
    emission order, so ascending node id == the linear execution order.
    """
    thought: str
    graph: ToolGraph
    final: Optional[str] = None
    tool_not_found: bool = False
    n_virtual: int = 0

    @property
    def calls(self) -> List[ToolCall]:
        return [ToolCall(n.tool, n.args) for n in self.graph.nodes]


class ScriptedPlanner:
    """GPT-4-Turbo proxy planning against the ground-truth stage list."""

    def __init__(self, cfg: PlannerConfig, registry: ToolRegistry,
                 seed: int = 0):
        self.cfg = cfg
        self.registry = registry
        self.n_total_tools = len(registry.tools)
        self.rng = np.random.default_rng(seed)

    # -- behaviour model ----------------------------------------------------
    def p_aggregate(self, n_visible: int) -> float:
        """Multi-tool aggregation propensity vs toolset breadth — the
        paper's central observation, "a narrower selection of tools ...
        encourages the aggregation of more tools per step"."""
        frac = n_visible / max(self.n_total_tools, 1)
        return float(np.clip(0.54 - 0.37 * frac, 0.17, 0.54))

    # calls the proxy planner may forget without breaking the main answer
    # (outcome-critical filters are NOT skippable)
    _SKIPPABLE = {"draw_bboxes", "ui_scroll", "sql_count", "mosaic",
                  "screenshot_map", "add_layer", "plot_histogram"}

    def start_task(self, task: Task):
        self._remaining: List[List[ToolCall]] = [list(s) for s in task.plan]
        cfg = self.cfg
        derail = (cfg.p_task_derail_react if cfg.mode == "react"
                  else cfg.p_task_derail_cot)
        if cfg.few_shot:
            derail *= cfg.derail_fs_factor
        # pre-draw the task-level competence outcome, anchored to plan
        # PROGRESS (stage index), not step count — aggregation must not
        # change the planner's propensity to go off-plan
        n_stages = max(len(self._remaining), 1)
        self._derail_stage = (int(self.rng.integers(0, n_stages))
                              if self.rng.random() < derail else -1)
        self._stages_entered = 0
        # success-only slip: forget one non-critical side-effect call
        if self.rng.random() < cfg.p_skip_side_effect:
            for stage in self._remaining:
                drop = [c for c in stage if c.tool in self._SKIPPABLE]
                if drop:
                    stage.remove(drop[0])
                    break
        self._remaining = [s for s in self._remaining if s]
        self._steps_taken = 0

    def next_step(self, task: Task, visible_tools: Dict[str, Tool],
                  history: List[str]) -> PlanStep:
        cfg = self.cfg
        self._steps_taken += 1
        thought = ""
        if cfg.mode == "react":
            nxt = (self._remaining[0][0].tool if self._remaining
                   else "final answer")
            thought = (f"Thought: the task '{task.query[:80]}' has "
                       f"{len(self._remaining)} remaining sub-goals. The "
                       f"previous observations are consistent with the "
                       f"plan; the workspace holds the intermediate "
                       f"results I need. Next I should invoke {nxt} with "
                       f"arguments grounded in the latest observation, "
                       f"then verify the result before moving on.")

        if not self._remaining:
            return PlanStep(thought, [], final=self._final_text(task))

        # gating miss: a needed tool is not in the visible catalog. The
        # planner first probes the nearest-looking visible tool (wasted
        # step + error observation), then declares TOOL_NOT_FOUND.
        needed = self._remaining[0][0]
        if needed.tool not in visible_tools:
            if not getattr(self, "_miss_probed", False):
                self._miss_probed = True
                vis = sorted(visible_tools)
                probe = vis[int(self.rng.integers(0, len(vis)))]
                return PlanStep(thought, [ToolCall(probe, {})])
            return PlanStep(thought, [], tool_not_found=True)

        # derail event: the proxy planner goes off-plan irrecoverably when
        # it reaches the pre-drawn stage
        if self._derail_stage == self._stages_entered:
            # off-plan excursions are read-only in practice (queries,
            # lookups) — they waste steps without corrupting the workspace
            wrong = [t for t in self.registry.tools
                     if t.startswith(("sql_", "wiki_", "ui_read",
                                      "suggest_", "web_search"))]
            bad = wrong[int(self.rng.integers(0, len(wrong)))]
            self._derail_stage = -2
            if self.rng.random() < cfg.p_derail_recover:
                # wrong turn, but the planner recovers the plan afterwards
                self._remaining = [[ToolCall(bad, {})]] + self._remaining
            else:
                # irrecoverable: the rest of the plan is lost
                self._remaining = [[ToolCall(bad, {})]]

        # transient wrong-tool slip (retries next step)
        p_slip = (cfg.p_wrong_tool_fs if cfg.few_shot
                  else cfg.p_wrong_tool_zs)
        if self.rng.random() < p_slip:
            vis = list(visible_tools)
            bad = vis[int(self.rng.integers(0, len(vis)))]
            return PlanStep(thought, [ToolCall(bad, {})])

        # aggregation: how many calls of the current stage in one step?
        stage = self._remaining[0]
        if self.rng.random() < self.p_aggregate(len(visible_tools)):
            calls = stage
            self._remaining = self._remaining[1:]
            self._stages_entered += 1
            # strong aggregators sometimes merge the following stage too
            if (self._remaining and len(calls) +
                    len(self._remaining[0]) <= 4
                    and self.rng.random() < 0.30):
                calls = calls + self._remaining[0]
                self._remaining = self._remaining[1:]
                self._stages_entered += 1
        else:
            calls = [stage[0]]
            rest = stage[1:]
            self._remaining = ([rest] if rest else []) + self._remaining[1:]
            if not rest:
                self._stages_entered += 1
        return PlanStep(thought, list(calls))

    def next_compiled_step(self, task: Task, visible_tools: Dict[str, Tool],
                           history: List[str], max_virtual: int
                           ) -> CompiledStep:
        """Compile up to ``max_virtual`` consecutive linear planner steps
        into ONE round-trip: a hazard-DAG of their calls (deps inferred
        from workspace data-flow) plus the final answer when the plan
        completes inside the window.

        Determinism: this calls the SAME ``next_step`` the linear path
        uses, in the same order, so the competence-model rng stream
        (derail, slips, aggregation draws) is consumed identically —
        compilation changes round-trip structure, never behaviour.
        Collection stops at a TOOL_NOT_FOUND boundary: the fallback
        swaps the visible catalog between round-trips, so it must not
        share a completion with pre-fallback calls. The boundary peek is
        free — the TOOL_NOT_FOUND branch of ``next_step`` draws no rng
        and leaves the plan untouched, so the next round-trip re-emits
        it verbatim.
        """
        thought = ""
        final: Optional[str] = None
        tool_not_found = False
        calls: List[ToolCall] = []
        n_virtual = 0
        while n_virtual < max_virtual:
            step = self.next_step(task, visible_tools, history)
            if n_virtual == 0:
                thought = step.thought
            if step.tool_not_found:
                if not calls:           # a bare TOOL_NOT_FOUND round-trip
                    tool_not_found = True
                    n_virtual += 1
                break
            if step.final is not None:  # fold the final into this round
                final = step.final
                n_virtual += 1
                break
            calls.extend(step.calls)
            n_virtual += 1
        graph = compile_calls(calls, tool_effects)
        return CompiledStep(thought, graph, final=final,
                            tool_not_found=tool_not_found,
                            n_virtual=n_virtual)

    def note_fallback(self):
        """Called by the agent after a full-catalog fallback: the context
        switch occasionally confuses the proxy planner (paper: 'slight
        deviations')."""
        if self.rng.random() < 0.30:
            self._derail_stage = self._stages_entered

    def _final_text(self, task: Task) -> str:
        return (f"Final: task '{task.query[:50]}' completed; results are "
                f"in the workspace.")

    # -- prompt serialization (REAL tokens) ----------------------------------
    def serialize_prompt_prefix(self, catalog_text: str) -> str:
        """The task-independent head of every planner prompt: system +
        platform context + instructions + the (gated) catalog. Sessions
        sharing an intent share this text verbatim — it is what the
        engine's per-intent prefix cache prefills once (see DESIGN.md
        §Pipeline concurrency)."""
        cfg = self.cfg
        parts = [SYSTEM_PROMPT, PLATFORM_CONTEXT, SESSION_DIGEST,
                 REACT_INSTRUCTIONS if cfg.mode == "react"
                 else COT_INSTRUCTIONS,
                 "Available tools:", catalog_text]
        if cfg.few_shot:
            parts.append(FEW_SHOT_EXAMPLES)
        parts.append(
            "Session: geollm-engine v2.4 | project: default | mesh region "
            "cache warm | artifact store: workspace:// | time budget: "
            "standard | user tier: enterprise")
        return "\n".join(parts)

    def serialize_prompt(self, task: Task, catalog_text: str,
                         history: List[str]) -> str:
        parts = [self.serialize_prompt_prefix(catalog_text),
                 f"Task: {task.query}"]
        parts.extend(history)
        return "\n".join(parts)

    @staticmethod
    def serialize_completion(step) -> str:
        """Serialize a PlanStep or CompiledStep emission. Compiled
        round-trips emit the DAG itself — node ids and deps included —
        so the token accounting honestly prices the fused program the
        planner would have to write out."""
        parts = []
        if step.thought:
            parts.append(step.thought)
        if step.tool_not_found:
            parts.append("TOOL_NOT_FOUND")
        if isinstance(step, CompiledStep):
            if step.graph.nodes:
                parts.append("Action: " + json.dumps(step.graph.to_json()))
        elif step.calls:
            parts.append("Action: " + json.dumps(
                [{"tool": c.tool, "args": c.args} for c in step.calls]))
        if step.final:
            parts.append(step.final)
        return "\n".join(parts)
