"""Tool registry: API libraries and tool schemas (GeoLLM-Engine-style).

Every tool carries a JSON-schema-ish signature; serializing a catalog into
a planner prompt is what costs tokens — the quantity GeckOpt's gating
shrinks. Library names follow the paper's Table 1 (`SQL_apis`, `data_apis`,
`map_apis`, `web_apis`, `UI_apis`, `wiki_apis`) plus the remote-sensing
task suites GeoLLM-Engine exposes (detection, land-cover, VQA) and the
platform's modality backends (speech via whisper, vision via qwen2-vl).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.toolgraph import ToolEffects, WORKSPACE_RESOURCES


class EffectsCoverageError(Exception):
    """The registry and an effects table disagree — a tool without an
    effects entry (hazard inference would reject it at compile time) or
    an effects entry for no registered tool (dead declaration that can
    mask a rename), or an entry naming resources outside the hazard
    alphabet (dependencies silently not inferred)."""


@dataclass(frozen=True)
class Tool:
    name: str
    library: str
    description: str
    params: Tuple[Tuple[str, str, str], ...]   # (name, type, doc)
    returns: str = "object"

    def schema(self) -> Dict:
        return {
            "name": self.name,
            "description": self.description,
            "parameters": {
                "type": "object",
                "properties": {
                    p: {"type": t, "description": d}
                    for p, t, d in self.params},
                "required": [p for p, _, _ in self.params],
            },
            "returns": self.returns,
        }

    def serialize(self, compact: bool = True) -> str:
        """Compact catalog form (what production function-calling sends):
        name(params) + one-line description."""
        if compact:
            ps = ",".join(f"{p}:{t}" for p, t, _ in self.params)
            return f"{self.name}({ps}) — {self.description}"
        return json.dumps(self.schema(), separators=(",", ":"))


@dataclass
class ToolRegistry:
    tools: Dict[str, Tool] = field(default_factory=dict)

    def register(self, tool: Tool):
        assert tool.name not in self.tools, tool.name
        self.tools[tool.name] = tool

    def libraries(self) -> List[str]:
        return sorted({t.library for t in self.tools.values()})

    def by_library(self, libs: Sequence[str]) -> List[Tool]:
        libset = set(libs)
        return [t for t in self.tools.values() if t.library in libset]

    def catalog_text(self, libs: Optional[Sequence[str]] = None) -> str:
        tools = (list(self.tools.values()) if libs is None
                 else self.by_library(libs))
        return "\n".join(t.serialize() for t in
                         sorted(tools, key=lambda t: t.name))

    def get(self, name: str) -> Optional[Tool]:
        return self.tools.get(name)

    def names(self) -> List[str]:
        """Sorted tool names — the known-tool universe the tool-graph
        compiler validates node references against."""
        return sorted(self.tools)

    def validate_graph(self, graph):
        """Validate a ToolGraph against this catalog: typed
        ToolGraphError on unknown tools, dangling deps, duplicate node
        ids or cycles (core/toolgraph.py)."""
        return graph.validate(known_tools=self.names())


def validate_effects(registry: "ToolRegistry",
                     effects: Mapping[str, ToolEffects],
                     alphabet=WORKSPACE_RESOURCES) -> None:
    """Fail-fast cross-check of a registry against its effects table
    (the runtime mirror of the static analyzer's RL004/RL005 rules;
    ``env/tools_impl.py`` runs it at import time so a drifted table
    breaks immediately, not just under lint).

    Raises :class:`EffectsCoverageError` when coverage is not exactly
    1:1 or an entry names a resource outside the hazard alphabet.
    """
    problems: List[str] = []
    missing = sorted(set(registry.tools) - set(effects))
    extra = sorted(set(effects) - set(registry.tools))
    if missing:
        problems.append(f"registry tools without effects entry: {missing}")
    if extra:
        problems.append(f"effects entries for unregistered tools: {extra}")
    for name in sorted(effects):
        unknown = sorted(effects[name].unknown_resources(alphabet))
        if unknown:
            problems.append(
                f"{name}: effects name unknown resources {unknown} "
                f"(alphabet: {sorted(alphabet)})")
    if problems:
        raise EffectsCoverageError("; ".join(problems))


def _t(name, lib, desc, params, returns="object"):
    return Tool(name, lib, desc, tuple(params), returns)


def build_default_registry() -> ToolRegistry:
    """The platform's hand-written base catalog (docstring counts are
    derived below — see N_TOOLS/N_LIBRARIES — so they can never go
    stale as tools are added)."""
    r = ToolRegistry()
    P = lambda *ps: list(ps)

    # --- SQL_apis: metadata catalog queries --------------------------------
    for t in [
        _t("sql_query_images", "SQL_apis",
           "Query the image metadata catalog with filters on sensor, region, "
           "time range, cloud cover and resolution; returns image ids.",
           P(("sensor", "string", "sensor/dataset name e.g. xview1, sentinel2"),
             ("region", "string", "named region or bounding box"),
             ("date_from", "string", "ISO start date"),
             ("date_to", "string", "ISO end date"),
             ("max_cloud", "number", "max cloud-cover fraction"))),
        _t("sql_query_regions", "SQL_apis",
           "Resolve a place name to catalog region ids and bounding boxes.",
           P(("place", "string", "free-text place name"))),
        _t("sql_count", "SQL_apis",
           "Count catalog rows matching a filter expression.",
           P(("filter", "string", "SQL-like boolean filter"))),
        _t("sql_distinct", "SQL_apis",
           "List distinct values of a metadata column.",
           P(("column", "string", "metadata column name"))),
        _t("sql_sample", "SQL_apis",
           "Sample N catalog rows matching a filter.",
           P(("filter", "string", "SQL-like filter"),
             ("n", "integer", "sample size"))),
    ]:
        r.register(t)

    # --- data_apis: loading / filtering / processing -----------------------
    for t in [
        _t("load_images", "data_apis",
           "Load images by id list into the workspace; returns handles.",
           P(("image_ids", "array", "catalog image ids"))),
        _t("filter_clouds", "data_apis",
           "Drop workspace images above a cloud-cover threshold.",
           P(("handles", "array", "image handles"),
             ("max_cloud", "number", "threshold 0-1"))),
        _t("filter_date", "data_apis",
           "Keep workspace images inside a date range.",
           P(("handles", "array", "image handles"),
             ("date_from", "string", "ISO date"),
             ("date_to", "string", "ISO date"))),
        _t("mosaic", "data_apis",
           "Mosaic several overlapping images into one composite.",
           P(("handles", "array", "image handles"))),
        _t("reproject", "data_apis",
           "Reproject images to a target CRS.",
           P(("handles", "array", "image handles"),
             ("crs", "string", "target CRS e.g. EPSG:4326"))),
        _t("compute_ndvi", "data_apis",
           "Compute NDVI rasters for multispectral images.",
           P(("handles", "array", "image handles"))),
        _t("band_math", "data_apis",
           "Evaluate a band-arithmetic expression over images.",
           P(("handles", "array", "image handles"),
             ("expr", "string", "e.g. (B8-B4)/(B8+B4)"))),
        _t("export_geotiff", "data_apis",
           "Export workspace rasters as GeoTIFF artifacts.",
           P(("handles", "array", "image handles"))),
    ]:
        r.register(t)

    # --- map_apis: visualization -------------------------------------------
    for t in [
        _t("plot_map", "map_apis",
           "Render images/layers on an interactive map centered on a region.",
           P(("handles", "array", "image or layer handles"),
             ("region", "string", "center region"))),
        _t("add_layer", "map_apis",
           "Add a vector/raster overlay layer to the current map.",
           P(("layer", "string", "layer handle or name"))),
        _t("draw_bboxes", "map_apis",
           "Draw detection bounding boxes on the map.",
           P(("detections", "array", "detection result handle"))),
        _t("heatmap", "map_apis",
           "Render a density heatmap from point detections.",
           P(("detections", "array", "detection handles"))),
        _t("screenshot_map", "map_apis",
           "Capture the current map view as an image artifact.",
           P()),
        _t("plot_histogram", "map_apis",
           "Plot a histogram of a raster band or metadata column.",
           P(("source", "string", "handle or column"))),
        _t("plot_timeseries", "map_apis",
           "Plot a time series over images or detections.",
           P(("source", "string", "handle set"),
             ("metric", "string", "what to aggregate"))),
    ]:
        r.register(t)

    # --- detect_apis: remote-sensing model inference ------------------------
    for t in [
        _t("detect_objects", "detect_apis",
           "Run an object detector over images; returns boxes and classes.",
           P(("handles", "array", "image handles"),
             ("classes", "array", "object classes e.g. airplane, ship"))),
        _t("count_objects", "detect_apis",
           "Count detected objects per class over images.",
           P(("handles", "array", "image handles"),
             ("classes", "array", "object classes"))),
        _t("change_detection", "detect_apis",
           "Detect changes between two co-registered images.",
           P(("before", "string", "image handle"),
             ("after", "string", "image handle"))),
        _t("suggest_model", "detect_apis",
           "Recommend the best detector checkpoint for a class/sensor.",
           P(("task", "string", "detection task description"))),
    ]:
        r.register(t)

    # --- landcover_apis ------------------------------------------------------
    for t in [
        _t("classify_landcover", "landcover_apis",
           "Per-pixel land-cover classification (ESA classes).",
           P(("handles", "array", "image handles"))),
        _t("landcover_stats", "landcover_apis",
           "Aggregate land-cover class fractions over a region.",
           P(("handles", "array", "classified raster handles"))),
        _t("compare_landcover", "landcover_apis",
           "Compare land-cover fractions between two dates.",
           P(("a", "string", "classified handle"),
             ("b", "string", "classified handle"))),
    ]:
        r.register(t)

    # --- vqa_apis -------------------------------------------------------------
    for t in [
        _t("visual_qa", "vqa_apis",
           "Answer a free-text question about an image.",
           P(("handle", "string", "image handle"),
             ("question", "string", "the question"))),
        _t("caption_image", "vqa_apis",
           "Generate a caption for an image.",
           P(("handle", "string", "image handle"))),
        _t("compare_images_qa", "vqa_apis",
           "Answer a question comparing two images.",
           P(("a", "string", "image handle"), ("b", "string", "image handle"),
             ("question", "string", "the question"))),
    ]:
        r.register(t)

    # --- web_apis -------------------------------------------------------------
    for t in [
        _t("web_search", "web_apis",
           "Search the web; returns result titles, urls and snippets.",
           P(("query", "string", "search query"))),
        _t("open_url", "web_apis",
           "Fetch a web page and return its readable text.",
           P(("url", "string", "absolute URL"))),
        _t("download_file", "web_apis",
           "Download a file from a URL into the workspace.",
           P(("url", "string", "absolute URL"))),
        _t("post_form", "web_apis",
           "Submit a form on the current page.",
           P(("fields", "object", "form field values"))),
    ]:
        r.register(t)

    # --- UI_apis ---------------------------------------------------------------
    for t in [
        _t("ui_click", "UI_apis",
           "Click a UI element by accessibility label.",
           P(("label", "string", "element label"))),
        _t("ui_type", "UI_apis",
           "Type text into a focused UI field.",
           P(("text", "string", "text to type"))),
        _t("ui_scroll", "UI_apis",
           "Scroll the active view.",
           P(("direction", "string", "up|down|left|right"))),
        _t("ui_read", "UI_apis",
           "Read the text content of a UI element.",
           P(("label", "string", "element label"))),
        _t("ui_open_panel", "UI_apis",
           "Open a named application panel.",
           P(("panel", "string", "panel name"))),
    ]:
        r.register(t)

    # --- wiki_apis ---------------------------------------------------------------
    for t in [
        _t("wiki_search", "wiki_apis",
           "Search the knowledge base; returns article titles.",
           P(("query", "string", "search query"))),
        _t("wiki_get", "wiki_apis",
           "Fetch a knowledge-base article body.",
           P(("title", "string", "article title"))),
        _t("wiki_summarize", "wiki_apis",
           "Summarize a knowledge-base article.",
           P(("title", "string", "article title"))),
    ]:
        r.register(t)

    # --- speech_apis (whisper backend) -------------------------------------------
    for t in [
        _t("transcribe_audio", "speech_apis",
           "Transcribe an audio clip (whisper backend).",
           P(("clip", "string", "audio clip id"))),
        _t("translate_audio", "speech_apis",
           "Translate foreign speech to English text (whisper backend).",
           P(("clip", "string", "audio clip id"))),
    ]:
        r.register(t)

    # --- vision_apis (qwen2-vl backend) --------------------------------------------
    for t in [
        _t("describe_scene", "vision_apis",
           "Detailed scene description via the VLM backend.",
           P(("handle", "string", "image handle"))),
        _t("ground_phrase", "vision_apis",
           "Locate a phrase in an image; returns a box (VLM backend).",
           P(("handle", "string", "image handle"),
             ("phrase", "string", "referring expression"))),
    ]:
        r.register(t)

    # --- code_apis --------------------------------------------------------------------
    for t in [
        _t("run_python", "code_apis",
           "Execute a short python snippet over workspace artifacts.",
           P(("code", "string", "python source"))),
        _t("tabulate", "code_apis",
           "Render a list of records as a table artifact.",
           P(("records", "array", "list of objects"))),
    ]:
        r.register(t)

    return r


DEFAULT_REGISTRY = build_default_registry()

#: registry counts, derived — the hand-maintained "12 libraries, 48
#: tools" literals this module (and the intents/serving docstrings)
#: used to carry went stale the moment the catalog grew; anything that
#: needs the numbers reads these
N_TOOLS = len(DEFAULT_REGISTRY.tools)
N_LIBRARIES = len(DEFAULT_REGISTRY.libraries())
build_default_registry.__doc__ = (
    f"The platform's base catalog: {N_LIBRARIES} libraries, "
    f"{N_TOOLS} tools (counts derived from the registry itself; "
    f"core/catalog.py scales past this with generated families).")
