"""Embedding-based tool retrieval: expose top-k relevant tools per
query, with the gated intent as a fused reranking prior.

GeckOpt narrows the prompt catalog by intent→library mapping; at
hundreds of tools even one intent's libraries are too wide to serialize
per request. This layer retrieves a small per-query toolset by text
similarity — a seeded-deterministic stand-in for the vector-store tool
selection of Semantic Tool Discovery / ITR — and fuses the gate's
intent decision as a score prior (*gate augmentation, not
replacement*: the gate still decides ``visible``; retrieval only
decides which tool schemas are serialized into the prompt).

Determinism: the embedder is a hash-feature char-n-gram featurizer
(``zlib.crc32``, not Python's salted ``hash``), scoring is one jitted
cosine+prior matmul batched over the admission wave like
``IntentGate.batch``, and ranking ties break on tool id — so the full
ranking is a pure function of (catalog text, query, intent).

``ToolsetExposure`` is the serving object a retrieval produces: the
full ranking plus the current exposure width ``k``. Its sorted exposed
tool-id tuple is the canonical ``toolset_key``; ``key_str`` is the
stable engine prefix-cache key (sessions retrieving the same toolset
share one prefix prefill and its paged CoW blocks, and the cluster
rendezvous-routes the key like an intent prefix). ``widen_once`` is
the deterministic miss-and-widen fallback: if the planner emits a call
outside the exposed set (``TOOL_NOT_RETRIEVED``), the agent doubles
``k`` until the call is covered, charging each escalation to the
ledger — task outcomes stay bitwise identical to all-tools-exposed
because the planner's behaviour model never reads the catalog text
(DESIGN.md §Tool retrieval).
"""
from __future__ import annotations

import hashlib
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tools import ToolRegistry


@jax.jit
def _fused_scores(queries: jnp.ndarray, tools: jnp.ndarray,
                  prior: jnp.ndarray, prior_weight: jnp.ndarray
                  ) -> jnp.ndarray:
    """(B, D) query feats × (N, D) tool feats -> (B, N) fused scores:
    cosine similarity plus the per-intent library prior."""
    qn = queries / jnp.maximum(
        jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-6)
    tn = tools / jnp.maximum(
        jnp.linalg.norm(tools, axis=-1, keepdims=True), 1e-6)
    return qn @ tn.T + prior_weight * prior


class HashedNgramEmbedder:
    """Character n-gram feature hashing into a fixed dim — the cheapest
    embedder that still separates tool schemas by vocabulary. crc32 is
    process-stable (Python's ``hash`` is salted per process, which
    would break cross-run determinism)."""

    def __init__(self, dim: int = 256, n: int = 3):
        assert dim > 0 and n > 0
        self.dim = dim
        self.n = n

    def featurize(self, text: str) -> np.ndarray:
        v = np.zeros(self.dim, dtype=np.float32)
        s = f" {text.lower()} "
        for i in range(len(s) - self.n + 1):
            gram = s[i:i + self.n]
            v[zlib.crc32(gram.encode("utf-8")) % self.dim] += 1.0
        return v

    def featurize_batch(self, texts: Sequence[str]) -> np.ndarray:
        if not texts:
            return np.zeros((0, self.dim), dtype=np.float32)
        return np.stack([self.featurize(t) for t in texts])


@dataclass
class ToolsetExposure:
    """One query's retrieved toolset: the catalog-wide ranking plus the
    current exposure width (``k`` grows under miss-and-widen, never
    shrinks — sticky within a session)."""
    ranking: List[str]            # full deterministic catalog ranking
    k0: int                       # requested top-k
    k: int = field(init=False)    # current exposure width
    widens: int = 0               # miss-and-widen escalations taken

    def __post_init__(self):
        self.k0 = max(1, min(self.k0, len(self.ranking)))
        self.k = self.k0

    @property
    def exposed(self) -> Tuple[str, ...]:
        """Canonical toolset_key: the exposed tool ids, sorted — the
        identity the engine prefix cache and cluster router share."""
        return tuple(sorted(self.ranking[:self.k]))

    toolset_key = exposed

    @property
    def key_str(self) -> str:
        """Stable string form for engine/cluster prefix registries
        (sha1 of the sorted id tuple — identical across processes and
        machines, unlike ``hash``)."""
        digest = hashlib.sha1(
            ",".join(self.exposed).encode("utf-8")).hexdigest()[:16]
        return f"toolset:{digest}"

    def covers(self, tools) -> bool:
        exposed = set(self.ranking[:self.k])
        return all(t in exposed for t in tools)

    def widen_once(self) -> None:
        """One deterministic k-escalation (k doubles, capped at the
        catalog size)."""
        self.k = min(len(self.ranking), max(self.k * 2, self.k + 1))
        self.widens += 1

    def widen_full(self) -> None:
        """Jump straight to the full catalog (the TOOL_NOT_FOUND
        full-catalog fallback path; not counted as a retrieval miss)."""
        self.k = len(self.ranking)

    def catalog_text(self, registry: ToolRegistry) -> str:
        """Serialized exposed subset, sorted by tool name — at k == n
        this is byte-identical to ``registry.catalog_text()``, which is
        what makes the full-catalog fallback exact."""
        return "\n".join(registry.tools[n].serialize()
                         for n in self.exposed)


class ToolRetriever:
    """Top-k tool retrieval over one catalog registry.

    Scoring = cosine(query n-grams, tool schema n-grams) +
    ``prior_weight`` × per-intent library prior (1.0 for tools whose
    library serves the gated intent, else 0.0; unknown/ungated intents
    get a zero prior row). Ranking sorts the full catalog by
    ``(-score, tool id)`` — the tie-break keeps equal-scored tools in
    deterministic id order at any catalog size.
    """

    def __init__(self, registry: ToolRegistry,
                 intent_libs: Mapping[str, Sequence[str]],
                 k: int = 16, prior_weight: float = 0.25,
                 embedder: Optional[HashedNgramEmbedder] = None):
        assert k >= 1
        self.registry = registry
        self.k = k
        self.prior_weight = float(prior_weight)
        self.embedder = embedder or HashedNgramEmbedder()
        self.names: Tuple[str, ...] = tuple(registry.names())  # sorted
        texts = [registry.tools[n].serialize() for n in self.names]
        self._tool_feats = jnp.asarray(
            self.embedder.featurize_batch(texts))
        self.intents: Tuple[str, ...] = tuple(sorted(intent_libs))
        self._intent_row: Dict[str, int] = {
            it: i for i, it in enumerate(self.intents)}
        prior = np.zeros((len(self.intents) + 1, len(self.names)),
                         dtype=np.float32)   # last row: no/unknown intent
        for i, intent in enumerate(self.intents):
            libs = set(intent_libs[intent])
            for j, name in enumerate(self.names):
                if registry.tools[name].library in libs:
                    prior[i, j] = 1.0
        self._prior = prior

    # ------------------------------------------------------- ranking ----
    def rank_batch(self, queries: Sequence[str],
                   intents: Sequence[Optional[str]]
                   ) -> List[List[str]]:
        """Full catalog rankings for a wave of queries in ONE jitted
        scoring call (the retrieval analogue of ``IntentGate.batch``)."""
        assert len(queries) == len(intents)
        if not queries:
            return []
        feats = self.embedder.featurize_batch(queries)
        rows = np.array([self._intent_row.get(i, len(self.intents))
                         for i in intents])
        fused = np.asarray(_fused_scores(
            jnp.asarray(feats), self._tool_feats,
            jnp.asarray(self._prior[rows]),
            jnp.float32(self.prior_weight)))
        out: List[List[str]] = []
        for b in range(len(queries)):
            scores = fused[b]
            order = sorted(range(len(self.names)),
                           key=lambda j: (-float(scores[j]),
                                          self.names[j]))
            out.append([self.names[j] for j in order])
        return out

    def rank(self, query: str, intent: Optional[str] = None) -> List[str]:
        return self.rank_batch([query], [intent])[0]

    # ----------------------------------------------------- retrieval ----
    def retrieve(self, query: str, intent: Optional[str] = None,
                 k: Optional[int] = None) -> ToolsetExposure:
        return ToolsetExposure(self.rank(query, intent), k or self.k)

    def retrieve_batch(self, queries: Sequence[str],
                       intents: Sequence[Optional[str]],
                       k: Optional[int] = None) -> List[ToolsetExposure]:
        return [ToolsetExposure(r, k or self.k)
                for r in self.rank_batch(queries, intents)]
