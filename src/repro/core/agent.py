"""The multi-step agent loop: (optional GeckOpt gate) → planner → tools.

Implements the paper's runtime exactly:
  1. with gating on, one extra LLM call classifies intent and narrows the
     catalog to the mapped libraries;
  2. compositional planning proceeds over the (possibly narrowed) catalog,
     each step = one LLM request whose prompt carries the serialized
     catalog + history (all token-counted for real);
  3. fallback: if the planner reports TOOL_NOT_FOUND (the gate was too
     narrow), the agent reverts to the FULL toolset for this task and
     continues — "the agent being instructed via prompting to revert".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.accounting import TokenLedger
from repro.core.gate import IntentGate
from repro.core.planner import PlannerConfig, PlanStep, ScriptedPlanner
from repro.core.tools import ToolRegistry
from repro.env.tasks import Task
from repro.env.tools_impl import ToolError, Workspace, execute_tool
from repro.env.world import World


@dataclass
class TaskResult:
    task: Task
    workspace: Workspace
    ledger: TokenLedger
    completed_plan: bool
    fallback_used: bool
    intent_predicted: Optional[str]
    steps: int
    executed_tools: List[str] = field(default_factory=list)


class Agent:
    def __init__(self, registry: ToolRegistry, world: World,
                 planner_cfg: PlannerConfig,
                 gate: Optional[IntentGate] = None, seed: int = 0):
        self.registry = registry
        self.world = world
        self.planner_cfg = planner_cfg
        self.gate = gate
        self.seed = seed

    def run_task(self, task: Task, task_seed: int = 0) -> TaskResult:
        rng = np.random.default_rng(hash((self.seed, task_seed)) % 2**32)
        ws = Workspace(world=self.world, rng=rng,
                       temperature=self.planner_cfg.temperature)
        ledger = TokenLedger()
        planner = ScriptedPlanner(self.planner_cfg, self.registry,
                                  seed=int(rng.integers(0, 2**31)))
        planner.start_task(task)

        intent = None
        fallback_used = False
        if self.gate is not None:
            intent, libs = self.gate(task.query, ledger)
            visible = {t.name: t for t in self.registry.by_library(libs)}
            catalog = self.registry.catalog_text(libs)
        else:
            visible = dict(self.registry.tools)
            catalog = self.registry.catalog_text()

        history: List[str] = []
        executed: List[str] = []
        completed = False
        steps = 0
        while steps < self.planner_cfg.max_steps:
            steps += 1
            prompt = planner.serialize_prompt(task, catalog, history)
            step = planner.next_step(task, visible, history)
            ledger.record("plan", prompt, planner.serialize_completion(step))

            if step.tool_not_found and self.gate is not None and \
                    not fallback_used:
                # GeckOpt fallback: revert to the full toolset
                fallback_used = True
                visible = dict(self.registry.tools)
                catalog = self.registry.catalog_text()
                planner.note_fallback()
                history.append("Observation: TOOL_NOT_FOUND — reverting to "
                               "the full tool catalog.")
                continue
            if step.final is not None:
                completed = True
                break
            if not step.calls:
                history.append("Observation: (no action)")
                continue
            obs_parts = []
            for call in step.calls:
                try:
                    out = execute_tool(ws, call.tool, call.args)
                    executed.append(call.tool)
                    obs_parts.append(f"{call.tool} -> {out}")
                except ToolError as e:
                    obs_parts.append(f"{call.tool} -> ERROR: {e}")
            history.append("Observation: " + " | ".join(obs_parts))
            history.append(
                f"Workspace: {len(ws.handles)} handles loaded, "
                f"{len(ws.map_layers)} map layers, "
                f"{len(ws.detections)} detection sets, "
                f"{len(ws.artifacts)} artifacts; last tools: "
                f"{', '.join(executed[-4:]) or 'none'}")

        return TaskResult(task=task, workspace=ws, ledger=ledger,
                          completed_plan=completed,
                          fallback_used=fallback_used,
                          intent_predicted=intent, steps=steps,
                          executed_tools=executed)
