"""The multi-step agent loop: (optional GeckOpt gate) → planner → tools.

Implements the paper's runtime exactly:
  1. with gating on, one extra LLM call classifies intent and narrows the
     catalog to the mapped libraries;
  2. compositional planning proceeds over the (possibly narrowed) catalog,
     each step = one LLM request whose prompt carries the serialized
     catalog + history (all token-counted for real);
  3. fallback: if the planner reports TOOL_NOT_FOUND (the gate was too
     narrow), the agent reverts to the FULL toolset for this task and
     continues — "the agent being instructed via prompting to revert".

The loop is factored into a resumable ``AgentSession`` so the serving
pipeline (serving/pipeline.py) can interleave many sessions — gate a
whole admission wave in one batched classifier call, then advance the
sessions round-robin like continuous batching at the agent level.
``run_task`` remains the sequential entry point and is exactly
equivalent: per-session state (workspace rng, planner rng, ledger) is
isolated, so the interleaving order cannot change any task's outcome
(see DESIGN.md §Pipeline concurrency).

At serving scale the pipeline mirrors each session's planner turns onto
an inference engine — a single ``InferenceEngine`` or a multi-replica
``EngineCluster`` whose intent-affinity router keeps every session on
the replica caching its gated intent's prompt prefix (DESIGN.md
§Cluster serving). Session isolation is what makes that safe: a
session's outcome is independent of which replica serves its turns.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.accounting import TokenLedger
from repro.core.gate import IntentGate
from repro.core.planner import PlannerConfig, PlanStep, ScriptedPlanner
from repro.core.tools import Tool, ToolRegistry
from repro.env.tasks import Task
from repro.env.tools_impl import ToolError, Workspace, execute_tool
from repro.env.world import World


@dataclass
class TaskResult:
    task: Task
    workspace: Workspace
    ledger: TokenLedger
    completed_plan: bool
    fallback_used: bool
    intent_predicted: Optional[str]
    steps: int
    executed_tools: List[str] = field(default_factory=list)


@dataclass
class AgentSession:
    """One task's in-flight state, advanced one planner step at a time."""
    task: Task
    workspace: Workspace
    ledger: TokenLedger
    planner: ScriptedPlanner
    visible: Dict[str, Tool]
    catalog: str
    history: List[str] = field(default_factory=list)
    executed: List[str] = field(default_factory=list)
    intent: Optional[str] = None
    gated: bool = False
    fallback_used: bool = False
    completed: bool = False
    done: bool = False
    steps: int = 0
    index: int = 0              # arrival order (pipeline bookkeeping)

    def result(self) -> TaskResult:
        return TaskResult(task=self.task, workspace=self.workspace,
                          ledger=self.ledger,
                          completed_plan=self.completed,
                          fallback_used=self.fallback_used,
                          intent_predicted=self.intent, steps=self.steps,
                          executed_tools=self.executed)


class Agent:
    def __init__(self, registry: ToolRegistry, world: World,
                 planner_cfg: PlannerConfig,
                 gate: Optional[IntentGate] = None, seed: int = 0):
        self.registry = registry
        self.world = world
        self.planner_cfg = planner_cfg
        self.gate = gate
        self.seed = seed

    # ------------------------------------------------------- session API ----
    def start_session(self, task: Task, task_seed: int = 0) -> AgentSession:
        """Create the per-task state; does NOT run the gate yet (the
        pipeline gates whole admission waves in one batched call)."""
        rng = np.random.default_rng(hash((self.seed, task_seed)) % 2**32)
        ws = Workspace(world=self.world, rng=rng,
                       temperature=self.planner_cfg.temperature)
        planner = ScriptedPlanner(self.planner_cfg, self.registry,
                                  seed=int(rng.integers(0, 2**31)))
        planner.start_task(task)
        return AgentSession(task=task, workspace=ws, ledger=TokenLedger(),
                            planner=planner,
                            visible=dict(self.registry.tools),
                            catalog=self.registry.catalog_text())

    def apply_gate_result(self, session: AgentSession, intent: str,
                          libs: Tuple[str, ...]):
        """Install an (already ledger-charged) gate decision."""
        session.intent = intent
        session.visible = {t.name: t
                           for t in self.registry.by_library(libs)}
        session.catalog = self.registry.catalog_text(libs)
        session.gated = True

    def gate_session(self, session: AgentSession):
        """Single-query gate call (the sequential path)."""
        if self.gate is not None:
            intent, libs = self.gate(session.task.query, session.ledger)
            self.apply_gate_result(session, intent, libs)

    def step_session(self, session: AgentSession) -> bool:
        """One planner step (one LLM request). Returns True when the
        session has finished (plan complete or step budget exhausted)."""
        if session.done:
            return True
        session.steps += 1
        s = session
        prompt = s.planner.serialize_prompt(s.task, s.catalog, s.history)
        step = s.planner.next_step(s.task, s.visible, s.history)
        s.ledger.record("plan", prompt,
                        s.planner.serialize_completion(step))

        if step.tool_not_found and s.gated and not s.fallback_used:
            # GeckOpt fallback: revert to the full toolset
            s.fallback_used = True
            s.visible = dict(self.registry.tools)
            s.catalog = self.registry.catalog_text()
            s.planner.note_fallback()
            s.history.append("Observation: TOOL_NOT_FOUND — reverting to "
                             "the full tool catalog.")
        elif step.final is not None:
            s.completed = True
            s.done = True
        elif not step.calls:
            s.history.append("Observation: (no action)")
        else:
            ws = s.workspace
            obs_parts = []
            for call in step.calls:
                try:
                    out = execute_tool(ws, call.tool, call.args)
                    s.executed.append(call.tool)
                    obs_parts.append(f"{call.tool} -> {out}")
                except ToolError as e:
                    obs_parts.append(f"{call.tool} -> ERROR: {e}")
            s.history.append("Observation: " + " | ".join(obs_parts))
            s.history.append(
                f"Workspace: {len(ws.handles)} handles loaded, "
                f"{len(ws.map_layers)} map layers, "
                f"{len(ws.detections)} detection sets, "
                f"{len(ws.artifacts)} artifacts; last tools: "
                f"{', '.join(s.executed[-4:]) or 'none'}")

        if s.steps >= self.planner_cfg.max_steps:
            s.done = True
        return s.done

    # ---------------------------------------------------- sequential API ----
    def run_task(self, task: Task, task_seed: int = 0) -> TaskResult:
        session = self.start_session(task, task_seed)
        self.gate_session(session)
        while not self.step_session(session):
            pass
        return session.result()
