"""The multi-step agent loop: (optional GeckOpt gate) → planner → tools.

Implements the paper's runtime exactly:
  1. with gating on, one extra LLM call classifies intent and narrows the
     catalog to the mapped libraries;
  2. compositional planning proceeds over the (possibly narrowed) catalog,
     each step = one LLM request whose prompt carries the serialized
     catalog + history (all token-counted for real);
  3. fallback: if the planner reports TOOL_NOT_FOUND (the gate was too
     narrow), the agent reverts to the FULL toolset for this task and
     continues — "the agent being instructed via prompting to revert".

The loop is factored into a resumable ``AgentSession`` so the serving
pipeline (serving/pipeline.py) can interleave many sessions — gate a
whole admission wave in one batched classifier call, then advance the
sessions round-robin like continuous batching at the agent level.
``run_task`` remains the sequential entry point and is exactly
equivalent: per-session state (workspace rng, planner rng, ledger) is
isolated, so the interleaving order cannot change any task's outcome
(see DESIGN.md §Pipeline concurrency).

At serving scale the pipeline mirrors each session's planner turns onto
an inference engine — a single ``InferenceEngine`` or a multi-replica
``EngineCluster`` whose intent-affinity router keeps every session on
the replica caching its gated intent's prompt prefix (DESIGN.md
§Cluster serving). Session isolation is what makes that safe: a
session's outcome is independent of which replica serves its turns.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.accounting import TokenLedger
from repro.core.gate import IntentGate
from repro.core.planner import (CompiledStep, PlannerConfig, PlanStep,
                                ScriptedPlanner)
from repro.core.retriever import ToolRetriever, ToolsetExposure
from repro.core.tools import Tool, ToolRegistry
from repro.obs import NULL_TRACER
from repro.env.tasks import Task
from repro.env.tools_impl import (NodeObservation, ToolError, Workspace,
                                  execute_graph, execute_tool)
from repro.env.world import World


@dataclass
class TaskResult:
    task: Task
    workspace: Workspace
    ledger: TokenLedger
    completed_plan: bool
    fallback_used: bool
    intent_predicted: Optional[str]
    steps: int
    executed_tools: List[str] = field(default_factory=list)
    toolset: Optional[Tuple[str, ...]] = None  # initially exposed toolset
    widens: int = 0                            # miss-and-widen escalations


@dataclass
class AgentSession:
    """One task's in-flight state, advanced one planner step at a time."""
    task: Task
    workspace: Workspace
    ledger: TokenLedger
    planner: ScriptedPlanner
    visible: Dict[str, Tool]
    catalog: str
    history: List[str] = field(default_factory=list)
    executed: List[str] = field(default_factory=list)
    intent: Optional[str] = None
    gated: bool = False
    fallback_used: bool = False
    completed: bool = False
    done: bool = False
    steps: int = 0              # planner LLM round-trips issued
    virtual_steps: int = 0      # linear planner steps covered (== steps
    #                             without the compiler); the max_steps
    #                             budget is charged in virtual steps so
    #                             compilation cannot change which calls
    #                             the behaviour model gets to make
    index: int = 0              # arrival order (pipeline bookkeeping)
    exposure: Optional[ToolsetExposure] = None  # retrieved-toolset state
    exposed_initial: Optional[Tuple[str, ...]] = None

    def result(self) -> TaskResult:
        return TaskResult(task=self.task, workspace=self.workspace,
                          ledger=self.ledger,
                          completed_plan=self.completed,
                          fallback_used=self.fallback_used,
                          intent_predicted=self.intent, steps=self.steps,
                          executed_tools=self.executed,
                          toolset=self.exposed_initial,
                          widens=(self.exposure.widens
                                  if self.exposure else 0))


class Agent:
    def __init__(self, registry: ToolRegistry, world: World,
                 planner_cfg: PlannerConfig,
                 gate: Optional[IntentGate] = None, seed: int = 0,
                 retriever: Optional[ToolRetriever] = None,
                 exposure: str = "gated", tracer=NULL_TRACER):
        """``exposure`` picks what the serialized prompt catalog holds:

          * ``"gated"`` — the gate's library subset (the seed behaviour);
          * ``"all"`` — the full catalog text regardless of gating (the
            retrieval bench's baseline cell);
          * ``"retrieved"`` — the retriever's top-k toolset, widened
            deterministically on TOOL_NOT_RETRIEVED misses.

        ``visible`` — the behaviour model's input — is gate-driven in
        every mode, which is why task outcomes are bitwise identical
        across modes (DESIGN.md §Tool retrieval)."""
        assert exposure in ("gated", "all", "retrieved"), exposure
        if exposure == "retrieved" and retriever is None:
            raise ValueError("exposure='retrieved' needs a retriever")
        self.registry = registry
        self.world = world
        self.planner_cfg = planner_cfg
        self.gate = gate
        self.seed = seed
        self.retriever = retriever
        self.exposure = exposure
        self.tracer = tracer

    # ------------------------------------------------------- session API ----
    def start_session(self, task: Task, task_seed: int = 0) -> AgentSession:
        """Create the per-task state; does NOT run the gate yet (the
        pipeline gates whole admission waves in one batched call)."""
        rng = np.random.default_rng(hash((self.seed, task_seed)) % 2**32)
        ws = Workspace(world=self.world, rng=rng,
                       temperature=self.planner_cfg.temperature)
        planner = ScriptedPlanner(self.planner_cfg, self.registry,
                                  seed=int(rng.integers(0, 2**31)))
        planner.start_task(task)
        return AgentSession(task=task, workspace=ws, ledger=TokenLedger(),
                            planner=planner,
                            visible=dict(self.registry.tools),
                            catalog=self.registry.catalog_text())

    def apply_gate_result(self, session: AgentSession, intent: str,
                          libs: Tuple[str, ...]):
        """Install an (already ledger-charged) gate decision. ``visible``
        always narrows to the gated libraries; the serialized catalog
        only follows in ``"gated"`` exposure mode (``"all"`` keeps the
        full text, ``"retrieved"`` is set by apply_retrieval_result)."""
        session.intent = intent
        session.visible = {t.name: t
                           for t in self.registry.by_library(libs)}
        if self.exposure == "gated":
            session.catalog = self.registry.catalog_text(libs)
        session.gated = True

    def apply_retrieval_result(self, session: AgentSession,
                               exposure: ToolsetExposure):
        """Install an already-computed retrieval: the session's prompt
        catalog becomes the exposed top-k toolset text."""
        session.exposure = exposure
        session.exposed_initial = exposure.exposed
        session.catalog = exposure.catalog_text(self.registry)
        self.tracer.event("toolset_retrieved", tick=0, lane="retrieve",
                          session=session.index, k=exposure.k,
                          key=exposure.key_str)

    def retrieve_session(self, session: AgentSession):
        """Single-query retrieval (the sequential path; the pipeline
        retrieves whole admission waves in one batched scoring call)."""
        if self.exposure != "retrieved":
            return
        self.apply_retrieval_result(
            session,
            self.retriever.retrieve(session.task.query, session.intent))

    def gate_session(self, session: AgentSession):
        """Single-query gate call (the sequential path)."""
        if self.gate is not None:
            intent, libs = self.gate(session.task.query, session.ledger)
            self.apply_gate_result(session, intent, libs)
        self.retrieve_session(session)

    def plan_step(self, session: AgentSession):
        """One planner LLM round-trip: serialize the prompt, draw the
        next (linear or compiled) step, charge the ledger. Execution and
        reconciliation are separate (``execute_step``/``apply_step``) so
        the pipeline can fuse many sessions' round-trips into one
        batched tool execution."""
        s = session
        s.steps += 1
        prompt = s.planner.serialize_prompt(s.task, s.catalog, s.history)
        if self.planner_cfg.compile_plans:
            budget = self.planner_cfg.max_steps - s.virtual_steps
            step = s.planner.next_compiled_step(s.task, s.visible,
                                                s.history, budget)
            s.virtual_steps += step.n_virtual
            n_calls = len(step.graph.nodes)
        else:
            step = s.planner.next_step(s.task, s.visible, s.history)
            s.virtual_steps += 1
            n_calls = len(step.calls)
        s.ledger.record("plan", prompt,
                        s.planner.serialize_completion(step),
                        tool_calls=n_calls,
                        virtual_steps=(step.n_virtual
                                       if isinstance(step, CompiledStep)
                                       else 1))
        if s.exposure is not None and not step.tool_not_found:
            # TOOL_NOT_RETRIEVED miss-and-widen: the behaviour model may
            # emit a call outside the exposed toolset (it plans over
            # ``visible``, not the serialized catalog). Deterministically
            # double k until the calls are covered, charging each
            # re-serialization as a "widen" ledger entry (zero virtual
            # steps: round-trip metrics stay invariant). The loop bound
            # also terminates when a call is outside the FULL ranking
            # (truncated catalogs) — execution then raises the same
            # ToolError it would with all tools exposed.
            calls = (step.graph.nodes if isinstance(step, CompiledStep)
                     else step.calls)
            tools = {c.tool for c in calls}
            exp = s.exposure
            while (tools and not exp.covers(tools)
                   and exp.k < len(exp.ranking)):
                exp.widen_once()
                s.catalog = exp.catalog_text(self.registry)
                s.ledger.record(
                    "widen",
                    s.planner.serialize_prompt(s.task, s.catalog,
                                               s.history),
                    s.planner.serialize_completion(step))
                self.tracer.event("toolset_widen", tick=s.steps,
                                  lane="retrieve", session=s.index,
                                  k=exp.k)
        return step

    def execute_step(self, session: AgentSession, step
                     ) -> Optional[List[NodeObservation]]:
        """Run the step's tool calls against the session workspace.
        Linear steps execute in emission order; compiled steps execute
        their hazard DAG in topological waves (observation-equivalent,
        see env/tools_impl.execute_graph). Returns None when the step
        carries no calls (final / TOOL_NOT_FOUND / empty)."""
        s = session
        if isinstance(step, CompiledStep):
            if not step.graph.nodes:
                return None
            return execute_graph(s.workspace, step.graph)
        if not step.calls or step.tool_not_found:
            return None
        obs: List[NodeObservation] = []
        for i, call in enumerate(step.calls):
            try:
                out = execute_tool(s.workspace, call.tool, call.args)
                obs.append(NodeObservation(i, call.tool,
                                           f"{call.tool} -> {out}", True))
            except ToolError as e:
                obs.append(NodeObservation(i, call.tool,
                                           f"{call.tool} -> ERROR: {e}",
                                           False))
        return obs

    def apply_step(self, session: AgentSession, step,
                   observations: Optional[List[NodeObservation]]) -> bool:
        """Reconcile a round-trip's outcome into the session: fallback
        handling, observation/history append (observations arrive in
        node-id order — the documented reconciliation order), completion
        and the (virtual) step budget. Returns True when done."""
        s = session
        if step.tool_not_found and s.gated and not s.fallback_used:
            # GeckOpt fallback: revert to the full toolset
            s.fallback_used = True
            s.visible = dict(self.registry.tools)
            if s.exposure is not None:
                # jump the exposure straight to the full catalog (not a
                # retrieval miss — the gate was wrong, not the retriever);
                # at k == n the exposed text is byte-identical to
                # registry.catalog_text(), keeping the fallback exact
                s.exposure.widen_full()
                s.catalog = s.exposure.catalog_text(self.registry)
            else:
                s.catalog = self.registry.catalog_text()
            s.planner.note_fallback()
            s.history.append("Observation: TOOL_NOT_FOUND — reverting to "
                             "the full tool catalog.")
        else:
            if observations:
                ws = s.workspace
                s.executed.extend(o.tool for o in observations if o.ok)
                s.history.append("Observation: " + " | ".join(
                    o.text for o in observations))
                s.history.append(
                    f"Workspace: {len(ws.handles)} handles loaded, "
                    f"{len(ws.map_layers)} map layers, "
                    f"{len(ws.detections)} detection sets, "
                    f"{len(ws.artifacts)} artifacts; last tools: "
                    f"{', '.join(s.executed[-4:]) or 'none'}")
            elif step.final is None:
                s.history.append("Observation: (no action)")
            if step.final is not None:
                s.completed = True
                s.done = True

        if s.virtual_steps >= self.planner_cfg.max_steps:
            s.done = True
        return s.done

    def step_session(self, session: AgentSession) -> bool:
        """One planner round-trip (one LLM request). Returns True when
        the session has finished (plan complete or budget exhausted)."""
        if session.done:
            return True
        step = self.plan_step(session)
        observations = self.execute_step(session, step)
        return self.apply_step(session, step, observations)

    # ---------------------------------------------------- sequential API ----
    def run_task(self, task: Task, task_seed: int = 0) -> TaskResult:
        session = self.start_session(task, task_seed)
        self.gate_session(session)
        while not self.step_session(session):
            pass
        return session.result()
