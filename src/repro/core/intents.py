"""Intent taxonomy + offline intent→library mapping (paper Table 1).

The offline phase maps task intents to API-library subsets "with minimal
human involvement": ``build_intent_map`` mines a labeled task corpus (the
synthetic GeoLLM-Engine task generator provides one) and keeps every
library whose tools appear in ≥ coverage_q of that intent's ground-truth
plans — reproducing the paper's offline step rather than hard-coding it.
The hand-written Table-1 mapping is kept as ``TABLE1_MAP`` for reference
and asserted (in tests) to agree with the mined map on the paper's three
intent families.
"""
from __future__ import annotations

from collections import Counter, defaultdict
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

INTENTS = (
    "load_filter_plot",      # paper: "Load→Filter→Plot"
    "ui_web_navigation",     # paper: "UI/Web Navigation"
    "information_seeking",   # paper: "Information Seeking"
    "detection_analysis",    # GeoLLM-Engine detection/counting tasks
    "landcover_analysis",    # land-cover classification tasks
    "visual_qa",             # VQA tasks
    "speech_transcription",  # audio backend tasks
    "code_analysis",         # tabulation / scripting tasks
)

# Paper Table 1 (plus the additional GeoLLM-Engine families).
TABLE1_MAP: Dict[str, Tuple[str, ...]] = {
    "load_filter_plot": ("SQL_apis", "data_apis", "map_apis"),
    "ui_web_navigation": ("web_apis", "UI_apis"),
    "information_seeking": ("wiki_apis",),
    "detection_analysis": ("SQL_apis", "data_apis", "detect_apis",
                           "map_apis"),
    "landcover_analysis": ("SQL_apis", "data_apis", "landcover_apis"),
    "visual_qa": ("SQL_apis", "data_apis", "vqa_apis", "vision_apis"),
    "speech_transcription": ("speech_apis", "wiki_apis"),
    "code_analysis": ("code_apis", "SQL_apis"),
}


@dataclass
class IntentMap:
    intent_to_libs: Dict[str, Tuple[str, ...]]

    def libraries_for(self, intent: str,
                      full_fallback: Sequence[str] = ()) -> Tuple[str, ...]:
        return self.intent_to_libs.get(intent, tuple(full_fallback))


def build_intent_map(task_corpus, registry, coverage_q: float = 0.98
                     ) -> IntentMap:
    """Mine intent→library mapping from (intent, ground-truth plan) pairs.

    Keeps the smallest library set covering ≥ coverage_q of each intent's
    observed tool calls (the paper's offline phase).
    """
    lib_of = {name: t.library for name, t in registry.tools.items()}
    per_intent_calls: Dict[str, Counter] = defaultdict(Counter)
    per_intent_total: Dict[str, int] = defaultdict(int)
    for task in task_corpus:
        for stage in task.plan:
            for call in stage:
                lib = lib_of.get(call.tool)
                if lib:
                    per_intent_calls[task.intent][lib] += 1
                    per_intent_total[task.intent] += 1
    mapping = {}
    for intent, counts in per_intent_calls.items():
        total = per_intent_total[intent]
        libs: List[str] = []
        covered = 0
        for lib, c in counts.most_common():
            libs.append(lib)
            covered += c
            if covered >= coverage_q * total:
                break
        mapping[intent] = tuple(sorted(libs))
    return IntentMap(mapping)


INTENT_DESCRIPTIONS = {
    "load_filter_plot": "load imagery from the catalog, filter it, and "
                        "visualize on a map",
    "ui_web_navigation": "navigate the web or application UI",
    "information_seeking": "look up factual information in the knowledge "
                           "base",
    "detection_analysis": "detect, count or compare objects in imagery",
    "landcover_analysis": "classify or compare land cover",
    "visual_qa": "answer questions about image content",
    "speech_transcription": "transcribe or translate audio",
    "code_analysis": "tabulate results or run analysis code",
}
